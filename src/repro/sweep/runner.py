"""Cell execution + fan-out for the Monte-Carlo sweep.

`run_cell` is a pure function of its `ScenarioSpec`: it builds the
named market, a seeded client pool and one `FLCloudRunner`, runs it,
and returns plain-scalar metrics. Purity is what makes the sweep both
deterministic (same spec -> same numbers, pinned by tests/test_sweep.py
down to the serialized report) and trivially parallel — `run_sweep`
fans cells over a `multiprocessing` pool and `Pool.map` preserves
submission order, so the parallel result list is byte-identical to the
serial one.

The pool uses the "spawn"-safe module-level worker (`run_cell` itself);
workers re-import this module rather than inheriting interpreter state,
so nothing about the parent process can leak into a cell.
"""
from __future__ import annotations

import multiprocessing
from typing import Dict, List, Optional, Sequence

from repro.common.config import ClientProfile, CloudConfig, FLRunConfig
from repro.sweep.spec import ScenarioSpec, market_config

# every metric a cell reports; stats/report aggregate exactly these
METRICS = ("cost", "makespan_s", "lost_work_s", "n_preemptions")


def _clients(spec: ScenarioSpec):
    """A heterogeneous cross-silo pool: epoch times spread over a ~2x
    range (deterministic in the index, so every cell of a sweep trains
    the same workload; per-seed variation comes from the run's jitter
    and the market's scenario draw)."""
    return tuple(
        ClientProfile(name=f"c{i}",
                      mean_epoch_s=600.0 + 90.0 * (i % 7),
                      cold_multiplier=1.15, jitter=0.08)
        for i in range(spec.n_clients))


def run_cell(spec: ScenarioSpec) -> Dict[str, float]:
    """One deterministic run at the spec's coordinates -> metric dict
    (plain floats, picklable). The run seed and the scenario seed are
    both `spec.seed`: each Monte-Carlo repetition re-draws the client
    jitter *and* the adversarial market weather. A spec with
    `record_dir` set also persists the cell's event stream to
    `spec.trace_path()` for the sweep's `--audit` reconciliation."""
    from repro.fl.runner import FLCloudRunner  # deferred: worker import
    cloud = CloudConfig(
        market=market_config(spec.market, spec.seed),
        preemption_model=spec.preemption_model,
        preemption_rate_per_hr=spec.preemption_rate_per_hr)
    cfg = FLRunConfig(dataset="sweep", clients=_clients(spec),
                      n_epochs=spec.n_epochs, policy=spec.policy,
                      engine=(spec.engine or None), seed=spec.seed)
    res = FLCloudRunner(cfg, cloud_cfg=cloud,
                        record_to=spec.trace_path()).run()
    return {
        "cost": float(res.total_cost),
        "makespan_s": float(res.makespan_s),
        "lost_work_s": float(res.lost_work_s),
        "n_preemptions": float(res.n_preemptions),
    }


def run_sweep(specs: Sequence[ScenarioSpec], parallel: bool = True,
              processes: Optional[int] = None) -> List[Dict[str, float]]:
    """Run every spec; results align with `specs` by index. `parallel`
    fans out over a process pool (capped at the grid size); serial mode
    produces the identical list — the equivalence tests pin that, and
    the speedup benchmark measures the gap on multi-core hosts."""
    specs = list(specs)
    if not parallel or len(specs) <= 1:
        return [run_cell(s) for s in specs]
    n_proc = processes or multiprocessing.cpu_count()
    n_proc = max(1, min(n_proc, len(specs)))
    if n_proc == 1:
        return [run_cell(s) for s in specs]
    with multiprocessing.Pool(n_proc) as pool:
        return pool.map(run_cell, specs)
