"""Monte-Carlo scenario sweep: deterministic fan-out of policies x
markets x preemption models x seeds.

One seeded benchmark run answers "what did policy P cost on market M
once"; the paper's claim is statistical — FedCostAware should win *in
expectation, with a margin wider than the noise*. This package turns
that claim into a measured grid:

  spec    — `ScenarioSpec`, the picklable coordinates of one cell run
            (policy, named market, preemption model, seed, run shape),
            plus the registry of named sweep markets (the adversarial
            generators of `repro.cloud.scenarios` over a shared
            2-provider base).
  runner  — `run_cell` (one deterministic `FLCloudRunner` run per
            spec) and `run_sweep` (serial or `multiprocessing` fan-out
            with order-stable results — parallel output is
            byte-identical to serial).
  stats   — mean / percentile / seeded-bootstrap-CI summaries per
            (policy, market) cell across seeds.
  report  — the deterministic `BENCH_sweep.json` payload (sorted keys,
            no timestamps; two identical sweeps diff clean) and the
            human-readable per-market ranking table.

`benchmarks/sweep.py` is the CLI; docs/sweep.md documents the spec
format, the JSON schema and the CI thresholds.
"""
from repro.sweep.spec import (MARKETS, ScenarioSpec, build_grid,
                              market_config)
from repro.sweep.runner import run_cell, run_sweep
from repro.sweep.stats import bootstrap_ci, summarize
from repro.sweep.report import build_report, ranking_table

__all__ = ["MARKETS", "ScenarioSpec", "build_grid", "market_config",
           "run_cell", "run_sweep", "bootstrap_ci", "summarize",
           "build_report", "ranking_table"]
