"""Per-cell summary statistics: mean, percentiles, bootstrap CI.

A sweep cell is a handful of seeds (5-30), far too few for normal
approximations on cost distributions that preemption makes heavy-tailed
— so the confidence interval on the mean comes from a seeded
percentile bootstrap instead. The bootstrap RNG is seeded from the
data-independent `seed` argument, keeping the whole report
deterministic: two runs of the same sweep produce byte-identical JSON.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

DEFAULT_N_BOOT = 1000


def bootstrap_ci(values: Sequence[float], seed: int = 0,
                 n_boot: int = DEFAULT_N_BOOT,
                 level: float = 0.95) -> Tuple[float, float]:
    """Percentile-bootstrap CI for the mean of `values`: resample with
    replacement `n_boot` times (seeded), take the (1-level)/2 and
    1-(1-level)/2 quantiles of the resampled means. A single value
    collapses the interval to that value."""
    x = np.asarray(values, dtype=np.float64)
    if len(x) == 0:
        raise ValueError("bootstrap_ci needs at least one value")
    if len(x) == 1:
        return float(x[0]), float(x[0])
    rng = np.random.RandomState(seed)
    idx = rng.randint(0, len(x), size=(n_boot, len(x)))
    means = x[idx].mean(axis=1)
    alpha = (1.0 - level) / 2.0
    lo, hi = np.percentile(means, [100.0 * alpha, 100.0 * (1 - alpha)])
    return float(lo), float(hi)


def summarize(values: Sequence[float], seed: int = 0,
              n_boot: int = DEFAULT_N_BOOT) -> Dict[str, float]:
    """The per-cell record the report stores for one metric: mean,
    p10/p50/p90, bootstrap CI bounds, and the sample count."""
    x = np.asarray(values, dtype=np.float64)
    lo, hi = bootstrap_ci(x, seed=seed, n_boot=n_boot)
    return {
        "mean": float(x.mean()),
        "p10": float(np.percentile(x, 10.0)),
        "p50": float(np.percentile(x, 50.0)),
        "p90": float(np.percentile(x, 90.0)),
        "ci_lo": lo,
        "ci_hi": hi,
        "n": int(len(x)),
    }
