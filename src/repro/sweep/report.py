"""Deterministic sweep report: the BENCH_sweep.json payload and the
human-readable per-market ranking table.

The JSON is a pure function of (specs, results): sorted keys, no
timestamps, no host information — two identical sweeps diff clean,
which is what lets CI treat the artifact itself as a determinism check.
Schema (documented in docs/sweep.md):

  {
    "grid": {"policies": [...], "markets": [...], "models": [...],
             "engines": [...], "seeds": [...],
             "n_clients": N, "n_epochs": N},
    "cells": {
      "<policy>|<market>|<model>": {
        "<metric>": {mean, p10, p50, p90, ci_lo, ci_hi, n}, ...
      }, ...
    }
  }

Cells swept with an explicit engine override carry a fourth key part,
`<policy>|<market>|<model>|<engine>`; default-engine cells keep the
3-part key, so pre-engine-axis reports diff clean against new ones.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Dict, List, Sequence

from repro.sweep.runner import METRICS
from repro.sweep.spec import ScenarioSpec
from repro.sweep.stats import summarize


def cell_key(spec: ScenarioSpec) -> str:
    """The report key of a spec's (policy, market, model[, engine])
    cell. The engine part appears only when the spec pins one, keeping
    default-engine keys (and every pre-engine-axis report) unchanged."""
    key = f"{spec.policy}|{spec.market}|{spec.preemption_model}"
    if spec.engine:
        key += f"|{spec.engine}"
    return key


def build_report(specs: Sequence[ScenarioSpec],
                 results: Sequence[Dict[str, float]]) -> Dict:
    """Aggregate aligned (spec, result) pairs into the report dict:
    group by cell, summarize each metric across the cell's seeds.
    Deterministic — the bootstrap seed is derived from the cell key, so
    the same grid always yields the same CIs."""
    if len(specs) != len(results):
        raise ValueError(f"{len(specs)} specs vs {len(results)} results")
    by_cell: Dict[str, List[Dict[str, float]]] = defaultdict(list)
    seeds_by_cell: Dict[str, List[int]] = defaultdict(list)
    for spec, res in zip(specs, results):
        by_cell[cell_key(spec)].append(res)
        seeds_by_cell[cell_key(spec)].append(spec.seed)
    cells = {}
    for key in sorted(by_cell):
        rows = by_cell[key]
        boot_seed = hash_seed(key)
        cells[key] = {
            m: summarize([r[m] for r in rows], seed=boot_seed)
            for m in METRICS}
        cells[key]["seeds"] = sorted(seeds_by_cell[key])
    return {
        "grid": {
            "policies": sorted({s.policy for s in specs}),
            "markets": sorted({s.market for s in specs}),
            "models": sorted({s.preemption_model for s in specs}),
            "engines": sorted({s.engine for s in specs}),
            "seeds": sorted({s.seed for s in specs}),
            "n_clients": specs[0].n_clients if specs else 0,
            "n_epochs": specs[0].n_epochs if specs else 0,
        },
        "cells": cells,
    }


def hash_seed(key: str) -> int:
    """Stable (non-PYTHONHASHSEED) bootstrap seed from a cell key."""
    h = 0
    for ch in key:
        h = (h * 131 + ord(ch)) % (2 ** 31 - 1)
    return h


def dumps(report: Dict) -> str:
    """Canonical serialization: sorted keys, fixed separators — the
    bytes CI diffs between two runs of the same grid."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"


def ranking_table(report: Dict, metric: str = "cost") -> str:
    """Per-market policy ranking by mean `metric`, cheapest first, with
    the bootstrap CI alongside — the terminal summary `benchmarks/
    sweep.py` prints."""
    by_market: Dict[str, List] = defaultdict(list)
    for key, cell in report["cells"].items():
        policy, market, model, *rest = key.split("|")
        engine = rest[0] if rest else ""
        label = f"{policy}[{engine}]" if engine else policy
        s = cell[metric]
        by_market[market].append((s["mean"], label, model, s))
    lines = []
    for market in sorted(by_market):
        lines.append(f"{market}:")
        for rank, (mean, label, model, s) in enumerate(
                sorted(by_market[market]), start=1):
            lines.append(
                f"  {rank}. {label:<20} {mean:>10.4f} "
                f"[{s['ci_lo']:.4f}, {s['ci_hi']:.4f}]  "
                f"(p10 {s['p10']:.4f} / p90 {s['p90']:.4f}, "
                f"model={model}, n={s['n']})")
    return "\n".join(lines)
