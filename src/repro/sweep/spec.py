"""Sweep cell coordinates and the named-market registry.

A `ScenarioSpec` pins everything one cell run depends on, as plain
scalars — picklable across `multiprocessing` workers and hashable as a
dict key. The market axis is a *name* resolved through `MARKETS` at run
time (a `MarketConfig` holds no live objects, but shipping names keeps
specs tiny and the JSON report self-describing).

Every named market shares one 2-provider synthetic base (aws priced
like the paper's Table-I g5.xlarge row, gcp slightly off it) so
cross-market cost differences come from the scenario shaping, not from
different base economics. The scenario's own seed is the spec seed:
each Monte-Carlo repetition sees a *different draw of the same
adversarial weather*, which is exactly what the bootstrap CIs need.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.common.config import (MarketConfig, ProviderConfig,
                                 ScenarioConfig)

# default preemption model per market: crunch markets carry scheduled
# correlated reclaims (the "correlated" model folds them in on top of
# background churn); everywhere else the price-coupled hazard ties
# reclaims to the scenario's price shape
MARKET_MODELS: Dict[str, str] = {
    "baseline": "price_coupled",
    "flash_crash": "price_coupled",
    "capacity_crunch": "correlated",
    "diurnal": "price_coupled",
    "price_inversion": "price_coupled",
}

MARKETS: Dict[str, Optional[str]] = {
    "baseline": None,                       # un-shaped 2-provider base
    "flash_crash": "flash_crash",
    "capacity_crunch": "capacity_crunch",
    "diurnal": "diurnal",
    "price_inversion": "price_inversion",
}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Coordinates of one sweep cell run (one policy on one market
    under one preemption model at one seed). Frozen + scalar-only, so
    specs pickle to workers and key result dicts."""
    policy: str                    # repro.core.policies registry name
    market: str                    # MARKETS registry name
    preemption_model: str          # repro.cloud.preemption.MODEL_NAMES
    seed: int
    n_clients: int = 8             # cross-silo pool per run
    n_epochs: int = 6              # FL rounds per run
    preemption_rate_per_hr: float = 0.15   # background churn
    # round-engine override: "" keeps the policy's own engine (so
    # fedcostaware_async stays async); "sync" / "async_buffered" pin it
    # regardless of policy — the sweep's engine axis
    engine: str = ""
    # when non-empty, the cell run records its full event stream to
    # `<record_dir>/<cell_slug>.events.jsonl` — what `sweep --audit`
    # replays through the dollar-exact reconciler
    record_dir: str = ""

    def cell_slug(self) -> str:
        """Filesystem-safe cell identity: the grid coordinates joined
        in grid order, naming audit traces and audit failures."""
        return (f"{self.policy}__{self.market}__{self.preemption_model}"
                f"__{self.engine or 'default'}__s{self.seed}")

    def trace_path(self) -> Optional[Path]:
        """Where this cell records its event stream (None when the
        sweep is not recording)."""
        if not self.record_dir:
            return None
        return Path(self.record_dir) / f"{self.cell_slug()}.events.jsonl"


def market_config(name: str, seed: int) -> MarketConfig:
    """The named sweep market at `seed`: the shared 2-provider base,
    shaped by the registered scenario generator (None for the
    baseline). Unknown names raise, listing the registry."""
    if name not in MARKETS:
        raise ValueError(f"unknown sweep market {name!r}; known: "
                         f"{sorted(MARKETS)}")
    scenario = MARKETS[name]
    return MarketConfig(
        providers=(
            ProviderConfig(name="aws", on_demand_rate=1.008,
                           spot_rate_mean=0.3951, spot_rate_sigma=0.02,
                           n_zones=3),
            ProviderConfig(name="gcp", on_demand_rate=1.11,
                           spot_rate_mean=0.4200, spot_rate_sigma=0.02,
                           min_billing_s=30.0, n_zones=2),
        ),
        scenario=(None if scenario is None
                  # run-scale horizon: sweep runs finish in a few
                  # simulated hours, so the adversarial weather must
                  # land inside them, not somewhere in a 48 h default
                  else ScenarioConfig(name=scenario, seed=seed,
                                      horizon_s=4 * 3600.0,
                                      step_s=60.0)))


def build_grid(policies: Sequence[str], markets: Sequence[str],
               seeds: Sequence[int],
               models: Optional[Sequence[str]] = None,
               n_clients: int = 8, n_epochs: int = 6,
               engines: Optional[Sequence[str]] = None,
               ) -> List[ScenarioSpec]:
    """The full sweep grid, in deterministic (policy, market, model,
    engine, seed) order. `models=None` gives each market its registered
    default (`MARKET_MODELS`); an explicit list crosses every model
    with every market. `engines=None` keeps each policy's own round
    engine; an explicit list (e.g. ``["sync", "async_buffered"]``)
    crosses the engine override into the grid as a fourth axis."""
    specs: List[ScenarioSpec] = []
    cell_engines = list(engines) if engines is not None else [""]
    for policy in policies:
        for market in markets:
            cell_models = (models if models is not None
                           else [MARKET_MODELS.get(market,
                                                   "price_coupled")])
            for model in cell_models:
                for engine in cell_engines:
                    for seed in seeds:
                        specs.append(ScenarioSpec(
                            policy=policy, market=market,
                            preemption_model=model, seed=seed,
                            n_clients=n_clients, n_epochs=n_epochs,
                            engine=engine))
    return specs
