"""Struct-of-arrays fleet core: vectorized instance state + billing.

The per-object `CloudSimulator` pays one heap callback, one `Instance`
object and several bus publishes per instance lifecycle transition —
fine at cross-silo scale (tens of clients), hopeless for the ROADMAP's
100k-client fleets. This module holds the same lifecycle state in
contiguous numpy arrays, one slot per client:

  status        int8    ABSENT | SPINNING | RUNNING
  zone_idx      int64   index into the market's zone table
  t_request / t_ready   spin-up timing of the current instance
  billing_from  float64 open-billing anchor (NaN = no open segment)
  preempt_at    float64 absolute reclaim time (inf = never)
  fresh         bool    no epoch completed on the current instance yet
  settled       float64 dollars settled for the client so far

so a whole step's spin-up completions, billing settlements and
preemption draws are single array operations (`SpotMarket.cost_batch`,
`PreemptionModel.next_preemption_delays`) instead of Python loops.

Billing semantics are identical to the per-object path: billing starts
when an instance becomes RUNNING (spin-up is unbilled), segments close
at terminate/preempt with the provider's min-billing floor (spot only)
and granularity rounding, and dollars are priced by the zone's
`SpotMarket` source over the exact same prefix-sum integrals.

`ClientArrays` is the matching client-profile SoA: built either from
explicit `ClientProfile` tuples or — the cross-device jump — expanded
from a `PopulationConfig` in O(arrays), never materializing one Python
object per client.

The round discipline that drives these arrays lives in
`repro.fl.fleet.FleetRunner`; the switch between this core and the
per-object path is `CloudConfig.fleet_threshold` / `FLRunConfig.fleet`
(see docs/architecture.md, "Fleet core").
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.config import ClientProfile, PopulationConfig
from repro.cloud.pricing import SpotMarket

# instance slot states
ABSENT, SPINNING, RUNNING = 0, 1, 2


class _Placement:
    """Lightweight (provider, zone) record passed to the preemption
    models' vectorized path — duck-typed like an `Instance` but shared
    across a whole zone group, so batching 10k draws allocates a
    handful of these, not 10k."""

    __slots__ = ("provider", "zone")

    def __init__(self, provider: str, zone: str):
        self.provider = provider
        self.zone = zone


class ClientArrays:
    """Client heterogeneity profiles as contiguous arrays.

    Names are generated lazily (`name` / `names`): a 100k-client
    population costs five float arrays up front, and the string names
    only materialize when a result dict is assembled at run end.
    """

    def __init__(self, n: int, warm_mean: np.ndarray,
                 cold_mult: np.ndarray, jitter: np.ndarray,
                 budget: np.ndarray, join_round: np.ndarray,
                 name_prefix: str = "c",
                 explicit_names: Optional[List[str]] = None,
                 pinned: Optional[List[Optional[Tuple[Optional[str],
                                                      str]]]] = None):
        self.n = n
        self.warm_mean = warm_mean
        self.cold_mult = cold_mult
        self.jitter = jitter
        self.budget = budget
        self.join_round = join_round
        self._prefix = name_prefix
        self._names = explicit_names      # None -> prefix+index on demand
        # per-client pinned (provider, zone) placement, or None for
        # policy-driven placement; populations are never pinned
        self.pinned = pinned if pinned is not None else [None] * n

    # ------------------------------------------------------------------
    @classmethod
    def from_population(cls, pop: PopulationConfig) -> "ClientArrays":
        """Expand a `PopulationConfig` into arrays: per-client warm
        epoch times are lognormal around `mean_epoch_s` with
        cross-client sigma `epoch_sigma`, drawn from the population's
        own seed (reproducible independent of the run seed)."""
        n = pop.n_clients
        rng = np.random.RandomState(pop.seed)
        warm = pop.mean_epoch_s * np.exp(rng.randn(n) * pop.epoch_sigma)
        return cls(
            n, warm,
            np.full(n, pop.cold_multiplier),
            np.full(n, pop.jitter),
            np.full(n, pop.budget),
            np.zeros(n, dtype=np.int64),
            name_prefix=pop.name_prefix)

    @classmethod
    def from_profiles(cls, profiles: Sequence[ClientProfile]
                      ) -> "ClientArrays":
        """Arrays from explicit per-client profiles (the cross-silo
        spelling); pinned zones are preserved per client."""
        n = len(profiles)
        return cls(
            n,
            np.array([p.mean_epoch_s for p in profiles], dtype=np.float64),
            np.array([p.cold_multiplier for p in profiles]),
            np.array([p.jitter for p in profiles]),
            np.array([p.budget for p in profiles]),
            np.array([p.join_round for p in profiles], dtype=np.int64),
            explicit_names=[p.name for p in profiles],
            pinned=[None if p.zone is None else (p.provider, p.zone)
                    for p in profiles])

    # ------------------------------------------------------------------
    def name(self, i: int) -> str:
        """The i-th client's name."""
        if self._names is not None:
            return self._names[i]
        return f"{self._prefix}{i}"

    def names(self) -> List[str]:
        """All client names (materializes the lazy population names)."""
        if self._names is None:
            self._names = [f"{self._prefix}{i}" for i in range(self.n)]
        return self._names


class FleetState:
    """Instance lifecycle + billing state for a whole fleet, one slot
    per client (the sync barrier's invariant: at most one tracked
    instance per client; a replacement reuses the slot).

    All mutating operations take index arrays and run as batched numpy
    ops, grouped per zone only where billing rules differ. Settled
    dollars accumulate per client (`settled`) and per step/zone
    (`flush_step` drains the per-step aggregates that become one
    `FleetStepSummary` event).
    """

    def __init__(self, n: int, market: SpotMarket, on_demand: bool):
        self.n = n
        self.market = market
        self.on_demand = on_demand
        # zone table: stable index per (provider, zone) in market order
        self.zone_table: List[Tuple[str, str]] = [
            (z.provider, z.name) for z in market.zones]
        self.zone_index: Dict[Tuple[str, str], int] = {
            pz: i for i, pz in enumerate(self.zone_table)}
        provs = [market.provider_of(p) for p, _ in self.zone_table]
        self._min_billing = np.array(
            [0.0 if on_demand else p.min_billing_s for p in provs])
        self._granularity = np.array(
            [p.billing_granularity_s for p in provs])
        self._placements = [_Placement(p, z) for p, z in self.zone_table]

        self.status = np.zeros(n, dtype=np.int8)
        self.zone_idx = np.zeros(n, dtype=np.int64)
        self.t_request = np.full(n, np.nan)
        self.t_ready = np.full(n, np.nan)
        self.billing_from = np.full(n, np.nan)
        self.preempt_at = np.full(n, np.inf)
        self.fresh = np.ones(n, dtype=bool)
        self.settled = np.zeros(n)

        # lifetime counters + per-step aggregates (drained per summary)
        self.n_spinups = 0
        self.n_preemptions = 0
        self.n_terminations = 0
        self._step_cost = 0.0
        self._step_by_zone: Dict[int, Dict[str, float]] = defaultdict(
            lambda: defaultdict(float))
        # per-slot dollars settled since the last flush — the payload of
        # FleetStepSummary.client_cost_delta (dense array + touched mask
        # so `settle` stays a pure numpy scatter, no per-client loop)
        self._step_settled = np.zeros(n)
        self._step_touched = np.zeros(n, dtype=bool)

    # ------------------------------------------------------------------
    # Lifecycle transitions.
    # ------------------------------------------------------------------
    def request(self, idx: np.ndarray, zone_ids: np.ndarray,
                t_request: np.ndarray, spin_delays: np.ndarray
                ) -> np.ndarray:
        """Open fresh instance slots: SPINNING from `t_request`, ready
        after `spin_delays`. Returns the ready times."""
        ready = t_request + spin_delays
        self.status[idx] = SPINNING
        self.zone_idx[idx] = zone_ids
        self.t_request[idx] = t_request
        self.t_ready[idx] = ready
        self.billing_from[idx] = np.nan
        self.preempt_at[idx] = np.inf
        self.fresh[idx] = True
        self.n_spinups += len(idx)
        for z, cnt in zip(*np.unique(zone_ids, return_counts=True)):
            self._step_by_zone[int(z)]["spinups"] += int(cnt)
        return ready

    def activate(self, idx: np.ndarray, model, rng,
                 step_t: float) -> None:
        """SPINNING -> RUNNING at each slot's own ready time: billing
        opens at `t_ready`, and — spot fleets — the vectorized
        preemption model draws each instance's reclaim delay in one
        batch, anchored at the step time (per-step hazard batching;
        delays are measured from each instance's ready instant)."""
        self.status[idx] = RUNNING
        self.billing_from[idx] = self.t_ready[idx]
        if self.on_demand or model is None:
            return
        delays = np.full(len(idx), np.inf)
        for z in np.unique(self.zone_idx[idx]):
            sel = self.zone_idx[idx] == z
            insts = [self._placements[int(z)]] * int(sel.sum())
            delays[sel] = model.next_preemption_delays(insts, step_t, rng)
        self.preempt_at[idx] = self.t_ready[idx] + delays

    def settle(self, idx: np.ndarray, t_end: np.ndarray) -> np.ndarray:
        """Close the open billing segments of `idx` at aligned times
        `t_end`: min-billing floor (spot) + granularity rounding per
        provider, then one `cost_batch` per distinct zone. Returns the
        per-slot amounts (0 where no segment was open) and folds them
        into the per-client and per-step accumulators."""
        amounts = np.zeros(len(idx))
        t0 = self.billing_from[idx]
        open_mask = ~np.isnan(t0)
        if not open_mask.any():
            return amounts
        for z in np.unique(self.zone_idx[idx][open_mask]):
            sel = open_mask & (self.zone_idx[idx] == z)
            a = np.asarray(t0[sel])
            billed = np.maximum(t_end[sel] - a, self._min_billing[z])
            g = self._granularity[z]
            if g > 1.0:
                billed = np.ceil(billed / g - 1e-12) * g
            prov, zname = self.zone_table[int(z)]
            amt = self.market.cost_batch(zname, a, a + billed,
                                         self.on_demand, provider=prov)
            amounts[sel] = amt
            tot = float(amt.sum())
            self._step_cost += tot
            self._step_by_zone[int(z)]["cost"] += tot
        self.settled[idx] += amounts
        self._step_settled[idx] += amounts
        self._step_touched[idx[amounts != 0.0]] = True
        self.billing_from[idx] = np.nan
        return amounts

    def terminate(self, idx: np.ndarray, t_end: np.ndarray) -> None:
        """Deliberate stop at aligned times `t_end`: RUNNING slots
        settle their open segment; SPINNING slots just close (a spin-up
        terminated before ready never billed). Slots become ABSENT."""
        if len(idx) == 0:
            return
        self.settle(idx, t_end)
        running = self.status[idx] == RUNNING
        self.n_terminations += int(running.sum())
        for z, cnt in zip(*np.unique(self.zone_idx[idx][running],
                                     return_counts=True)):
            self._step_by_zone[int(z)]["terminations"] += int(cnt)
        self.status[idx] = ABSENT
        self.preempt_at[idx] = np.inf

    def preempt(self, idx: np.ndarray, t_end: np.ndarray) -> None:
        """Spot reclaim at aligned times `t_end` (callers pass each
        slot's own `preempt_at`): settle + count + close the slot."""
        if len(idx) == 0:
            return
        self.settle(idx, t_end)
        self.n_preemptions += len(idx)
        for z, cnt in zip(*np.unique(self.zone_idx[idx],
                                     return_counts=True)):
            self._step_by_zone[int(z)]["preemptions"] += int(cnt)
        self.status[idx] = ABSENT
        self.preempt_at[idx] = np.inf

    # ------------------------------------------------------------------
    # Queries.
    # ------------------------------------------------------------------
    def open_cost(self, now: float,
                  idx: Optional[np.ndarray] = None) -> np.ndarray:
        """Accrued-but-unsettled dollars of each slot's open billing
        segment at `now` (0 where closed); `idx=None` means the whole
        fleet. One `cost_batch` per distinct zone."""
        if idx is None:
            idx = np.arange(self.n)
        out = np.zeros(len(idx))
        t0 = self.billing_from[idx]
        open_mask = ~np.isnan(t0)
        if not open_mask.any():
            return out
        for z in np.unique(self.zone_idx[idx][open_mask]):
            sel = open_mask & (self.zone_idx[idx] == z)
            a = np.asarray(t0[sel])
            prov, zname = self.zone_table[int(z)]
            out[sel] = self.market.cost_batch(
                zname, a, np.full(len(a), now), self.on_demand,
                provider=prov)
        return out

    def flush_step(self) -> Tuple[float, Dict[str, Dict[str, float]],
                                  np.ndarray, np.ndarray]:
        """Drain the per-step aggregates: (dollars settled since the
        last flush, per-"provider/zone" breakdown, slot indices that
        settled nonzero dollars this step, their aligned amounts) — the
        payload of one `FleetStepSummary` event. The amounts sum to the
        first element (the step's `cost_delta`)."""
        by_zone = {f"{self.zone_table[z][0]}/{self.zone_table[z][1]}":
                   dict(aggs) for z, aggs in self._step_by_zone.items()}
        cost = self._step_cost
        touched = np.nonzero(self._step_touched)[0]
        amounts = self._step_settled[touched].copy()
        self._step_settled[touched] = 0.0
        self._step_touched[touched] = False
        self._step_cost = 0.0
        self._step_by_zone = defaultdict(lambda: defaultdict(float))
        return cost, by_zone, touched, amounts

    def resolve_zone(self, provider: Optional[str], zone: str) -> int:
        """Zone-table index of a pinned placement (provider resolved
        like `SpotMarket.resolve_provider`)."""
        prov = self.market.resolve_provider(zone, provider)
        return self.zone_index[(prov, zone)]
