"""Pluggable spot-reclaim models for the cloud simulator.

Real spot markets do not preempt at a flat Poisson rate: interruptions
cluster when demand (and therefore the spot price) spikes, and recorded
market days come with recorded reclaim times. This module makes the
reclaim process a strategy the simulator consults once per spot
instance, behind one small protocol:

  PreemptionModel.next_preemption_delay(inst, now, rng)
      -> seconds until this instance is reclaimed, or None for "never".

  PreemptionModel.next_preemption_delays(insts, now, rng)
      -> float array of delays for a whole batch, np.inf for "never".
         The vectorized path the struct-of-arrays fleet core
         (`repro.cloud.fleet`) uses: one call per simulation step for
         every instance that became RUNNING, instead of one Python
         callback per instance.

Four implementations:

  ConstantRateModel        — the pre-model behavior: exponential
                             inter-arrival at `preemption_rate_per_hr`.
                             Bit-identical to the old inline code (same
                             RNG, same draw, no draw at rate 0), so
                             default runs and golden traces do not move.
  PriceCoupledModel        — non-homogeneous hazard coupled to the
                             zone's current spot price: a price spike in
                             a `TracePriceSource` day drives an
                             interruption burst. Sensitivity is per
                             provider (`Provider.
                             preemption_price_sensitivity`).
  ReplayInterruptionModel  — replays recorded reclaim timestamps
                             (`SpotMarket.interruptions`, loaded from
                             `<provider>.interruptions.csv` files by
                             `repro.cloud.traces`) on the market clock.
  CorrelatedReclaimModel   — a base hazard model composed with the
                             market's recorded interruption schedule:
                             background churn plus scheduled
                             capacity-crunch reclaims that land
                             *correlated* across every zone of the
                             flagged provider (the `capacity_crunch`
                             scenario generator, `cloud.scenarios`).

Every model's batched path (`next_preemption_delays`) consumes the RNG
stream exactly like sequential scalar calls — `rng.random_sample(n)` /
`rng.exponential(scale, n)` draw in instance order — so a seeded run
lands on the same reclaim sequence whether it crosses
`CloudConfig.fleet_threshold` or not. tests/test_fleet.py pins the
draw identity for all models.

`build_preemption_model` resolves `CloudConfig.preemption_model`
("constant" | "price_coupled" | "replay" | "correlated") into an
instance bound to the run's `SpotMarket`.

See docs/markets.md for the trace formats and docs/architecture.md for
where the model sits in the event flow.
"""
from __future__ import annotations

import bisect
from typing import Dict, Optional, Protocol, Tuple

import numpy as np

from repro.cloud.pricing import SpotMarket

MODEL_NAMES = ("constant", "price_coupled", "replay", "correlated")


class PreemptionModel(Protocol):
    """When does the spot market reclaim an instance?"""

    def next_preemption_delay(self, inst, now: float,
                              rng: np.random.RandomState,
                              ) -> Optional[float]:
        """Seconds from `now` until `inst` is reclaimed, or None if it
        never is. Called once when the instance becomes RUNNING; the
        simulator schedules the provider's warning and the reclaim off
        the returned delay. Draws (if any) must come from `rng` so
        seeded runs stay deterministic."""
        ...

    def next_preemption_delays(self, insts, now: float,
                               rng: np.random.RandomState,
                               ) -> np.ndarray:
        """Vectorized form: delays (seconds from `now`) for every
        element of `insts` at once, `np.inf` standing in for the scalar
        API's None. `insts` is a sequence of anything carrying
        `.provider` and `.zone` (live `Instance`s or the fleet core's
        lightweight placement records)."""
        ...


class ConstantRateModel:
    """Flat Poisson reclaims — the paper's §III-D fault model and the
    simulator's historical behavior.

    The delay is a single `rng.exponential` draw with the exact
    arithmetic of the pre-model inline code (and no draw at all when
    the rate is zero), keeping seeded event sequences bit-identical
    across the refactor.
    """

    def __init__(self, rate_per_hr: float):
        self.rate_per_hr = rate_per_hr

    def next_preemption_delay(self, inst, now, rng):
        """One exponential inter-arrival at the configured rate."""
        if self.rate_per_hr <= 0.0:
            return None
        rate = self.rate_per_hr / 3600.0
        return float(rng.exponential(1.0 / rate))

    def next_preemption_delays(self, insts, now, rng):
        """Batched exponential draws. `rng.exponential(scale, size=n)`
        consumes the legacy `RandomState` stream in the same order as n
        sequential scalar draws, so the batch is draw-identical to
        calling `next_preemption_delay` once per instance — the
        equivalence tests pin this."""
        n = len(insts)
        if self.rate_per_hr <= 0.0:
            return np.full(n, np.inf)
        rate = self.rate_per_hr / 3600.0
        return rng.exponential(1.0 / rate, size=n)


class PriceCoupledModel:
    """Reclaim hazard scaled by the zone's current price level.

    The instantaneous hazard is

        lambda(t) = base_rate * max(0, 1 + s * (p(t) / p_ref - 1))

    where `p(t)` is the zone's spot price, `p_ref` its time-averaged
    price over the recorded horizon (`SpotMarket.mean_spot_price`), and
    `s` the owning provider's `preemption_price_sensitivity`. At `s=0`
    this degrades to the constant model's mean behavior; larger `s`
    concentrates interruptions into price spikes (a 2x spike at `s=5`
    multiplies the hazard by 6).

    Sampling discretizes the hazard onto a `step_s` grid: each step
    preempts with probability `1 - exp(-lambda * step)`, which keeps
    the model correct under hazard clamping and arbitrary price shapes.
    The draw itself is a single uniform inverted through the per-step
    failure CDF (`_zone_failure_cdf`) — scalar and batched calls
    therefore consume the RNG stream identically (one uniform per
    instance, in instance order), so a seeded run's reclaim sequence
    does not depend on whether the fleet path batched the draws. The
    pre-fix scalar path thinned step-by-step (one uniform per step),
    which consumed the stream in a different order than the batch;
    distributionally the two are the same.
    """

    def __init__(self, market: SpotMarket, base_rate_per_hr: float,
                 step_s: float = 60.0, horizon_s: float = 14 * 86400.0):
        self.market = market
        self.base_rate_per_hr = base_rate_per_hr
        self.step_s = step_s
        self.horizon_s = horizon_s
        self._ref_price: Dict[Tuple[str, str], float] = {}

    def _ref(self, provider: str, zone: str) -> float:
        """Cached per-zone reference (mean) price."""
        key = (provider, zone)
        if key not in self._ref_price:
            self._ref_price[key] = self.market.mean_spot_price(
                zone, provider)
        return self._ref_price[key]

    def hazard(self, provider: str, zone: str, t: float) -> float:
        """Instantaneous reclaim hazard (events/second) at `t`."""
        base = self.base_rate_per_hr / 3600.0
        if base <= 0.0:
            return 0.0
        s = self.market.provider_of(provider).preemption_price_sensitivity
        ref = self._ref(provider, zone)
        level = self.market.spot_price(zone, t, provider) / ref
        return base * max(1.0 + s * (level - 1.0), 0.0)

    def next_preemption_delay(self, inst, now, rng):
        """One uniform inverted through the zone's failure CDF: the
        first step whose cumulative failure probability exceeds the
        draw fails at its end; a draw beyond the horizon's CDF means
        the instance outlives the horizon (None)."""
        if self.base_rate_per_hr <= 0.0:
            return None
        cdf = self._zone_failure_cdf(inst.provider, inst.zone, now,
                                     self.horizon_s)
        k = int(np.searchsorted(cdf, rng.random_sample(), side="right"))
        if k >= len(cdf):
            return None
        return (k + 1) * self.step_s

    def _zone_failure_cdf(self, provider: str, zone: str, now: float,
                          horizon_s: float) -> np.ndarray:
        """Per-step failure CDF for one zone from `now`: F[k] is the
        probability thinning has fired by the end of step k. Built once
        per (zone, step) and shared by every co-located instance — the
        whole fleet's preemption draws then reduce to one uniform per
        instance plus a `searchsorted`."""
        n_steps = int(horizon_s / self.step_s)
        ts = now + np.arange(n_steps) * self.step_s
        base = self.base_rate_per_hr / 3600.0
        s = self.market.provider_of(provider).preemption_price_sensitivity
        ref = self._ref(provider, zone)
        src = self.market.source(zone, provider)
        prices_at = getattr(src, "prices_at", None)
        if prices_at is not None:
            level = prices_at(ts) / ref
        else:
            level = np.array([self.market.spot_price(zone, float(t),
                                                     provider)
                              for t in ts]) / ref
        lam = base * np.maximum(1.0 + s * (level - 1.0), 0.0)
        p = -np.expm1(-lam * self.step_s)
        return 1.0 - np.cumprod(1.0 - p)

    def next_preemption_delays(self, insts, now, rng,
                               horizon_s: Optional[float] = None):
        """Inverse-CDF sampling over the whole batch: one uniform per
        instance (`rng.random_sample(n)` consumes the RandomState
        stream exactly like n sequential scalar draws, so the batch is
        draw-identical to calling `next_preemption_delay` per instance
        — pinned by tests/test_fleet.py), then one shared CDF +
        `searchsorted` per distinct zone. `horizon_s` overrides the
        model horizon (the fleet may pass round-scale horizons to keep
        the CDF short)."""
        n = len(insts)
        out = np.full(n, np.inf)
        if self.base_rate_per_hr <= 0.0 or n == 0:
            return out
        horizon = self.horizon_s if horizon_s is None else horizon_s
        u = rng.random_sample(n)
        groups: Dict[Tuple[str, str], list] = {}
        for i, inst in enumerate(insts):
            groups.setdefault((inst.provider, inst.zone), []).append(i)
        for (prov, zone), raw in groups.items():
            cdf = self._zone_failure_cdf(prov, zone, now, horizon)
            idx = np.asarray(raw)
            # first step whose CDF exceeds u -> fails at end of step k
            k = np.searchsorted(cdf, u[idx], side="right")
            hit = k < len(cdf)
            out[idx[hit]] = (k[hit] + 1) * self.step_s
        return out


class ReplayInterruptionModel:
    """Recorded real interruption timestamps, on the market clock.

    A reclaim recorded at time T in zone z takes down whatever spot
    instance is running there at T (every co-located instance sees the
    same event, as a real capacity reclaim would). An instance whose
    zone has no recorded interruption after `now` runs until terminated.
    Draws nothing — replayed fault patterns are exactly reproducible.
    """

    def __init__(self, market: SpotMarket):
        self.market = market

    def next_preemption_delay(self, inst, now, rng):
        """First recorded interruption in the instance's zone after
        `now` (strictly — an instance born at the reclaim instant
        survives it)."""
        times = self.market.interruptions.get((inst.provider, inst.zone))
        if not times:
            return None
        i = bisect.bisect_right(times, now)
        if i >= len(times):
            return None
        return times[i] - now

    def next_preemption_delays(self, insts, now, rng):
        """Batched zone lookups: one bisect per distinct zone, the same
        recorded delay fanned out to every co-located instance (as the
        scalar API would return). Draws nothing."""
        out = np.full(len(insts), np.inf)
        cache: Dict[Tuple[str, str], float] = {}
        for i, inst in enumerate(insts):
            key = (inst.provider, inst.zone)
            if key not in cache:
                times = self.market.interruptions.get(key)
                if times:
                    j = bisect.bisect_right(times, now)
                    cache[key] = (times[j] - now if j < len(times)
                                  else np.inf)
                else:
                    cache[key] = np.inf
            out[i] = cache[key]
        return out


class CorrelatedReclaimModel:
    """Scheduled capacity-crunch reclaims on top of a base hazard.

    Real provider-wide capacity crunches reclaim spot instances across
    *every* zone of the squeezed provider at nearly the same instant —
    a correlation no per-zone Poisson process reproduces. This model
    composes a base `PreemptionModel` (independent background churn)
    with the market's recorded interruption schedule
    (`SpotMarket.interruptions`, e.g. the `capacity_crunch` scenario
    generator's correlated reclaim times): an instance falls at
    whichever comes first, the base model's draw or the next scheduled
    reclaim in its zone.

    The schedule lookup draws nothing, so the composition's RNG
    consumption — and therefore the scalar/batch draw identity — is
    exactly the base model's.
    """

    def __init__(self, market: SpotMarket, base: PreemptionModel):
        self.market = market
        self.base = base
        self._sched = ReplayInterruptionModel(market)

    def next_preemption_delay(self, inst, now, rng):
        """min(base draw, next scheduled reclaim), None if neither."""
        delays = [d for d in (self.base.next_preemption_delay(inst, now,
                                                              rng),
                              self._sched.next_preemption_delay(inst, now,
                                                                rng))
                  if d is not None]
        return min(delays) if delays else None

    def next_preemption_delays(self, insts, now, rng):
        """Elementwise min of the base batch and the schedule batch
        (inf stands in for None on both sides)."""
        return np.minimum(
            self.base.next_preemption_delays(insts, now, rng),
            self._sched.next_preemption_delays(insts, now, rng))


def build_preemption_model(cfg, market: SpotMarket) -> PreemptionModel:
    """Resolve `CloudConfig.preemption_model` into a model bound to
    `market`. Unknown names raise `ValueError` listing the registry."""
    name = getattr(cfg, "preemption_model", "constant")
    if name == "constant":
        return ConstantRateModel(cfg.preemption_rate_per_hr)
    if name == "price_coupled":
        return PriceCoupledModel(market, cfg.preemption_rate_per_hr)
    if name == "replay":
        return ReplayInterruptionModel(market)
    if name == "correlated":
        return CorrelatedReclaimModel(
            market, ConstantRateModel(cfg.preemption_rate_per_hr))
    raise ValueError(f"unknown preemption model {name!r}; "
                     f"known: {MODEL_NAMES}")
