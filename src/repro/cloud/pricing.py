"""Provider-agnostic spot / on-demand market across providers, regions
and availability zones.

The paper (§III-A "Dynamic Resource Allocation") queries real-time spot
prices across regions/zones and picks the cheapest. The pricing surface
is layered so both synthetic and real market days plug in behind one
interface:

  PriceSource   — `price(t)` / `integral(t0, t1)` for one zone's spot
                  price process. Two implementations:
                    SyntheticOUSource  — the calibrated OU-like process
                                         (paper Table I rates)
                    TracePriceSource   — piecewise-constant real price
                                         history (AWS spot-history
                                         format, loaded by cloud.traces)
                  Both answer integrals in O(1) off prefix sums — the
                  billing hot path prices an open segment on every cost
                  query.
  Provider      — per-provider billing semantics: on-demand rate,
                  billing granularity, min-billing floor, preemption
                  notice.
  Zone          — (provider, region, zone) placement target.
  SpotMarket    — owns every provider's sources and arbitrates
                  `cheapest_zone` across providers with deterministic
                  tie-breaking (lowest price, then registration order).

`PriceBook(cfg, seed)` survives as a constructor alias for the default
single-provider synthetic market; it builds the exact same traces as the
pre-redesign class, so seeded runs are bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import (Dict, Iterable, List, Optional, Protocol, Sequence,
                    Tuple, Union)

import numpy as np

from repro.comms.billing import TransferRates
from repro.common.config import CloudConfig, MarketConfig, ProviderConfig

DEFAULT_PROVIDER = "aws"


@dataclasses.dataclass(frozen=True)
class StorageRates:
    """Object-storage pricing of one provider: what a warning-window
    checkpoint write costs (S3-style flat PUT request + per-MB egress
    of the model state). Zero by default, so checkpoint writes stay
    free — and every pre-redesign total unchanged — until a market
    opts in."""
    put_usd: float = 0.0               # $ per PUT request
    egress_usd_per_mb: float = 0.0     # $ per MB written out

    def checkpoint_cost(self, size_mb: float) -> float:
        """Dollars one checkpoint write of `size_mb` MB costs."""
        return self.put_usd + self.egress_usd_per_mb * max(size_mb, 0.0)


@dataclasses.dataclass(frozen=True)
class Provider:
    """Billing semantics of one cloud provider (formerly CloudConfig
    globals, now carried per provider so markets can mix them)."""
    name: str
    on_demand_rate: float              # $/hr
    billing_granularity_s: float = 1.0  # round billed duration up to this
    min_billing_s: float = 60.0         # spot min-billing floor (seconds)
    preemption_notice_s: float = 0.0    # reclaim warning lead time
    # hazard-vs-price slope under the price-coupled preemption model
    # (repro.cloud.preemption.PriceCoupledModel); 0 keeps this
    # provider's reclaim rate flat even when the market spikes
    preemption_price_sensitivity: float = 1.0
    # object-storage rates billed per warning-window checkpoint write
    storage: StorageRates = StorageRates()
    # client-update egress rates (repro.comms.billing); zero default
    # keeps transfer billing opt-in and pre-comms totals unchanged
    transfer: TransferRates = TransferRates()
    # uplink bandwidth for client-update uploads (Mbit/s); <= 0 means
    # unmodeled (instantaneous). Zone pairs override the base rate.
    uplink_mbps: float = 0.0
    zone_uplink_mbps: Tuple[Tuple[str, float], ...] = ()

    @classmethod
    def from_cloud_config(cls, cfg: CloudConfig,
                          name: str = DEFAULT_PROVIDER) -> "Provider":
        """Build the single default provider a legacy scalar
        `CloudConfig` (no explicit `MarketConfig`) describes."""
        return cls(name, on_demand_rate=cfg.on_demand_rate,
                   billing_granularity_s=cfg.billing_granularity_s,
                   min_billing_s=cfg.min_billing_s,
                   preemption_price_sensitivity=(
                       cfg.preemption_price_sensitivity))

    @classmethod
    def from_provider_config(cls, pc: ProviderConfig) -> "Provider":
        """Lift one `MarketConfig` provider entry into the runtime
        descriptor every billing/preemption decision reads."""
        return cls(pc.name, on_demand_rate=pc.on_demand_rate,
                   billing_granularity_s=pc.billing_granularity_s,
                   min_billing_s=pc.min_billing_s,
                   preemption_notice_s=pc.preemption_notice_s,
                   preemption_price_sensitivity=(
                       pc.preemption_price_sensitivity),
                   storage=StorageRates(
                       pc.storage_put_usd,
                       pc.storage_egress_usd_per_mb),
                   transfer=TransferRates(pc.update_egress_usd_per_mb),
                   uplink_mbps=pc.uplink_mbps,
                   zone_uplink_mbps=tuple(pc.zone_uplink_mbps))


@dataclasses.dataclass(frozen=True)
class Zone:
    """A placement target: (provider, region, availability zone)."""
    name: str                       # e.g. "us-east-1a"
    region: str                     # e.g. "us-east-1"
    provider: str = DEFAULT_PROVIDER


class PriceSource(Protocol):
    """One zone's spot price process."""

    def price(self, t: float) -> float:
        """Spot price ($/hr) in force at time `t`."""
        ...

    def integral(self, t0: float, t1: float) -> float:
        """Integral of price over [t0, t1] in $·s/hr (divide by 3600
        for dollars)."""
        ...


class SyntheticOUSource:
    """Piecewise-constant mean-reverting price process for one zone.

    AWS publishes spot price updates at irregular intervals (minutes to
    hours); we model hourly steps of an OU-like process clipped to
    [0.25, 1.0] x on-demand.
    """

    def __init__(self, mean: float, sigma: float, on_demand: float,
                 seed: int, step_s: float = 3600.0, horizon_s: float = 7 * 86400.0,
                 reversion: float = 0.2):
        rng = np.random.RandomState(seed)
        n = int(horizon_s / step_s) + 2
        prices = np.empty(n)
        p = mean + rng.randn() * sigma
        for i in range(n):
            prices[i] = np.clip(p, 0.25 * on_demand, 1.0 * on_demand)
            p = p + reversion * (mean - p) + rng.randn() * sigma
        self._step = step_s
        self._prices = prices
        # prefix sums: _cum[i] = integral over the first i full steps,
        # making `integral` O(1) instead of O(steps spanned) — it sits on
        # the billing hot path (every cost query prices an open segment).
        self._cum = np.concatenate([[0.0], np.cumsum(prices) * step_s])

    def price(self, t: float) -> float:
        """Price of the hourly step containing `t` (last step extends
        beyond the horizon)."""
        i = min(int(t / self._step), len(self._prices) - 1)
        return float(self._prices[i])

    def _antiderivative(self, t: float) -> float:
        """Integral of the trace over [0, t]; beyond the horizon the last
        step's price extends (matching `price`'s clamped lookup)."""
        i = min(int(t / self._step), len(self._prices) - 1)
        return float(self._cum[i]
                     + self._prices[i] * (t - i * self._step))

    def integral(self, t0: float, t1: float) -> float:
        """Integral of the price over [t0, t1] in $·s/hr, O(1)."""
        if t1 <= t0:
            return 0.0
        return self._antiderivative(t1) - self._antiderivative(t0)

    def _antiderivative_batch(self, t: np.ndarray) -> np.ndarray:
        """Vectorized `_antiderivative` over an array of times — the
        fleet core settles thousands of billing segments per step, and
        one scalar Python call per instance would dominate the whole
        simulation."""
        i = np.minimum((t / self._step).astype(np.int64),
                       len(self._prices) - 1)
        return self._cum[i] + self._prices[i] * (t - i * self._step)

    def integral_batch(self, t0: np.ndarray, t1: np.ndarray) -> np.ndarray:
        """Elementwise `integral` over aligned time arrays ($·s/hr)."""
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.asarray(t1, dtype=np.float64)
        out = self._antiderivative_batch(t1) - self._antiderivative_batch(t0)
        return np.where(t1 <= t0, 0.0, out)

    def prices_at(self, t: np.ndarray) -> np.ndarray:
        """Vectorized `price` lookup (per-step hazard batching)."""
        i = np.minimum((np.asarray(t, dtype=np.float64)
                        / self._step).astype(np.int64),
                       len(self._prices) - 1)
        return self._prices[i]


# backwards-compatible name for the synthetic process
SpotPriceTrace = SyntheticOUSource


class TracePriceSource:
    """Piecewise-constant price history at *irregular* update times —
    the shape of real `describe-spot-price-history` output.

    `times` are seconds (ascending, relative to the market epoch) at
    which the price changed; `prices[i]` holds on [times[i],
    times[i+1]). Outside the recorded horizon the trace clamps: before
    `times[0]` the first price applies, after the last update the final
    price extends indefinitely (mirroring the synthetic source's clamped
    lookup). Integrals are O(log n): prefix sums over the irregular
    segments plus a binary search for the containing segment.
    """

    def __init__(self, times: Sequence[float], prices: Sequence[float]):
        t = np.asarray(times, dtype=np.float64)
        p = np.asarray(prices, dtype=np.float64)
        if t.ndim != 1 or t.shape != p.shape or len(t) == 0:
            raise ValueError("times/prices must be equal-length 1-D, "
                             "non-empty")
        if np.any(np.diff(t) < 0):
            raise ValueError("times must be ascending")
        if np.any(p < 0):
            raise ValueError("negative price in trace")
        self._times = t
        self._prices = p
        # _cum[i] = integral from times[0] up to times[i]
        widths = np.diff(t)
        self._cum = np.concatenate([[0.0],
                                    np.cumsum(self._prices[:-1] * widths)])

    def _index(self, t: float) -> int:
        i = int(np.searchsorted(self._times, t, side="right")) - 1
        return min(max(i, 0), len(self._times) - 1)

    def price(self, t: float) -> float:
        """Price of the recorded segment containing `t` (clamped
        outside the horizon)."""
        return float(self._prices[self._index(t)])

    def _antiderivative(self, t: float) -> float:
        """Integral over [times[0], t]; clamped below times[0]."""
        if t <= self._times[0]:
            # pre-horizon: first price extends backwards
            return float(self._prices[0] * (t - self._times[0]))
        i = self._index(t)
        return float(self._cum[i]
                     + self._prices[i] * (t - self._times[i]))

    def integral(self, t0: float, t1: float) -> float:
        """Integral of the price over [t0, t1] in $·s/hr, O(log n)."""
        if t1 <= t0:
            return 0.0
        return self._antiderivative(t1) - self._antiderivative(t0)

    def _antiderivative_batch(self, t: np.ndarray) -> np.ndarray:
        """Vectorized `_antiderivative`: one `searchsorted` over the
        whole batch instead of a Python call per billing segment."""
        i = np.clip(np.searchsorted(self._times, t, side="right") - 1,
                    0, len(self._times) - 1)
        out = self._cum[i] + self._prices[i] * (t - self._times[i])
        # pre-horizon clamp: the first price extends backwards
        pre = t <= self._times[0]
        return np.where(pre, self._prices[0] * (t - self._times[0]), out)

    def integral_batch(self, t0: np.ndarray, t1: np.ndarray) -> np.ndarray:
        """Elementwise `integral` over aligned time arrays ($·s/hr)."""
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.asarray(t1, dtype=np.float64)
        out = self._antiderivative_batch(t1) - self._antiderivative_batch(t0)
        return np.where(t1 <= t0, 0.0, out)

    def prices_at(self, t: np.ndarray) -> np.ndarray:
        """Vectorized `price` lookup (per-step hazard batching)."""
        i = np.clip(np.searchsorted(self._times,
                                    np.asarray(t, dtype=np.float64),
                                    side="right") - 1,
                    0, len(self._times) - 1)
        return self._prices[i]

    @property
    def horizon(self) -> Tuple[float, float]:
        """(first, last) recorded update times of the trace."""
        return float(self._times[0]), float(self._times[-1])


# ---------------------------------------------------------------------------
# The market facade.
# ---------------------------------------------------------------------------
_REGIONS = ("us-east-1", "us-east-2", "us-west-2", "eu-west-1")


class SpotMarket:
    """All providers' zones, prices and billing semantics; cross-provider
    cheapest-zone arbitration.

    Zone registration order is the arbitration tie-break: `cheapest_zone`
    scans zones in registration order and keeps the strictly cheapest,
    so equal prices resolve to the first-registered zone (provider
    config order, then zone index). That rule is deterministic across
    runs and preserves the pre-redesign single-provider behavior
    exactly.
    """

    def __init__(self, providers: Optional[Iterable[Provider]] = None):
        self.providers: Dict[str, Provider] = {}
        self.zones: List[Zone] = []
        self._sources: Dict[Tuple[str, str], PriceSource] = {}
        self._zone_owner: Dict[str, str] = {}   # zone name -> first owner
        # recorded real interruption timestamps per (provider, zone),
        # seconds on the market clock, ascending — consumed by the
        # replay preemption model (repro.cloud.preemption)
        self.interruptions: Dict[Tuple[str, str], Tuple[float, ...]] = {}
        for p in providers or ():
            self.add_provider(p)

    # ------------------------------------------------------------------
    # Construction.
    # ------------------------------------------------------------------
    def add_provider(self, provider: Provider) -> Provider:
        """Register a provider; the first registered one is the
        market's default. Duplicate names raise."""
        if provider.name in self.providers:
            raise ValueError(f"provider {provider.name!r} already "
                             f"registered")
        self.providers[provider.name] = provider
        return provider

    def add_zone(self, zone: Zone, source: PriceSource) -> Zone:
        """Register a zone and its price source under an
        already-registered provider; registration order is the
        cheapest-zone tie-break order."""
        if zone.provider not in self.providers:
            raise ValueError(f"unknown provider {zone.provider!r} for "
                             f"zone {zone.name!r}")
        key = (zone.provider, zone.name)
        if key in self._sources:
            raise ValueError(f"zone {key} already registered")
        self.zones.append(zone)
        self._sources[key] = source
        self._zone_owner.setdefault(zone.name, zone.provider)
        return zone

    def add_interruptions(self, provider: str, zone: str,
                          times: Sequence[float]) -> None:
        """Attach recorded interruption timestamps (market-clock
        seconds, any order) to one provider's zone for the replay
        preemption model."""
        if provider not in self.providers:
            raise ValueError(f"unknown provider {provider!r}")
        self.interruptions[(provider, zone)] = tuple(sorted(times))

    def replace_source(self, zone: str, source: PriceSource,
                       provider: Optional[str] = None) -> None:
        """Swap an already-registered zone's price source in place
        (registration order, and therefore cheapest-zone tie-breaking,
        is unchanged). The scenario generators (`cloud.scenarios`)
        reshape markets through this hook."""
        key = (self.resolve_provider(zone, provider), zone)
        if key not in self._sources:
            raise ValueError(f"zone {key} not registered")
        self._sources[key] = source

    @property
    def default_provider(self) -> str:
        """Name of the first-registered provider."""
        return next(iter(self.providers))

    @classmethod
    def synthetic(cls, cfg: CloudConfig, seed: int = 0) -> "SpotMarket":
        """The default single-provider market: bit-identical traces to
        the pre-redesign `PriceBook(cfg, seed)`."""
        m = cls([Provider.from_cloud_config(cfg)])
        m._add_synthetic_zones(m.providers[DEFAULT_PROVIDER],
                               cfg.spot_rate_mean, cfg.spot_rate_sigma,
                               cfg.on_demand_rate, cfg.n_zones,
                               _REGIONS, seed)
        return m

    def _add_synthetic_zones(self, provider: Provider, mean: float,
                             sigma: float, on_demand: float, n_zones: int,
                             regions: Sequence[str], seed: int):
        for i in range(n_zones):
            region = regions[i % len(regions)]
            z = Zone(f"{region}{chr(ord('a') + i // len(regions))}",
                     region, provider.name)
            # zone-specific mean wiggle so zones genuinely differ
            zmean = mean * (1.0 + 0.02 * ((i % 3) - 1))
            self.add_zone(z, SyntheticOUSource(zmean, sigma, on_demand,
                                               seed=seed + i))

    @classmethod
    def from_market_config(cls, mcfg: MarketConfig,
                           seed: int = 0) -> "SpotMarket":
        """Build a (possibly multi-provider) market. Providers with a
        `price_trace` path get trace-driven zones (cloud.traces); the
        rest synthesize OU zones off a provider-indexed seed. Providers
        with an `interruption_trace` additionally register recorded
        interruption timestamps for the replay preemption model, on the
        same market clock as the price histories."""
        from repro.cloud.traces import (build_interruption_schedule,
                                        build_zone_sources,
                                        parse_interruption_file,
                                        parse_price_file)
        m = cls()
        # parse each history file once; every trace-driven provider then
        # shares one market epoch so their histories stay aligned on the
        # simulated clock
        parsed = {pc.name: parse_price_file(pc.price_trace)
                  for pc in mcfg.providers if pc.price_trace is not None}
        interruptions = {pc.name: parse_interruption_file(
                             pc.interruption_trace)
                         for pc in mcfg.providers
                         if pc.interruption_trace is not None}
        stamps = ([r.timestamp for recs in parsed.values() for r in recs]
                  or [r.timestamp for recs in interruptions.values()
                      for r in recs])
        epoch = min(stamps) if stamps else None
        for pi, pc in enumerate(mcfg.providers):
            prov = m.add_provider(Provider.from_provider_config(pc))
            if pc.price_trace is not None:
                for zone, source in build_zone_sources(
                        parsed[pc.name], provider=pc.name, epoch=epoch):
                    m.add_zone(zone, source)
            else:
                m._add_synthetic_zones(
                    prov, pc.spot_rate_mean, pc.spot_rate_sigma,
                    pc.on_demand_rate, pc.n_zones, pc.regions,
                    seed + 1000 * pi)
            if pc.name in interruptions:
                for zone_name, times in build_interruption_schedule(
                        interruptions[pc.name], epoch=epoch).items():
                    m.add_interruptions(pc.name, zone_name, times)
        if mcfg.scenario is not None:
            # lazy import: scenarios build on this module's sources
            from repro.cloud.scenarios import apply_scenario
            apply_scenario(m, mcfg.scenario)
        return m

    @classmethod
    def for_cloud_config(cls, cfg: CloudConfig,
                         seed: int = 0) -> "SpotMarket":
        """The market a `CloudConfig` describes: its explicit
        `MarketConfig` when set, else the legacy scalar fields as a
        single synthetic provider."""
        if cfg.market is not None:
            return cls.from_market_config(cfg.market, seed=seed)
        return cls.synthetic(cfg, seed=seed)

    # ------------------------------------------------------------------
    # Lookups.
    # ------------------------------------------------------------------
    def provider_of(self, name: Optional[str]) -> Provider:
        """The named provider's descriptor (None -> the default)."""
        return self.providers[name or self.default_provider]

    def resolve_provider(self, zone: Optional[str] = None,
                         provider: Optional[str] = None) -> str:
        """Which provider a lookup means: the explicit `provider` when
        given, else the (first-registered) owner of `zone`, else the
        default provider — so a pinned zone name alone is enough to
        reach the right provider's prices and billing rules."""
        if provider is not None:
            return provider
        if zone is not None and zone in self._zone_owner:
            return self._zone_owner[zone]
        return self.default_provider

    def source(self, zone: str,
               provider: Optional[str] = None) -> PriceSource:
        """The zone's price source (provider resolved per
        `resolve_provider`)."""
        return self._sources[(self.resolve_provider(zone, provider),
                              zone)]

    def spot_price(self, zone: str, t: float,
                   provider: Optional[str] = None) -> float:
        """Spot price ($/hr) of a zone at time `t`."""
        return self.source(zone, provider).price(t)

    def on_demand_price(self, zone: str, t: float,
                        provider: Optional[str] = None) -> float:
        """On-demand rate ($/hr) of the zone's provider (flat in t)."""
        return self.provider_of(
            self.resolve_provider(zone, provider)).on_demand_rate

    def price(self, zone: str, t: float, on_demand: bool,
              provider: Optional[str] = None) -> float:
        """`on_demand_price` or `spot_price`, by market kind."""
        return (self.on_demand_price(zone, t, provider) if on_demand
                else self.spot_price(zone, t, provider))

    def cheapest_zone(self, t: float,
                      allowed: Optional[List[str]] = None,
                      providers: Optional[Sequence[str]] = None,
                      ) -> Tuple[Zone, float]:
        """Cheapest spot placement at `t` across `providers` (default:
        every registered provider), optionally restricted to `allowed`
        zone names. Ties break to the first-registered zone."""
        best: Optional[Zone] = None
        best_p = float("inf")
        for z in self.zones:
            if providers is not None and z.provider not in providers:
                continue
            if allowed is not None and z.name not in allowed:
                continue
            p = self.spot_price(z.name, t, z.provider)
            if p < best_p:                  # strict: first-lowest wins
                best, best_p = z, p
        if best is None:
            raise ValueError("no zone matches the placement constraints")
        return best, best_p

    def cost(self, zone: str, t0: float, t1: float, on_demand: bool,
             provider: Optional[str] = None) -> float:
        """Dollars accrued over [t0, t1] (per-second billing)."""
        if on_demand:
            rate = self.on_demand_price(zone, t0, provider)
            return rate * max(t1 - t0, 0.0) / 3600.0
        return self.source(zone, provider).integral(t0, t1) / 3600.0

    def cost_batch(self, zone: str, t0s: np.ndarray, t1s: np.ndarray,
                   on_demand: bool,
                   provider: Optional[str] = None) -> np.ndarray:
        """Vectorized `cost` over aligned segment arrays for one zone —
        the fleet core settles a whole step's billing segments with two
        prefix-sum lookups instead of a Python call per instance. Falls
        back to a scalar loop for custom `PriceSource` implementations
        without `integral_batch`."""
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        if on_demand:
            rate = self.on_demand_price(zone, 0.0, provider)
            return rate * np.maximum(t1s - t0s, 0.0) / 3600.0
        src = self.source(zone, provider)
        batch = getattr(src, "integral_batch", None)
        if batch is not None:
            return batch(t0s, t1s) / 3600.0
        return np.array([src.integral(a, b) / 3600.0
                         for a, b in zip(t0s, t1s)])

    def mean_spot_price(self, zone: str,
                        provider: Optional[str] = None) -> float:
        """Time-averaged spot price of a zone over its recorded horizon
        (trace sources) or the synthetic 7-day horizon — the reference
        level the price-coupled preemption model measures spikes
        against."""
        src = self.source(zone, provider)
        horizon = getattr(src, "horizon", None)
        if horizon is not None and horizon[1] > horizon[0]:
            t0, t1 = horizon
        else:
            t0, t1 = 0.0, 7 * 86400.0
        mean = src.integral(t0, t1) / (t1 - t0)
        return mean if mean > 0.0 else src.price(t0)


def PriceBook(cfg: CloudConfig, seed: int = 0) -> SpotMarket:
    """Pre-redesign constructor: the single-provider synthetic market."""
    return SpotMarket.synthetic(cfg, seed=seed)
