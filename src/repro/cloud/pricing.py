"""Spot / on-demand pricing across regions and availability zones.

The paper (§III-A "Dynamic Resource Allocation") queries real-time spot
prices across regions/zones and picks the cheapest. Here prices are
simulated as per-zone piecewise-constant mean-reverting traces calibrated
to the paper's observed g5.xlarge rates (on-demand $1.008/hr, spot
≈ $0.3951/hr, Table I).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.common.config import CloudConfig


@dataclasses.dataclass(frozen=True)
class Zone:
    name: str          # e.g. "us-east-1a"
    region: str        # e.g. "us-east-1"


class SpotPriceTrace:
    """Piecewise-constant mean-reverting price process for one zone.

    AWS publishes spot price updates at irregular intervals (minutes to
    hours); we model hourly steps of an OU-like process clipped to
    [0.25, 1.0] x on-demand.
    """

    def __init__(self, mean: float, sigma: float, on_demand: float,
                 seed: int, step_s: float = 3600.0, horizon_s: float = 7 * 86400.0,
                 reversion: float = 0.2):
        rng = np.random.RandomState(seed)
        n = int(horizon_s / step_s) + 2
        prices = np.empty(n)
        p = mean + rng.randn() * sigma
        for i in range(n):
            prices[i] = np.clip(p, 0.25 * on_demand, 1.0 * on_demand)
            p = p + reversion * (mean - p) + rng.randn() * sigma
        self._step = step_s
        self._prices = prices
        # prefix sums: _cum[i] = integral over the first i full steps,
        # making `integral` O(1) instead of O(steps spanned) — it sits on
        # the billing hot path (every cost query prices an open segment).
        self._cum = np.concatenate([[0.0], np.cumsum(prices) * step_s])

    def price(self, t: float) -> float:
        i = min(int(t / self._step), len(self._prices) - 1)
        return float(self._prices[i])

    def _antiderivative(self, t: float) -> float:
        """Integral of the trace over [0, t]; beyond the horizon the last
        step's price extends (matching `price`'s clamped lookup)."""
        i = min(int(t / self._step), len(self._prices) - 1)
        return float(self._cum[i]
                     + self._prices[i] * (t - i * self._step))

    def integral(self, t0: float, t1: float) -> float:
        """Integral of price over [t0, t1] in $·s/hr (divide by 3600 for $)."""
        if t1 <= t0:
            return 0.0
        return self._antiderivative(t1) - self._antiderivative(t0)


class PriceBook:
    """All zones' prices + on-demand rate; cheapest-zone queries."""

    def __init__(self, cfg: CloudConfig, seed: int = 0):
        self.cfg = cfg
        self.zones: List[Zone] = []
        self._traces: Dict[str, SpotPriceTrace] = {}
        regions = ("us-east-1", "us-east-2", "us-west-2", "eu-west-1")
        for i in range(cfg.n_zones):
            region = regions[i % len(regions)]
            z = Zone(f"{region}{chr(ord('a') + i // len(regions))}", region)
            self.zones.append(z)
            # zone-specific mean wiggle so zones genuinely differ
            mean = cfg.spot_rate_mean * (1.0 + 0.02 * ((i % 3) - 1))
            self._traces[z.name] = SpotPriceTrace(
                mean, cfg.spot_rate_sigma, cfg.on_demand_rate, seed=seed + i)

    def spot_price(self, zone: str, t: float) -> float:
        return self._traces[zone].price(t)

    def on_demand_price(self, zone: str, t: float) -> float:
        return self.cfg.on_demand_rate

    def price(self, zone: str, t: float, on_demand: bool) -> float:
        return (self.on_demand_price(zone, t) if on_demand
                else self.spot_price(zone, t))

    def cheapest_zone(self, t: float,
                      allowed: Optional[List[str]] = None) -> Tuple[str, float]:
        names = allowed or [z.name for z in self.zones]
        best = min(names, key=lambda z: self.spot_price(z, t))
        return best, self.spot_price(best, t)

    def cost(self, zone: str, t0: float, t1: float, on_demand: bool) -> float:
        """Dollars accrued over [t0, t1] (per-second billing)."""
        if on_demand:
            return self.cfg.on_demand_rate * max(t1 - t0, 0.0) / 3600.0
        return self._traces[zone].integral(t0, t1) / 3600.0
