"""Incremental per-client cost accounting driven by billing events.

The seed implementation answered every `client_cost` query with a full
scan over *all instances ever created* (O(n) per query, O(n^2) across a
run's cost-curve recording). `CostAccountant` subscribes to the event
bus and folds each closed billing segment into per-client totals as it
happens, so `client_cost` / `total_cost` only have to price the (at most
one per client) still-open billing segment:

  closed cost  — accumulated from `BillingTick` events, O(1) amortized
  open segment — priced on demand from the instance's billing start to
                 `clock()`; there are at most O(#clients) open segments
                 alive at any instant, independent of run length.

`benchmarks/accounting_bench.py` measures the gap at 100 clients x 200
rounds.

The accountant is also a *pure replay consumer*: constructed with no
price book / clock and subscribed to a bus fed by
`core.eventlog.EventReplayer`, it rebuilds the exact per-client totals
of the recorded run from the closed `BillingTick`s alone (a complete
trace terminates every instance, so no open segment is ever priced).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, Optional, Sequence, Set

import numpy as np

from repro.core.events import (BillingTick, CheckpointBilled,
                               ClientCheckpointed, ClientUpdateSent,
                               EventBus, FleetStepSummary,
                               InstancePreempted, InstanceReady,
                               InstanceTerminated, TransferBilled)
from repro.cloud.pricing import SpotMarket


class CostAccountant:
    """Per-client dollar totals as a bus consumer: O(1) amortized
    folding of closed `BillingTick` segments plus on-demand pricing of
    the open ones. Pass `prices=None` (no clock) for replay mode.

    Warning-window checkpoint writes are billed too (ROADMAP
    "checkpoint-aware cost model"): on `ClientCheckpointed` the live
    accountant prices the write against the client's provider's
    `StorageRates` (S3 PUT + per-MB egress of the snapshot's
    `size_mb`) and publishes the charge as `CheckpointBilled`, whose
    handler folds it into the totals — so a replayed stream rebuilds
    the exact same checkpoint spend without a price book. Default
    rates are zero: checkpoint dollars only appear when a market opts
    in, keeping every pre-redesign total unchanged."""

    def __init__(self, bus: EventBus, prices: Optional[SpotMarket] = None,
                 clock: Optional[Callable[[], float]] = None):
        self._bus = bus
        self._prices = prices
        self._clock = clock
        self._closed: Dict[str, float] = defaultdict(float)
        self._closed_total = 0.0
        self._ckpt: Dict[str, float] = defaultdict(float)
        self._ckpt_total = 0.0
        self._xfer: Dict[str, float] = defaultdict(float)
        self._xfer_total = 0.0
        self._open: Dict[int, object] = {}          # iid -> Instance
        self._open_by_client: Dict[str, Set[int]] = defaultdict(set)
        # fleet-step dollars folded into the total without per-client
        # attribution (pre-v6 logs whose summaries carry no
        # `client_cost_delta`); nonzero means `per_client()` is not the
        # whole story — see `has_client_costs`
        self.fleet_unattributed = 0.0
        bus.subscribe(InstanceReady, self._on_ready)
        bus.subscribe(BillingTick, self._on_billing)
        bus.subscribe(InstanceTerminated, self._on_closed)
        bus.subscribe(InstancePreempted, self._on_closed)
        bus.subscribe(ClientCheckpointed, self._on_checkpointed)
        bus.subscribe(CheckpointBilled, self._on_checkpoint_billed)
        bus.subscribe(ClientUpdateSent, self._on_update_sent)
        bus.subscribe(TransferBilled, self._on_transfer_billed)
        bus.subscribe(FleetStepSummary, self._on_fleet_step)

    # ------------------------------------------------------------------
    # Event handlers.
    # ------------------------------------------------------------------
    def _on_ready(self, ev: InstanceReady):
        inst = ev.instance
        self._open[inst.iid] = inst
        self._open_by_client[inst.client].add(inst.iid)

    def _on_billing(self, ev: BillingTick):
        self._closed[ev.client] += ev.amount
        self._closed_total += ev.amount
        self._drop_open(ev.instance)

    def _on_closed(self, ev):
        # terminated-while-spinning instances never opened a segment;
        # terminate/preempt after RUNNING already closed via BillingTick.
        self._drop_open(ev.instance)

    def _drop_open(self, inst):
        if self._open.pop(inst.iid, None) is not None:
            self._open_by_client[inst.client].discard(inst.iid)

    def _on_checkpointed(self, ev: ClientCheckpointed):
        """Live mode: price the checkpoint write against the storage
        rates of the provider that wrote it (stamped on the event by
        the executor), and publish the (non-zero) charge as
        `CheckpointBilled`. Replay mode skips this — the recorded
        `CheckpointBilled` carries the charge."""
        if self._prices is None:
            return
        rates = self._prices.provider_of(ev.provider or None).storage
        amount = rates.checkpoint_cost(ev.size_mb)
        if amount > 0.0:
            self._bus.publish(CheckpointBilled(ev.t, ev.client, amount))

    def _on_checkpoint_billed(self, ev: CheckpointBilled):
        """Fold one checkpoint's storage dollars into the totals (live
        and replay alike)."""
        self._ckpt[ev.client] += ev.amount
        self._ckpt_total += ev.amount

    def _on_update_sent(self, ev: ClientUpdateSent):
        """Live mode: price one client-update upload's egress against
        the sending provider's `TransferRates` and publish the
        (non-zero) charge as `TransferBilled` — the same live/replay
        split as checkpoint billing. Replay mode skips this; the
        recorded `TransferBilled` carries the charge."""
        if self._prices is None:
            return
        rates = self._prices.provider_of(ev.provider or None).transfer
        amount = rates.transfer_cost(ev.size_mb)
        if amount > 0.0:
            self._bus.publish(TransferBilled(ev.t, ev.client, amount))

    def _on_transfer_billed(self, ev: TransferBilled):
        """Fold one upload's egress dollars into the totals (live and
        replay alike)."""
        self._xfer[ev.client] += ev.amount
        self._xfer_total += ev.amount

    def _on_fleet_step(self, ev: FleetStepSummary):
        """Replay mode only: fold one fleet step's *settled* dollars
        (schema v6 aggregate trace). A live fleet run settles the same
        dollars through the fleet core's own arrays, so a live (priced)
        accountant ignores the summary — folding both would double
        count. The step total folds from `cost_delta`; per-client
        attribution folds from `client_cost_delta` (v6), whose values
        sum to `cost_delta` — it must not be added to the total again.
        A pre-v6 summary carries no attribution map: those dollars are
        tracked as *unattributed* so consumers (`replay_result`) can
        flag the per-client breakdown as absent instead of silently
        reporting every client as free (the schema-v5 bug)."""
        if self._prices is not None:
            return
        self._closed_total += ev.cost_delta
        if ev.client_cost_delta:
            for c, a in ev.client_cost_delta.items():
                self._closed[c] += a
        else:
            self.fleet_unattributed += ev.cost_delta

    # ------------------------------------------------------------------
    # Batched settlement (the fleet core's path into the same totals).
    # ------------------------------------------------------------------
    def settle_batch(self, clients: Sequence[str],
                     amounts: np.ndarray) -> float:
        """Fold a whole step's closed billing segments at once:
        `amounts[i]` dollars settle for `clients[i]`. Per-client dict
        updates are grouped with `np.unique`/`np.bincount`, so the
        Python-level work is O(distinct clients), not O(segments).
        Returns the total settled."""
        amounts = np.asarray(amounts, dtype=np.float64)
        if len(amounts) == 0:
            return 0.0
        uniq, inv = np.unique(np.asarray(clients, dtype=object),
                              return_inverse=True)
        sums = np.bincount(inv, weights=amounts, minlength=len(uniq))
        for c, a in zip(uniq, sums):
            self._closed[c] += float(a)
        total = float(amounts.sum())
        self._closed_total += total
        return total

    # ------------------------------------------------------------------
    # Queries.
    # ------------------------------------------------------------------
    def _open_cost(self, inst) -> float:
        t0 = inst._billing_from
        if t0 is None or self._prices is None:
            return 0.0          # closed, or replay mode (always closed)
        return self._prices.cost(inst.zone, t0, self._clock(),
                                 inst.on_demand,
                                 provider=getattr(inst, "provider", None))

    def client_cost(self, client: str) -> float:
        """Dollars accrued by `client` so far: open segments,
        checkpoint storage and update egress included."""
        return (self._closed[client] + self._ckpt[client]
                + self._xfer[client]
                + sum(self._open_cost(self._open[i])
                      for i in self._open_by_client[client]))

    def total_cost(self) -> float:
        """Dollars accrued by the whole run so far."""
        return (self._closed_total + self._ckpt_total + self._xfer_total
                + sum(self._open_cost(i) for i in self._open.values()))

    def checkpoint_cost(self, client: str) -> float:
        """Storage dollars `client`'s warning-window checkpoint writes
        have accrued (a subset of `client_cost`)."""
        return self._ckpt[client]

    def checkpoint_cost_total(self) -> float:
        """Storage dollars all checkpoint writes have accrued (a
        subset of `total_cost`)."""
        return self._ckpt_total

    def transfer_cost(self, client: str) -> float:
        """Egress dollars `client`'s update uploads have accrued (a
        subset of `client_cost`)."""
        return self._xfer[client]

    def transfer_cost_total(self) -> float:
        """Egress dollars all update uploads have accrued (a subset
        of `total_cost`)."""
        return self._xfer_total

    def per_client(self) -> Dict[str, float]:
        """`client_cost` for every client ever billed or running."""
        clients = (set(self._closed) | set(self._open_by_client)
                   | set(self._xfer))
        return {c: self.client_cost(c) for c in clients}

    def has_client_costs(self, tiny: float = 1e-12) -> bool:
        """Whether `per_client()` accounts for every folded dollar.
        False when fleet-step summaries without per-client attribution
        (pre-v6 logs) contributed to the total — the breakdown is then
        absent, not zero."""
        return self.fleet_unattributed <= tiny
