"""Cost reporting, reconciliation and pre-launch budget screening over
recorded event logs (`python -m repro.cloud.report`, docs/reporting.md).

The paper's pitch is FL for budget-constrained institutions, yet every
dollar of a run lives in a `.events.jsonl` stream that only tests
replay. This module is the human-facing answer to "where did the money
go?" — four subcommands, all pure replay consumers over
`core.eventlog` (zero engine or simulator involvement, mirroring the
Multi-FedLS record-then-audit discipline):

  summary    per-client / per-provider / per-zone spend split into
             compute, checkpoint-storage and update-egress categories,
             plus idle-time, preemption and lost-work columns rebuilt
             from the recorded Fig-4 state stream; `--per-round` adds
             dollars bucketed by the round window open at settlement
             time (RoundStarted -> RoundCompleted)
  trends     cost / makespan / preemption trajectories across every
             trace in a directory (deterministic sorted-key JSON or a
             CSV-style table)
  reconcile  the audit primitive: assert the run total equals
             per-client compute + checkpoint + egress +
             fleet-unattributed dollars to a tolerance, and on failure
             report the delta and the *first divergent event*
  validate   pre-launch budget screening (§III-E applied before the
             run exists): estimate the run's cost from client epoch
             times — given directly or derived from roofline FLOP /
             byte counts — and current `SpotMarket` prices, refuse
             over-budget launches and suggest the cheapest zone

Every output is byte-deterministic (sorted keys, fixed float formats,
no timestamps): CI runs the CLI twice and diffs the bytes.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from collections import defaultdict
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.events import (BillingTick, CheckpointBilled,
                               ClientCheckpointed, ClientLost,
                               ClientStateChanged, ClientUpdateSent,
                               EventBus, FleetStepSummary, RoundCompleted,
                               RoundStarted, RunCompleted, TransferBilled)
from repro.core.eventlog import iter_events, read_header

# the provider every legacy single-provider log implicitly ran on
# (InstanceRef's decode default): used when an event predates provider
# stamping and carries an empty string
_FALLBACK_PROVIDER = "aws"

# the reconciliation invariant's tolerance (dollars)
RECONCILE_TOL = 1e-9


# ---------------------------------------------------------------------------
# summary — category breakdowns from one stream walk.
# ---------------------------------------------------------------------------
def summarize_path(path: Union[str, Path]) -> Dict[str, Any]:
    """One trace's full spend breakdown as a JSON-ready dict.

    A single pass over the recorded events attributes every settled
    dollar to (client, provider, zone) x (compute | checkpoint |
    egress):

      * `BillingTick` — compute dollars, attributed via the instance
        snapshot's client / provider / zone;
      * `CheckpointBilled` — checkpoint-storage dollars; the provider
        comes from the client's preceding `ClientCheckpointed` (the
        live accountant publishes the charge nested inside that event,
        so it directly follows it in every recorded stream);
      * `TransferBilled` — update-egress dollars; provider / zone from
        the client's preceding `ClientUpdateSent`, same nesting;
      * `FleetStepSummary` — the fleet path's aggregate settlements:
        per-client compute from `client_cost_delta`, per-zone compute
        from `by_zone`, and pre-v6 summaries (no attribution map) into
        `fleet_unattributed`.

    Idle seconds fold from the `ClientStateChanged` stream and
    `lost_work_s` estimates preemption-interrupted training time (the
    elapsed training segment at each `ClientLost`, an upper bound that
    ignores checkpoint credit — replayed `RunResult.lost_work_s` is
    live-only and stays 0). The category totals are the reconciliation
    invariant's parts: tests pin them to the replayed
    `RunResult.{total,checkpoint,comm}_cost` to 1e-9.
    """
    path = Path(path)
    header = read_header(path)
    compute: Dict[str, float] = defaultdict(float)
    ckpt: Dict[str, float] = defaultdict(float)
    egress: Dict[str, float] = defaultdict(float)
    prov: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"compute": 0.0, "checkpoint": 0.0, "egress": 0.0})
    zone: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"compute": 0.0, "egress": 0.0})
    preempt: Dict[str, int] = defaultdict(int)
    lost: Dict[str, float] = defaultdict(float)
    state_s: Dict[Tuple[str, str], float] = defaultdict(float)
    cur_state: Dict[str, Tuple[str, float]] = {}
    last_ckpt_prov: Dict[str, str] = {}
    last_sent: Dict[str, Tuple[str, str]] = {}
    fleet_unattributed = 0.0
    fleet_preemptions = 0
    done: Optional[RunCompleted] = None

    def close_state(client: str, t: float) -> None:
        st = cur_state.pop(client, None)
        if st is not None:
            state_s[(client, st[0])] += t - st[1]

    for ev in iter_events(path):
        if isinstance(ev, BillingTick):
            inst = ev.instance
            p = getattr(inst, "provider", "") or _FALLBACK_PROVIDER
            compute[ev.client] += ev.amount
            prov[p]["compute"] += ev.amount
            zone[f"{p}/{inst.zone}"]["compute"] += ev.amount
        elif isinstance(ev, ClientCheckpointed):
            last_ckpt_prov[ev.client] = ev.provider or _FALLBACK_PROVIDER
        elif isinstance(ev, CheckpointBilled):
            p = last_ckpt_prov.get(ev.client, _FALLBACK_PROVIDER)
            ckpt[ev.client] += ev.amount
            prov[p]["checkpoint"] += ev.amount
        elif isinstance(ev, ClientUpdateSent):
            last_sent[ev.client] = (ev.provider or _FALLBACK_PROVIDER,
                                    ev.zone)
        elif isinstance(ev, TransferBilled):
            p, z = last_sent.get(ev.client, (_FALLBACK_PROVIDER, ""))
            egress[ev.client] += ev.amount
            prov[p]["egress"] += ev.amount
            if z:
                zone[f"{p}/{z}"]["egress"] += ev.amount
        elif isinstance(ev, FleetStepSummary):
            if ev.client_cost_delta:
                for c, a in ev.client_cost_delta.items():
                    compute[c] += a
            else:
                fleet_unattributed += ev.cost_delta
            for zkey, aggs in ev.by_zone.items():
                amount = aggs.get("cost", 0.0)
                zone[zkey]["compute"] += amount
                prov[zkey.split("/", 1)[0]]["compute"] += amount
            fleet_preemptions += ev.n_preemptions
        elif isinstance(ev, ClientLost):
            preempt[ev.client] += 1
            st = cur_state.get(ev.client)
            if st is not None and st[0] == "training":
                lost[ev.client] += ev.t - st[1]
        elif isinstance(ev, ClientStateChanged):
            close_state(ev.client, ev.t)
            if ev.state != "done":
                cur_state[ev.client] = (ev.state, ev.t)
        elif isinstance(ev, RunCompleted):
            done = ev
    if done is None:
        raise ValueError(f"{path}: event log has no RunCompleted "
                         f"summary (truncated recording?)")
    for c in list(cur_state):
        close_state(c, done.t)

    clients = sorted(set(compute) | set(ckpt) | set(egress))
    per_client = {
        c: {"compute": compute[c], "checkpoint": ckpt[c],
            "egress": egress[c],
            "total": compute[c] + ckpt[c] + egress[c],
            "idle_s": state_s.get((c, "idle"), 0.0),
            "preemptions": preempt[c], "lost_work_s": lost[c]}
        for c in clients}
    totals = {
        "compute": sum(compute.values()),
        "checkpoint": sum(ckpt.values()),
        "egress": sum(egress.values()),
        "fleet_unattributed": fleet_unattributed,
        "total": (sum(compute.values()) + sum(ckpt.values())
                  + sum(egress.values()) + fleet_unattributed),
        "makespan_s": done.makespan_s,
        "rounds": done.rounds_completed,
        "preemptions": sum(preempt.values()) + fleet_preemptions,
        "lost_work_s": sum(lost.values()),
    }
    return {"trace": path.name,
            "dataset": header.get("dataset"),
            "policy": header.get("policy"),
            "seed": header.get("seed"),
            "schema": header["schema"],
            "totals": totals,
            "per_client": per_client,
            "per_provider": {p: dict(v) for p, v in sorted(prov.items())},
            "per_zone": {z: dict(v) for z, v in sorted(zone.items())}}


def render_summary(payload: Dict[str, Any]) -> str:
    """The `summary` table for one trace: header comments, then one
    CSV block per breakdown (client / provider / zone). Fixed float
    formats keep the bytes deterministic across runs."""
    t = payload["totals"]
    lines = [
        f"# {payload['trace']}: dataset={payload['dataset']}, "
        f"policy={payload['policy']}, seed={payload['seed']}, "
        f"schema={payload['schema']}",
        f"# total ${t['total']:.6f} = compute ${t['compute']:.6f} + "
        f"checkpoint ${t['checkpoint']:.6f} + egress ${t['egress']:.6f}"
        f" + fleet-unattributed ${t['fleet_unattributed']:.6f}",
        f"# makespan {t['makespan_s'] / 3600:.3f} h, "
        f"rounds {t['rounds']}, preemptions {t['preemptions']}, "
        f"lost-work {t['lost_work_s']:.1f} s",
        "client,compute_usd,checkpoint_usd,egress_usd,total_usd,"
        "idle_s,preemptions,lost_work_s",
    ]
    for c, row in sorted(payload["per_client"].items()):
        lines.append(
            f"{c},{row['compute']:.6f},{row['checkpoint']:.6f},"
            f"{row['egress']:.6f},{row['total']:.6f},"
            f"{row['idle_s']:.1f},{row['preemptions']},"
            f"{row['lost_work_s']:.1f}")
    lines.append("provider,compute_usd,checkpoint_usd,egress_usd")
    for p, row in payload["per_provider"].items():
        lines.append(f"{p},{row['compute']:.6f},"
                     f"{row['checkpoint']:.6f},{row['egress']:.6f}")
    lines.append("zone,compute_usd,egress_usd")
    for z, row in payload["per_zone"].items():
        lines.append(f"{z},{row['compute']:.6f},{row['egress']:.6f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# per-round attribution — which round the money settled in.
# ---------------------------------------------------------------------------
def per_round_rows(path: Union[str, Path]) -> List[Dict[str, Any]]:
    """Per-round cost attribution: every settled dollar bucketed by
    the round window open at its settlement time.

    A `RoundStarted` opens round `round_idx`; its `RoundCompleted`
    closes it. Settlements (`BillingTick`, `CheckpointBilled`,
    `TransferBilled`, fleet `FleetStepSummary.cost_delta`) landing
    between the two attribute to that round. Under the async engines
    round windows overlap — a settlement inside several open windows
    attributes to the *most recently started* one (the round the money
    is actually buying progress for). Settlements outside every window
    — the initial spin-up before round 0 and the tail after the last
    aggregation — land in the `"-"` row, so the rows always sum back
    to the trace total (the `summary` reconciliation invariant holds
    per-round too).
    """
    path = Path(path)
    open_rounds: List[int] = []     # stack: most recently started last
    acc: Dict[Optional[int], Dict[str, float]] = defaultdict(
        lambda: {"compute": 0.0, "checkpoint": 0.0, "egress": 0.0})
    window: Dict[int, Dict[str, Any]] = {}

    def bucket() -> Optional[int]:
        return open_rounds[-1] if open_rounds else None

    for ev in iter_events(path):
        if isinstance(ev, RoundStarted):
            open_rounds.append(ev.round_idx)
            window[ev.round_idx] = {"t_start": ev.t, "t_end": None,
                                    "participants": len(ev.participants)}
        elif isinstance(ev, RoundCompleted):
            if ev.round_idx in open_rounds:
                open_rounds.remove(ev.round_idx)
            w = window.setdefault(
                ev.round_idx,
                {"t_start": ev.t,
                 "participants": len(ev.participants)})
            w["t_end"] = ev.t
        elif isinstance(ev, BillingTick):
            acc[bucket()]["compute"] += ev.amount
        elif isinstance(ev, CheckpointBilled):
            acc[bucket()]["checkpoint"] += ev.amount
        elif isinstance(ev, TransferBilled):
            acc[bucket()]["egress"] += ev.amount
        elif isinstance(ev, FleetStepSummary):
            acc[bucket()]["compute"] += ev.cost_delta

    rows: List[Dict[str, Any]] = []
    for idx in sorted(window):
        w, a = window[idx], acc.get(idx) or {
            "compute": 0.0, "checkpoint": 0.0, "egress": 0.0}
        rows.append({
            "round": idx, "t_start_s": w["t_start"],
            "t_end_s": w["t_end"], "participants": w["participants"],
            "compute": a["compute"], "checkpoint": a["checkpoint"],
            "egress": a["egress"],
            "total": a["compute"] + a["checkpoint"] + a["egress"]})
    out = acc.get(None)
    if out is not None:
        rows.append({
            "round": None, "t_start_s": None, "t_end_s": None,
            "participants": 0, "compute": out["compute"],
            "checkpoint": out["checkpoint"], "egress": out["egress"],
            "total": (out["compute"] + out["checkpoint"]
                      + out["egress"])})
    return rows


def render_per_round(trace: str, rows: List[Dict[str, Any]]) -> str:
    """The `summary --per-round` CSV block: one row per round window
    plus the `-` outside-round bucket, fixed float formats (CI diffs
    the bytes)."""
    lines = [f"# per-round attribution: {trace} (dollars by "
             f"settlement-time round window; '-' = outside any round)",
             "round,t_start_s,t_end_s,participants,compute_usd,"
             "checkpoint_usd,egress_usd,total_usd"]
    for r in rows:
        idx = "-" if r["round"] is None else str(r["round"])
        t0 = ("-" if r["t_start_s"] is None
              else f"{r['t_start_s']:.1f}")
        t1 = "-" if r["t_end_s"] is None else f"{r['t_end_s']:.1f}"
        lines.append(
            f"{idx},{t0},{t1},{r['participants']},"
            f"{r['compute']:.6f},{r['checkpoint']:.6f},"
            f"{r['egress']:.6f},{r['total']:.6f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# reconcile — the dollar-exact audit primitive.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Reconciliation:
    """Outcome of auditing one trace against the invariant
    `total == Σ per-client compute + checkpoint + egress +
    fleet_unattributed` (and the recorded `RunCompleted.total_cost`
    against the independent replay fold). `first_divergence` is the
    one-line description of the earliest event at which the folds
    disagreed, None when `ok`."""
    trace: str
    ok: bool
    total: float
    parts: Dict[str, float]
    delta: float
    first_divergence: Optional[str] = None


def reconcile_path(path: Union[str, Path],
                   tol: float = RECONCILE_TOL) -> Reconciliation:
    """Stream one trace through a fresh replay-mode `CostAccountant`
    and assert, after *every* event, that its per-category parts sum
    back to its running total — so a divergence is pinned to the first
    event that introduced it, not discovered at the end. The recorded
    `RunCompleted.total_cost` is additionally checked against the
    independent fold (a tampered or miscomputed summary reconciles as
    a failure at that event)."""
    from repro.cloud.accounting import CostAccountant
    path = Path(path)
    bus = EventBus()
    acct = CostAccountant(bus)

    def parts_sum() -> float:
        per_client_compute = sum(
            acct.client_cost(c) - acct.checkpoint_cost(c)
            - acct.transfer_cost(c) for c in acct.per_client())
        return (per_client_compute + acct.checkpoint_cost_total()
                + acct.transfer_cost_total() + acct.fleet_unattributed)

    first: Optional[str] = None
    saw_summary = False
    for idx, ev in enumerate(iter_events(path)):
        bus.publish(ev)
        saw_summary = saw_summary or isinstance(ev, RunCompleted)
        if first is not None:
            continue
        total = acct.total_cost()
        parts = parts_sum()
        if abs(total - parts) > tol:
            first = (f"event[{idx}] {type(ev).__name__} t={ev.t:.3f}: "
                     f"running total ${total:.9f} vs category sum "
                     f"${parts:.9f}")
        elif isinstance(ev, RunCompleted) and \
                abs(ev.total_cost - total) > tol:
            first = (f"event[{idx}] RunCompleted t={ev.t:.3f}: "
                     f"recorded total ${ev.total_cost:.9f} vs "
                     f"replayed fold ${total:.9f}")

    if first is None and not saw_summary:
        # a cleanly cut log (whole trailing lines removed) parses fine
        # but carries no recorded total to audit against — that is a
        # failed audit, not a passing one
        first = ("no RunCompleted summary event "
                 "(truncated recording?)")
    total = acct.total_cost()
    parts = {
        "per_client_compute": sum(
            acct.client_cost(c) - acct.checkpoint_cost(c)
            - acct.transfer_cost(c) for c in acct.per_client()),
        "checkpoint": acct.checkpoint_cost_total(),
        "egress": acct.transfer_cost_total(),
        "fleet_unattributed": acct.fleet_unattributed,
    }
    delta = total - sum(parts.values())
    ok = abs(delta) <= tol and first is None
    return Reconciliation(trace=path.name, ok=ok, total=total,
                          parts=parts, delta=delta,
                          first_divergence=first)


def render_reconciliation(rec: Reconciliation, tol: float) -> str:
    """One PASS/FAIL line per trace (plus the first divergent event on
    failure) — what the CI smoke step greps."""
    p = rec.parts
    head = (f"# reconcile {rec.trace}: "
            f"{'PASS' if rec.ok else 'FAIL'} "
            f"total ${rec.total:.9f} = compute "
            f"${p['per_client_compute']:.9f} + checkpoint "
            f"${p['checkpoint']:.9f} + egress ${p['egress']:.9f} + "
            f"fleet-unattributed ${p['fleet_unattributed']:.9f} "
            f"(delta {rec.delta:.3e}, tol {tol:.0e})")
    if rec.first_divergence is not None:
        head += f"\n#   first divergent {rec.first_divergence}"
    return head


# ---------------------------------------------------------------------------
# trends — trajectories across a directory of recorded runs.
# ---------------------------------------------------------------------------
def trend_rows(directory: Union[str, Path]) -> List[Dict[str, Any]]:
    """One row per `*.events.jsonl` under `directory` (sorted by file
    name, so output order is deterministic): run identity from the
    header plus replayed cost / makespan / preemption aggregates."""
    directory = Path(directory)
    paths = sorted(directory.glob("*.events.jsonl"))
    if not paths:
        raise ValueError(f"{directory}: no *.events.jsonl traces found")
    rows = []
    for p in paths:
        s = summarize_path(p)
        t = s["totals"]
        rows.append({
            "trace": s["trace"], "dataset": s["dataset"],
            "policy": s["policy"], "seed": s["seed"],
            "schema": s["schema"], "total_usd": t["total"],
            "checkpoint_usd": t["checkpoint"],
            "egress_usd": t["egress"],
            "makespan_h": t["makespan_s"] / 3600.0,
            "rounds": t["rounds"], "preemptions": t["preemptions"]})
    return rows


def render_trends(rows: List[Dict[str, Any]]) -> str:
    """The `trends` CSV table (one row per trace, fixed formats)."""
    lines = ["trace,dataset,policy,seed,total_usd,checkpoint_usd,"
             "egress_usd,makespan_h,rounds,preemptions"]
    for r in rows:
        lines.append(
            f"{r['trace']},{r['dataset']},{r['policy']},{r['seed']},"
            f"{r['total_usd']:.6f},{r['checkpoint_usd']:.6f},"
            f"{r['egress_usd']:.6f},{r['makespan_h']:.3f},"
            f"{r['rounds']},{r['preemptions']}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# validate — pre-launch budget screening.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BudgetCheck:
    """A pre-launch estimate against a budget: the requested
    placement's estimated dollars, the cheapest spot zone across every
    provider, and that fallback's own estimate."""
    estimate: float
    budget: float
    basis: str
    cheapest_zone: str
    cheapest_rate: float
    cheapest_estimate: float

    @property
    def ok(self) -> bool:
        """Whether the requested launch fits the budget."""
        return self.estimate <= self.budget


def screen_budget(epoch_s: Sequence[float], n_epochs: int, budget: float,
                  market, *, spin_up_s: float = 150.0,
                  on_demand: bool = False,
                  providers: Optional[Sequence[str]] = None) -> BudgetCheck:
    """§III-E screening before the run exists: each client owes
    `n_epochs * epoch_s + spin_up_s` busy seconds, priced at the
    requested placement — the cheapest spot zone of the requested
    `providers` at t=0, or the default provider's on-demand rate. The
    suggestion (`cheapest_zone`) always searches every provider's spot
    zones, so a refused on-demand or single-provider launch names the
    cheapest feasible alternative."""
    hours = [(n_epochs * e + spin_up_s) / 3600.0 for e in epoch_s]
    if on_demand:
        rate = market.provider_of(None).on_demand_rate
        basis = (f"{len(hours)} clients x {n_epochs} epochs, on-demand "
                 f"{market.default_provider} @ ${rate:.4f}/hr, "
                 f"spin-up {spin_up_s:.0f}s")
    else:
        z, rate = market.cheapest_zone(0.0, providers=providers)
        basis = (f"{len(hours)} clients x {n_epochs} epochs, spot "
                 f"{z.provider}/{z.name} @ ${rate:.4f}/hr, "
                 f"spin-up {spin_up_s:.0f}s")
    estimate = sum(hours) * rate
    best, best_rate = market.cheapest_zone(0.0)
    return BudgetCheck(
        estimate=estimate, budget=budget, basis=basis,
        cheapest_zone=f"{best.provider}/{best.name}",
        cheapest_rate=best_rate,
        cheapest_estimate=sum(hours) * best_rate)


def render_budget_check(chk: BudgetCheck) -> str:
    """The `validate` verdict: a one-line refusal naming the estimate
    and budget (the format tests pin), plus the cheapest-zone
    suggestion; or the pass line with headroom."""
    lines = []
    if chk.ok:
        lines.append(f"# validate: estimated ${chk.estimate:.2f} within "
                     f"budget ${chk.budget:.2f} "
                     f"(headroom ${chk.budget - chk.estimate:.2f})")
    else:
        lines.append(f"error: estimated ${chk.estimate:.2f} exceeds "
                     f"budget ${chk.budget:.2f}")
    lines.append(f"# basis: {chk.basis}")
    fits = chk.cheapest_estimate <= chk.budget
    lines.append(
        f"# cheapest zone: {chk.cheapest_zone} spot @ "
        f"${chk.cheapest_rate:.4f}/hr — estimated "
        f"${chk.cheapest_estimate:.2f} "
        f"{'fits' if fits else 'still exceeds'} budget "
        f"${chk.budget:.2f}")
    return "\n".join(lines)


def _roofline_epoch_s(args) -> float:
    """Epoch seconds from roofline FLOP/byte counts: steps-per-epoch
    times the `launch.roofline` step-time estimate, scaled by
    `--time-scale` (the simulated-seconds-per-step-second knob real
    training calibrates with)."""
    from repro.launch.roofline import estimate_step_time
    step_s = estimate_step_time(args.roofline_flops, args.roofline_bytes,
                                peak_flops=args.peak_flops,
                                hbm_bw=args.hbm_bw)
    return args.steps_per_epoch * step_s * args.time_scale


def _validate_market(args):
    """The `SpotMarket` the validate subcommand prices against: a
    trace-driven multi-provider market under `--price-trace`, else a
    synthetic single-provider market from the `--od-rate`/`--spot-rate`
    scalars (sigma 0 — screening wants the mean, not one noise draw)."""
    from repro.cloud.pricing import SpotMarket
    from repro.common.config import (CloudConfig, MarketConfig,
                                     ProviderConfig)
    if args.price_trace is not None:
        providers = tuple(p.strip() for p in args.providers.split(",")
                          if p.strip())
        market = MarketConfig(providers=tuple(
            ProviderConfig(name=p, on_demand_rate=args.od_rate,
                           price_trace=str(Path(args.price_trace)
                                           / f"{p}.csv"))
            for p in providers))
        cfg = CloudConfig(market=market)
    else:
        cfg = CloudConfig(on_demand_rate=args.od_rate,
                          spot_rate_mean=args.spot_rate / 0.98,
                          spot_rate_sigma=0.0)
    return SpotMarket.for_cloud_config(cfg, seed=0)


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------
def _dumps(obj: Any) -> str:
    """Byte-deterministic JSON: sorted keys, no timestamps."""
    return json.dumps(obj, sort_keys=True, indent=2)


def _cmd_summary(args) -> int:
    payloads = [summarize_path(p) for p in args.traces]
    if args.per_round:
        for p, path in zip(payloads, args.traces):
            p["per_round"] = per_round_rows(path)
    if args.json:
        print(_dumps(payloads))
    else:
        blocks = []
        for p in payloads:
            block = render_summary(p)
            if args.per_round:
                block += "\n" + render_per_round(p["trace"],
                                                 p["per_round"])
            blocks.append(block)
        print("\n\n".join(blocks))
    return 0


def _cmd_trends(args) -> int:
    rows = trend_rows(args.directory)
    print(_dumps(rows) if args.json else render_trends(rows))
    return 0


def _cmd_reconcile(args) -> int:
    failed = False
    for p in args.traces:
        rec = reconcile_path(p, tol=args.tol)
        print(render_reconciliation(rec, args.tol))
        failed = failed or not rec.ok
    return 1 if failed else 0


def _cmd_validate(args) -> int:
    if (args.epoch_s is None) == (args.roofline_flops is None):
        raise ValueError("validate needs exactly one of --epoch-s or "
                         "--roofline-flops/--roofline-bytes")
    if args.epoch_s is not None:
        epoch_s = [float(x) for x in args.epoch_s.split(",") if x.strip()]
    else:
        if args.roofline_bytes is None:
            raise ValueError("--roofline-flops requires --roofline-bytes")
        epoch_s = [_roofline_epoch_s(args)] * args.clients
    market = _validate_market(args)
    providers = None
    if not args.cross_provider:
        providers = (market.default_provider,)
    chk = screen_budget(epoch_s, args.epochs, args.budget, market,
                        spin_up_s=args.spin_up_s,
                        on_demand=args.on_demand, providers=providers)
    print(render_budget_check(chk))
    return 0 if chk.ok else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Argparse entry point (`python -m repro.cloud.report ...`);
    returns the process exit code: 0 on success, 1 on a failed
    reconciliation or refused budget, 2 on unreadable input."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.cloud.report",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summary",
                       help="per-client/provider/zone spend breakdown "
                            "of recorded traces")
    p.add_argument("traces", nargs="+", metavar="TRACE",
                   help="recorded .events.jsonl trace path(s)")
    p.add_argument("--json", action="store_true",
                   help="emit sorted-key JSON instead of the table")
    p.add_argument("--per-round", action="store_true",
                   help="append per-round cost attribution: dollars "
                        "settled inside each RoundStarted -> "
                        "RoundCompleted window, split into compute / "
                        "checkpoint / egress")
    p.set_defaults(func=_cmd_summary)

    p = sub.add_parser("trends",
                       help="cost/makespan/preemption trajectories "
                            "across every trace in a directory")
    p.add_argument("directory", metavar="DIR",
                   help="directory holding *.events.jsonl traces")
    p.add_argument("--json", action="store_true",
                   help="emit sorted-key JSON instead of the table")
    p.set_defaults(func=_cmd_trends)

    p = sub.add_parser("reconcile",
                       help="audit traces against the cost invariant; "
                            "nonzero exit on any divergence")
    p.add_argument("traces", nargs="+", metavar="TRACE",
                   help="recorded .events.jsonl trace path(s)")
    p.add_argument("--tol", type=float, default=RECONCILE_TOL,
                   help="dollar tolerance (default 1e-9)")
    p.set_defaults(func=_cmd_reconcile)

    p = sub.add_parser("validate",
                       help="pre-launch budget screening against "
                            "current market prices")
    p.add_argument("--budget", type=float, required=True,
                   help="run budget in dollars")
    p.add_argument("--epoch-s", default=None, metavar="LIST",
                   help="comma-separated per-client warm epoch seconds")
    p.add_argument("--epochs", type=int, default=10,
                   help="FL rounds to screen for (default 10)")
    p.add_argument("--spin-up-s", type=float, default=150.0,
                   help="provision+boot seconds per client (default 150)")
    p.add_argument("--on-demand", action="store_true",
                   help="price the launch at the default provider's "
                        "on-demand rate instead of cheapest spot")
    p.add_argument("--od-rate", type=float, default=1.008,
                   help="synthetic-market on-demand $/hr (default "
                        "1.008, the paper's g5.xlarge rate)")
    p.add_argument("--spot-rate", type=float, default=0.3951,
                   help="synthetic-market cheapest-zone spot $/hr "
                        "(default 0.3951)")
    p.add_argument("--price-trace", metavar="DIR", default=None,
                   help="price off real spot-history traces "
                        "(<provider>.csv per provider under DIR)")
    p.add_argument("--providers", metavar="NAMES", default="aws",
                   help="comma-separated provider list for "
                        "--price-trace (default: aws)")
    p.add_argument("--cross-provider", action="store_true",
                   help="let the requested placement span every "
                        "provider (default: default provider only; "
                        "the suggestion always searches all)")
    p.add_argument("--roofline-flops", type=float, default=None,
                   help="per-step FLOPs for a roofline-derived epoch "
                        "time (with --roofline-bytes)")
    p.add_argument("--roofline-bytes", type=float, default=None,
                   help="per-step HBM bytes for the roofline estimate")
    p.add_argument("--steps-per-epoch", type=int, default=100,
                   help="steps per epoch for the roofline estimate "
                        "(default 100)")
    p.add_argument("--peak-flops", type=float, default=None,
                   help="hardware peak FLOP/s override (default: the "
                        "launch.mesh TPU constant)")
    p.add_argument("--hbm-bw", type=float, default=None,
                   help="hardware HBM bandwidth override, bytes/s")
    p.add_argument("--time-scale", type=float, default=1.0,
                   help="simulated seconds per roofline second "
                        "(default 1.0)")
    p.add_argument("--clients", type=int, default=1,
                   help="client count for the roofline path "
                        "(default 1)")
    p.set_defaults(func=_cmd_validate)

    args = ap.parse_args(argv)
    try:
        return args.func(args)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
