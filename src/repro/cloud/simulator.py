"""Discrete-event cloud simulator: instance lifecycles, spin-up delays,
model-driven spot preemption, and per-second billing against the
SpotMarket.

This is the stand-in for AWS EC2 + the custom Ray node launcher in the
paper. The FedCostAware scheduler interacts with it through exactly the
operations the paper's scheduler uses: request instance (in a chosen
zone, on a chosen provider), terminate instance, observe ready/preempt
events, read accrued cost.

Billing semantics are per provider (`repro.cloud.pricing.Provider`):
the min-billing floor, billing granularity and preemption-notice lead
time all come from the provider descriptor of the zone an instance runs
in, so a multi-provider market bills each instance by its own
provider's rules.

Spot reclaims are delegated to a pluggable `PreemptionModel`
(`repro.cloud.preemption`): the default constant-rate model reproduces
the historical flat-Poisson behavior bit-for-bit, while the
price-coupled and recorded-interruption models replay realistic fault
patterns (see docs/markets.md).

Lifecycle notifications are published as typed events on an `EventBus`
(`repro.core.events`) — the simulator takes no per-request callbacks, so
any number of consumers (cluster manager, cost accountant, loggers) can
observe the same run without threading closures through call sites.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from repro.common.config import CloudConfig
from repro.cloud.preemption import PreemptionModel, build_preemption_model
from repro.cloud.pricing import DEFAULT_PROVIDER, SpotMarket, Zone
from repro.core.events import (BillingTick, EventBus, InstancePreempted,
                               InstancePreemptionWarning, InstanceReady,
                               InstanceRequested, InstanceTerminated)

# Instance states
REQUESTED, SPINNING_UP, RUNNING, TERMINATED, PREEMPTED = (
    "requested", "spinning_up", "running", "terminated", "preempted")


@dataclasses.dataclass
class Instance:
    """One cloud instance's mutable lifecycle record (the live
    counterpart of `repro.core.eventlog.InstanceRef` snapshots)."""
    iid: int
    client: str
    zone: str
    on_demand: bool
    t_request: float
    t_ready: Optional[float] = None
    t_end: Optional[float] = None
    state: str = SPINNING_UP
    cost: float = 0.0          # finalized at termination/preemption
    _billing_from: Optional[float] = None
    provider: str = DEFAULT_PROVIDER


class CloudSimulator:
    """Event-driven cloud with billing.

    Events are (time, seq, callback) on a heap; callbacks may schedule
    further events. `run_until_idle` drains the heap. Lifecycle
    transitions are published on `self.bus`.
    """

    def __init__(self, cfg: CloudConfig,
                 market: Optional[SpotMarket] = None,
                 seed: int = 0, bus: Optional[EventBus] = None,
                 preemption_model: Optional[PreemptionModel] = None):
        self.cfg = cfg
        self.market = market or SpotMarket.for_cloud_config(cfg, seed=seed)
        self.preemption_model = (preemption_model
                                 or build_preemption_model(cfg,
                                                           self.market))
        self.bus = bus or EventBus()
        self.now = 0.0
        self._heap: List = []
        self._seq = itertools.count()
        self._rng = np.random.RandomState(seed + 17)
        self._instances: Dict[int, Instance] = {}
        self._iid = itertools.count(1)
        self.event_log: List[dict] = []
        # aggregate-query indexes: per-client instance lists, running
        # settled-cost accumulators, and the set of instances with an
        # open billing segment — total_cost/client_cost/instances_of
        # are O(open)/O(k) instead of scanning every instance ever made
        self._by_client: Dict[str, List[Instance]] = defaultdict(list)
        self._settled_total = 0.0
        self._settled_by_client: Dict[str, float] = defaultdict(float)
        self._open_by_client: Dict[str, Dict[int, Instance]] = (
            defaultdict(dict))

    @property
    def prices(self) -> SpotMarket:
        """Pre-redesign name for the market facade."""
        return self.market

    def provider_of(self, inst: Instance):
        """Billing semantics of the instance's provider."""
        return self.market.provider_of(inst.provider)

    # ------------------------------------------------------------------
    # Event engine.
    # ------------------------------------------------------------------
    def schedule(self, t: float, fn: Callable[[], None]):
        """Run `fn` at absolute simulated time `t` (>= now); same-time
        events fire in scheduling order (FIFO sequence numbers)."""
        assert t >= self.now - 1e-9, (t, self.now)
        heapq.heappush(self._heap, (max(t, self.now), next(self._seq), fn))

    def schedule_in(self, delay: float, fn: Callable[[], None]):
        """`schedule` relative to the current clock."""
        self.schedule(self.now + max(delay, 0.0), fn)

    def run_until_idle(self, t_max: float = math.inf):
        """Drain the event heap (advancing `now`), stopping before the
        first event past `t_max` (which stays queued)."""
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            if t > t_max:
                heapq.heappush(self._heap, (t, next(self._seq), fn))
                return
            self.now = max(self.now, t)
            fn()

    # ------------------------------------------------------------------
    # Instance lifecycle (the paper's Ray-autoscaler custom API analogue).
    # ------------------------------------------------------------------
    def sample_spin_up(self) -> float:
        """Lognormal provisioning+boot delay around
        `cfg.spin_up_mean_s`."""
        mu = math.log(self.cfg.spin_up_mean_s)
        return float(np.exp(mu + self._rng.randn() * self.cfg.spin_up_sigma))

    def request_instance(self, client: str,
                         zone: Optional[Union[str, Zone]] = None,
                         on_demand: bool = False,
                         provider: Optional[str] = None) -> Instance:
        """Launch an instance for `client` in `zone` (None -> the
        currently cheapest zone across the whole market); it becomes
        RUNNING after a sampled spin-up delay and — if spot — gets its
        reclaim scheduled by the preemption model."""
        if zone is None:
            z, _ = self.market.cheapest_zone(self.now)
            zone, provider = z.name, z.provider
        elif isinstance(zone, Zone):
            zone, provider = zone.name, zone.provider
        # a bare pinned zone name binds to its owning provider (first
        # registered), not blindly to the default provider
        inst = Instance(next(self._iid), client, zone, on_demand, self.now,
                        provider=self.market.resolve_provider(zone,
                                                              provider))
        self._instances[inst.iid] = inst
        self._by_client[inst.client].append(inst)
        spin = self.sample_spin_up()
        self._log("request", inst)
        self.bus.publish(InstanceRequested(self.now, inst))

        def ready():
            if inst.state != SPINNING_UP:        # terminated while spinning
                return
            inst.state = RUNNING
            inst.t_ready = self.now
            inst._billing_from = self.now
            self._open_by_client[inst.client][inst.iid] = inst
            self._log("ready", inst)
            if not inst.on_demand:
                self._schedule_preemption(inst)
            self.bus.publish(InstanceReady(self.now, inst))

        self.schedule_in(spin, ready)
        return inst

    def _schedule_preemption(self, inst: Instance):
        """Ask the preemption model when the spot market reclaims
        `inst`; schedule the provider's warning and the reclaim. A
        model answer of None means the instance is never preempted."""
        delay = self.preemption_model.next_preemption_delay(
            inst, self.now, self._rng)
        if delay is None:
            return
        notice = self.provider_of(inst).preemption_notice_s
        if notice > 0.0:
            # the provider's reclaim warning (AWS: 2 min) precedes the
            # actual reclaim; consumers may checkpoint / drain on it
            reclaim_at = self.now + delay

            def warn():
                if inst.state == RUNNING:
                    self.bus.publish(InstancePreemptionWarning(
                        self.now, inst, reclaim_at))

            self.schedule_in(max(delay - notice, 0.0), warn)
        self.schedule_in(delay, lambda: self.preempt(inst))

    def preempt(self, inst: Instance) -> bool:
        """Spot reclaim. A no-op unless the instance is RUNNING — in
        particular, a preemption arriving while the instance is still
        SPINNING_UP neither bills nor changes state. Returns True if the
        instance was actually reclaimed."""
        if inst.state != RUNNING:
            return False
        self._finalize_billing(inst)
        inst.state = PREEMPTED
        inst.t_end = self.now
        self._log("preempt", inst)
        self.bus.publish(InstancePreempted(self.now, inst))
        return True

    def terminate(self, inst: Instance):
        """Custom terminate-specific-node API (paper §III-C)."""
        if inst.state in (TERMINATED, PREEMPTED):
            return
        if inst.state == RUNNING:
            self._finalize_billing(inst)
        inst.state = TERMINATED
        inst.t_end = self.now
        self._log("terminate", inst)
        self.bus.publish(InstanceTerminated(self.now, inst))

    # ------------------------------------------------------------------
    # Billing.
    # ------------------------------------------------------------------
    def _finalize_billing(self, inst: Instance):
        t0 = inst._billing_from
        if t0 is None:
            return
        t1 = self.now
        prov = self.provider_of(inst)
        billed = max(t1 - t0, prov.min_billing_s if not inst.on_demand
                     else 0.0)
        # coarse-granularity providers round the billed duration up to
        # whole billing units; per-second (or finer) billing is treated
        # as continuous, matching the pre-redesign behavior
        g = prov.billing_granularity_s
        if g > 1.0:
            billed = math.ceil(billed / g - 1e-12) * g
        amount = self.market.cost(inst.zone, t0, t0 + billed,
                                  inst.on_demand, provider=inst.provider)
        inst.cost += amount
        inst._billing_from = None
        self._settled_total += amount
        self._settled_by_client[inst.client] += amount
        self._open_by_client[inst.client].pop(inst.iid, None)
        self.bus.publish(BillingTick(self.now, inst, inst.client,
                                     t0, t0 + billed, amount))

    def accrued_cost(self, inst: Instance) -> float:
        """Cost so far including the open billing segment."""
        c = inst.cost
        if inst._billing_from is not None:
            c += self.market.cost(inst.zone, inst._billing_from, self.now,
                                  inst.on_demand, provider=inst.provider)
        return c

    def _open_cost(self, inst: Instance) -> float:
        """Price of the instance's open billing segment (0 if closed)."""
        if inst._billing_from is None:
            return 0.0
        return self.market.cost(inst.zone, inst._billing_from, self.now,
                                inst.on_demand, provider=inst.provider)

    def client_cost(self, client: str) -> float:
        """Settled accumulator + the client's open segments: O(open
        instances of `client`), not a scan of every instance ever made
        (see benchmarks/accounting_bench.py for the old gap)."""
        return (self._settled_by_client[client]
                + sum(self._open_cost(i)
                      for i in self._open_by_client[client].values()))

    def total_cost(self) -> float:
        """Settled accumulator + all open segments: O(currently open
        instances); see `client_cost`."""
        open_cost = sum(self._open_cost(i)
                        for open_map in self._open_by_client.values()
                        for i in open_map.values())
        return self._settled_total + open_cost

    def instances_of(self, client: str) -> List[Instance]:
        """Every instance (any state) ever created for `client` —
        served from the per-client index in O(k)."""
        return list(self._by_client[client])

    # ------------------------------------------------------------------
    def _log(self, kind: str, inst: Instance):
        self.event_log.append({
            "t": self.now, "kind": kind, "client": inst.client,
            "iid": inst.iid, "zone": inst.zone,
            "provider": inst.provider, "on_demand": inst.on_demand,
        })
