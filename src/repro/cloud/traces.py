"""Real spot-market trace ingestion: price histories and recorded
interruptions.

Price histories are the format `aws ec2 describe-spot-price-history`
exports — CSV with a header row

    Timestamp,AvailabilityZone,InstanceType,ProductDescription,SpotPrice
    2024-03-01T00:00:00Z,us-east-1a,g5.xlarge,Linux/UNIX,0.3872

or JSONL with the same keys per line — and build one piecewise-constant
`TracePriceSource` per availability zone. Timestamps become seconds
relative to the earliest record in the file (the "market epoch"), so a
replayed market day starts at simulated t=0 regardless of when the
history was captured.

Interruption traces are the same shape minus the price column
(`Timestamp,AvailabilityZone,InstanceType`), one row per observed spot
reclaim; files are conventionally named `<provider>.interruptions.csv`
(or `.jsonl`) and live alongside the price histories so both replay on
one shared market clock. `build_interruption_schedule` turns them into
per-zone ascending timestamp lists for the replay preemption model
(`repro.cloud.preemption.ReplayInterruptionModel`).

Malformed rows raise `TraceFormatError` carrying the file and line
number; the CI fixture-validation step runs this module as

    python -m repro.cloud.traces --validate tests/fixtures/prices

which routes `*.interruptions.*` files through the interruption parser
and everything else through the price parser.
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import json
import sys
from datetime import datetime, timezone
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.cloud.pricing import TracePriceSource, Zone

CSV_COLUMNS = ("Timestamp", "AvailabilityZone", "InstanceType",
               "ProductDescription", "SpotPrice")
INTERRUPTION_COLUMNS = ("Timestamp", "AvailabilityZone", "InstanceType")


class TraceFormatError(ValueError):
    """A trace file row failed to parse; the message carries
    `<file>:<line>` so CI output points at the offending record."""


@dataclasses.dataclass(frozen=True)
class PriceRecord:
    """One parsed spot-price-history row."""
    timestamp: float                # absolute epoch seconds (UTC)
    zone: str
    instance_type: str
    product: str
    price: float


def _parse_timestamp(raw: str, where: str) -> float:
    try:
        dt = datetime.fromisoformat(raw.replace("Z", "+00:00"))
    except ValueError:
        raise TraceFormatError(f"{where}: bad timestamp {raw!r}")
    if dt.tzinfo is None:
        dt = dt.replace(tzinfo=timezone.utc)
    return dt.timestamp()


def _parse_price(raw: str, where: str) -> float:
    try:
        price = float(raw)
    except (TypeError, ValueError):
        raise TraceFormatError(f"{where}: bad price {raw!r}")
    if not price >= 0.0:            # also catches NaN
        raise TraceFormatError(f"{where}: negative price {raw!r}")
    return price


def _record_from_fields(fields: Dict[str, str], where: str) -> PriceRecord:
    missing = [c for c in CSV_COLUMNS if not fields.get(c)]
    if missing:
        raise TraceFormatError(f"{where}: missing field(s) {missing}")
    return PriceRecord(
        timestamp=_parse_timestamp(fields["Timestamp"], where),
        zone=fields["AvailabilityZone"],
        instance_type=fields["InstanceType"],
        product=fields["ProductDescription"],
        price=_parse_price(fields["SpotPrice"], where))


def _iter_rows(path: Path, columns: Tuple[str, ...]):
    """Yield `(fields, where)` per data row of a CSV (strict header) or
    JSONL trace file, raising `TraceFormatError` on structural
    problems. Shared by the price and interruption parsers."""
    if path.suffix.lower() == ".jsonl":
        for i, line in enumerate(path.read_text().splitlines(), start=1):
            if not line.strip():
                continue
            where = f"{path.name}:{i}"
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceFormatError(f"{where}: bad JSON ({e.msg})")
            if not isinstance(obj, dict):
                raise TraceFormatError(f"{where}: expected an object")
            yield ({c: str(obj[c]) if c in obj else "" for c in columns},
                   where)
    else:
        with path.open(newline="") as fh:
            reader = csv.reader(fh)
            header = next(reader, None)
            if header is None or tuple(h.strip() for h in header) != \
                    columns:
                raise TraceFormatError(
                    f"{path.name}:1: bad header {header!r}, expected "
                    f"{','.join(columns)}")
            for i, row in enumerate(reader, start=2):
                if not row:
                    continue
                where = f"{path.name}:{i}"
                if len(row) != len(columns):
                    raise TraceFormatError(
                        f"{where}: {len(row)} column(s), expected "
                        f"{len(columns)}")
                yield dict(zip(columns, (c.strip() for c in row))), where


def parse_price_file(path: Union[str, Path]) -> List[PriceRecord]:
    """Parse one CSV or JSONL spot-history file into records (sorted by
    timestamp). Raises `TraceFormatError` on any malformed row."""
    path = Path(path)
    records = [_record_from_fields(fields, where)
               for fields, where in _iter_rows(path, CSV_COLUMNS)]
    if not records:
        raise TraceFormatError(f"{path.name}: no price records")
    records.sort(key=lambda r: (r.timestamp, r.zone))
    return records


@dataclasses.dataclass(frozen=True)
class InterruptionRecord:
    """One observed spot reclaim: when and in which zone."""
    timestamp: float                # absolute epoch seconds (UTC)
    zone: str
    instance_type: str


def parse_interruption_file(
        path: Union[str, Path]) -> List[InterruptionRecord]:
    """Parse one CSV or JSONL recorded-interruption file (the
    spot-history format minus the price/product columns) into records
    sorted by timestamp. Raises `TraceFormatError` on malformed rows."""
    path = Path(path)
    records: List[InterruptionRecord] = []
    for fields, where in _iter_rows(path, INTERRUPTION_COLUMNS):
        missing = [c for c in INTERRUPTION_COLUMNS if not fields.get(c)]
        if missing:
            raise TraceFormatError(f"{where}: missing field(s) {missing}")
        records.append(InterruptionRecord(
            timestamp=_parse_timestamp(fields["Timestamp"], where),
            zone=fields["AvailabilityZone"],
            instance_type=fields["InstanceType"]))
    if not records:
        raise TraceFormatError(f"{path.name}: no interruption records")
    records.sort(key=lambda r: (r.timestamp, r.zone))
    return records


def build_interruption_schedule(records: Sequence[InterruptionRecord],
                                epoch: Optional[float] = None,
                                instance_type: Optional[str] = None,
                                ) -> Dict[str, Tuple[float, ...]]:
    """Zone -> ascending interruption times in market-clock seconds.

    `epoch` should be the owning market's epoch (earliest price record
    across its trace files) so the reclaim times line up with the price
    replay; it defaults to the earliest interruption when the schedule
    stands alone."""
    if instance_type is not None:
        records = [r for r in records if r.instance_type == instance_type]
    if not records:
        raise TraceFormatError(
            "no interruption records"
            + (f" for instance type {instance_type!r}"
               if instance_type is not None else ""))
    t0 = epoch if epoch is not None else min(r.timestamp for r in records)
    by_zone: Dict[str, List[float]] = {}
    for r in records:
        by_zone.setdefault(r.zone, []).append(r.timestamp - t0)
    return {z: tuple(sorted(ts)) for z, ts in by_zone.items()}


def _region_of(zone: str) -> str:
    """AWS-style zone -> region: strip the trailing zone letter
    ("us-east-1a" -> "us-east-1"); GCP-style "us-central1-a" loses the
    "-a" suffix."""
    if len(zone) > 2 and zone[-2] == "-":
        return zone[:-2]
    return zone[:-1] if zone and zone[-1].isalpha() else zone


def build_zone_sources(records: Sequence[PriceRecord],
                       provider: str = "aws",
                       instance_type: Optional[str] = None,
                       epoch: Optional[float] = None,
                       ) -> List[Tuple[Zone, TracePriceSource]]:
    """Build `(Zone, TracePriceSource)` pairs from already-parsed
    records (one parse can feed several consumers — epoch computation
    and source construction).

    Zones are emitted sorted by name (deterministic market registration
    order). `epoch` overrides the t=0 reference (default: the records'
    earliest timestamp) so multiple providers' traces can share one
    market clock."""
    if instance_type is not None:
        records = [r for r in records if r.instance_type == instance_type]
    if not records:
        raise TraceFormatError(
            f"no price records"
            + (f" for instance type {instance_type!r}"
               if instance_type is not None else ""))
    t0 = epoch if epoch is not None else min(r.timestamp for r in records)
    by_zone: Dict[str, List[PriceRecord]] = {}
    for r in records:
        by_zone.setdefault(r.zone, []).append(r)
    out = []
    for zone_name in sorted(by_zone):
        zrecs = by_zone[zone_name]
        out.append((Zone(zone_name, _region_of(zone_name), provider),
                    TracePriceSource([r.timestamp - t0 for r in zrecs],
                                     [r.price for r in zrecs])))
    return out


def load_price_trace(path: Union[str, Path],
                     provider: str = "aws",
                     instance_type: Optional[str] = None,
                     epoch: Optional[float] = None,
                     ) -> List[Tuple[Zone, TracePriceSource]]:
    """`build_zone_sources` over one freshly parsed history file."""
    return build_zone_sources(parse_price_file(path), provider,
                              instance_type, epoch)


def shared_epoch(paths: Sequence[Union[str, Path]]) -> float:
    """Earliest timestamp across several history files — the common
    market epoch for a multi-provider trace-driven run."""
    return min(min(r.timestamp for r in parse_price_file(p))
               for p in paths)


# ---------------------------------------------------------------------------
# Fixture validation (CI).
# ---------------------------------------------------------------------------
def is_interruption_trace(path: Union[str, Path]) -> bool:
    """File-name convention: `<provider>.interruptions.csv` / `.jsonl`
    holds recorded reclaims; everything else is a price history."""
    stem = Path(path).stem          # drops only the final suffix
    return stem.endswith(".interruptions")


def validate_dir(directory: Union[str, Path]) -> List[str]:
    """Parse every *.csv / *.jsonl under `directory` — price histories
    and `*.interruptions.*` reclaim records; returns a summary line per
    file, raises `TraceFormatError` on the first bad row."""
    directory = Path(directory)
    paths = sorted(list(directory.glob("*.csv"))
                   + list(directory.glob("*.jsonl")))
    if not paths:
        raise TraceFormatError(f"no trace files under {directory}")
    lines = []
    for p in paths:
        if is_interruption_trace(p):
            irecords = parse_interruption_file(p)
            zones = sorted({r.zone for r in irecords})
            lines.append(f"{p.name}: {len(irecords)} interruptions, "
                         f"{len(zones)} zones ({', '.join(zones)})")
            continue
        records = parse_price_file(p)
        zones = sorted({r.zone for r in records})
        span_h = (max(r.timestamp for r in records)
                  - min(r.timestamp for r in records)) / 3600.0
        lines.append(f"{p.name}: {len(records)} records, "
                     f"{len(zones)} zones ({', '.join(zones)}), "
                     f"{span_h:.1f}h span")
    return lines


def main(argv=None) -> int:
    """CLI entry point: `python -m repro.cloud.traces --validate DIR`."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--validate", metavar="DIR", required=True,
                    help="parse every *.csv / *.jsonl under DIR; exit "
                         "non-zero on any malformed row")
    args = ap.parse_args(argv)
    try:
        for line in validate_dir(args.validate):
            print(line)
    except TraceFormatError as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
