"""Adversarial market scenario generators: seeded stress markets.

One spiky fixture trace and two recorded market days are thin coverage
for a scheduler whose claims are statistical — Multi-FedLS shows spot
price and interruption behavior varies sharply across providers,
regions and time. This module turns a built `SpotMarket` into a stress
market by reshaping its zone price sources (piecewise-constant
`TracePriceSource` traces on a seeded step grid) and, where the
scenario calls for it, registering correlated reclaim schedules for
the replay/correlated preemption models. Four generators:

  flash_crash      — step price spikes with exponential decay. Spike
                     onset times are shared across a provider's zones
                     (one market-wide demand shock), amplitudes drawn
                     per zone; `strength` scales spike count and size.
  capacity_crunch  — provider-wide capacity squeezes: during each
                     crunch window the flagged provider's prices rise
                     and *every* one of its zones receives reclaims at
                     nearly the same instants (within `CRUNCH_JITTER_S`
                     of each other — the cross-zone correlation a
                     per-zone Poisson process cannot produce). Other
                     providers see neither. Pair with the "replay" or
                     "correlated" preemption model.
  diurnal          — daily demand cycle (business-hours peak, night
                     trough) plus a weekend discount, per zone with a
                     seeded phase jitter.
  price_inversion  — persistent cross-provider inversions: alternating
                     multi-hour blocks in which the flagged provider
                     prices above the rest, then below — the regime
                     that rewards cross-provider placement and punishes
                     provider-pinned policies. Needs >= 2 providers.

Every generator is a pure function of (market, `ScenarioConfig`): fully
seeded, no global state, so the same config always produces
byte-identical traces and schedules (pinned by tests/test_scenarios.py
down to the recorded event log). Scenarios are applied by
`SpotMarket.from_market_config` when `MarketConfig.scenario` is set, so
any benchmark reaches a stress market by configuration alone; the sweep
harness (`repro.sweep`) fans the same registry out over policies and
seeds.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.common.config import ScenarioConfig
from repro.cloud.pricing import SpotMarket, TracePriceSource

# cross-zone reclaim jitter inside one capacity-crunch hit: every zone
# of the flagged provider falls within this window of the hit time
CRUNCH_JITTER_S = 30.0
# spacing between successive reclaim hits inside one crunch window
CRUNCH_RECLAIM_EVERY_S = 1200.0
# e-folding time of a flash-crash spike's decay
FLASH_DECAY_TAU_S = 1800.0


def _grid(cfg: ScenarioConfig) -> np.ndarray:
    """The scenario's sampling grid: `step_s`-spaced times covering
    [0, horizon_s]."""
    n = max(int(cfg.horizon_s / cfg.step_s), 2)
    return np.arange(n + 1, dtype=np.float64) * cfg.step_s


def _base_prices(market: SpotMarket, provider: str, zone: str,
                 ts: np.ndarray) -> np.ndarray:
    """The zone's current source sampled on the grid (vectorized when
    the source supports it)."""
    src = market.source(zone, provider)
    prices_at = getattr(src, "prices_at", None)
    if prices_at is not None:
        return np.asarray(prices_at(ts), dtype=np.float64)
    return np.array([src.price(float(t)) for t in ts])


def _provider_zones(market: SpotMarket,
                    provider: str) -> List[str]:
    """Zone names of one provider, in registration order."""
    return [z.name for z in market.zones if z.provider == provider]


def _flagged(market: SpotMarket, cfg: ScenarioConfig) -> str:
    """The provider a scenario squeezes: the explicit flag or the
    market's first-registered provider."""
    name = cfg.provider or market.default_provider
    if name not in market.providers:
        raise ValueError(f"scenario provider {name!r} not in market "
                         f"({sorted(market.providers)})")
    return name


# ---------------------------------------------------------------------------
# Generators. Each mutates `market` in place via `replace_source` /
# `add_interruptions` and draws only from its own seeded RandomState.
# ---------------------------------------------------------------------------
def flash_crash(market: SpotMarket, cfg: ScenarioConfig) -> None:
    """Step spikes with exponential decay on every provider's zones.

    Spike onsets are drawn once per provider (zones of one provider
    spike together, as a real demand shock would hit a region-wide
    market); each (spike, zone) pair gets its own amplitude. A spike
    multiplies the base price by `1 + A * exp(-(t - t0) / tau)` from
    its onset step, with A in [1.5, 2.5] * strength.
    """
    ts = _grid(cfg)
    for pi, pname in enumerate(market.providers):
        rng = np.random.RandomState(cfg.seed + 1000 * pi)
        n_spikes = max(1, int(round(3 * cfg.strength)))
        onsets = rng.uniform(0.1, 0.9, size=n_spikes) * cfg.horizon_s
        # snap onsets to the grid so the spike is a clean price step
        onsets = np.floor(onsets / cfg.step_s) * cfg.step_s
        for zone in _provider_zones(market, pname):
            base = _base_prices(market, pname, zone, ts)
            boost = np.zeros_like(ts)
            for t0 in onsets:
                amp = (1.5 + rng.uniform(0.0, 1.0)) * cfg.strength
                live = ts >= t0
                boost[live] += amp * np.exp(-(ts[live] - t0)
                                            / FLASH_DECAY_TAU_S)
            market.replace_source(
                zone, TracePriceSource(ts, base * (1.0 + boost)),
                provider=pname)


def capacity_crunch(market: SpotMarket, cfg: ScenarioConfig) -> None:
    """Provider-wide capacity squeezes with correlated reclaims.

    Crunch windows are drawn once for the flagged provider; inside each
    window its zone prices scale by `1 + 1.5 * strength` and reclaim
    hits land every `CRUNCH_RECLAIM_EVERY_S`, each hit reclaiming every
    zone of the provider within `CRUNCH_JITTER_S` (per-zone jitter is
    seeded). Other providers' prices and schedules are untouched —
    cross-provider placement is the escape hatch the scenario rewards.
    """
    flagged = _flagged(market, cfg)
    rng = np.random.RandomState(cfg.seed)
    ts = _grid(cfg)
    n_windows = max(1, int(round(2 * cfg.strength)))
    starts = np.sort(rng.uniform(0.1, 0.75, size=n_windows)) * cfg.horizon_s
    starts = np.floor(starts / cfg.step_s) * cfg.step_s
    length = 3600.0 * (1.0 + cfg.strength)
    squeeze = 1.0 + 1.5 * cfg.strength
    in_window = np.zeros(len(ts), dtype=bool)
    for t0 in starts:
        in_window |= (ts >= t0) & (ts < t0 + length)
    zones = _provider_zones(market, flagged)
    for zone in zones:
        base = _base_prices(market, flagged, zone, ts)
        market.replace_source(
            zone, TracePriceSource(ts, np.where(in_window, base * squeeze,
                                                base)),
            provider=flagged)
    # reclaim schedule: hits at fixed offsets inside each window, every
    # zone within CRUNCH_JITTER_S of the hit (drawn per zone and hit)
    hits = [float(t0 + k * CRUNCH_RECLAIM_EVERY_S)
            for t0 in starts
            for k in range(max(int(length / CRUNCH_RECLAIM_EVERY_S), 1))]
    times_by_zone: Dict[str, List[float]] = {z: [] for z in zones}
    for hit in hits:
        jitter = rng.uniform(0.0, CRUNCH_JITTER_S, size=len(zones))
        for z, j in zip(zones, jitter):
            times_by_zone[z].append(hit + float(j))
    for z, times in times_by_zone.items():
        merged = list(market.interruptions.get((flagged, z), ())) + times
        market.add_interruptions(flagged, z, merged)


def diurnal(market: SpotMarket, cfg: ScenarioConfig) -> None:
    """Daily demand cycle + weekend discount on every zone.

    Price scales by `1 + a * sin(2*pi*(t - phase)/day)` with
    a = 0.25 * strength (clipped below 0.9 so prices stay positive),
    peaking mid-afternoon; Saturdays and Sundays additionally scale by
    0.8. Each zone gets a seeded phase jitter of up to one hour so
    zones do not move in lockstep.
    """
    ts = _grid(cfg)
    day = 86400.0
    a = min(0.25 * cfg.strength, 0.9)
    for pi, pname in enumerate(market.providers):
        rng = np.random.RandomState(cfg.seed + 1000 * pi)
        for zone in _provider_zones(market, pname):
            base = _base_prices(market, pname, zone, ts)
            phase = 14 * 3600.0 + rng.uniform(-3600.0, 3600.0)
            cycle = 1.0 + a * np.sin(2 * np.pi * (ts - phase) / day)
            weekend = np.where((ts // day) % 7 >= 5, 0.8, 1.0)
            market.replace_source(
                zone, TracePriceSource(ts, base * cycle * weekend),
                provider=pname)


def price_inversion(market: SpotMarket, cfg: ScenarioConfig) -> None:
    """Persistent cross-provider price inversions.

    The horizon is cut into 6-hour blocks; in even blocks the flagged
    provider's zones price `1 + 0.5 * strength` above their base while
    every other provider prices the same factor below, and odd blocks
    swap the roles — so at any instant one provider is decisively
    cheaper, and which one it is keeps flipping. Needs a market with at
    least two providers (there is nothing to invert otherwise).
    """
    if len(market.providers) < 2:
        raise ValueError("price_inversion needs >= 2 providers")
    flagged = _flagged(market, cfg)
    ts = _grid(cfg)
    block_s = 6 * 3600.0
    factor = 1.0 + 0.5 * cfg.strength
    even = (ts // block_s) % 2 == 0
    for pname in market.providers:
        up = np.where(even, factor, 1.0 / factor)
        mult = up if pname == flagged else 1.0 / up
        for zone in _provider_zones(market, pname):
            base = _base_prices(market, pname, zone, ts)
            market.replace_source(
                zone, TracePriceSource(ts, base * mult), provider=pname)


# name -> generator; `MarketConfig.scenario.name` resolves here
SCENARIOS: Dict[str, Callable[[SpotMarket, ScenarioConfig], None]] = {
    "flash_crash": flash_crash,
    "capacity_crunch": capacity_crunch,
    "diurnal": diurnal,
    "price_inversion": price_inversion,
}


def apply_scenario(market: SpotMarket, cfg: ScenarioConfig) -> SpotMarket:
    """Reshape `market` in place through the named generator; returns
    the market for chaining. Unknown names raise, listing the
    registry."""
    try:
        gen = SCENARIOS[cfg.name]
    except KeyError:
        raise ValueError(f"unknown scenario {cfg.name!r}; known: "
                         f"{sorted(SCENARIOS)}") from None
    gen(market, cfg)
    return market
