"""Synchronous FL aggregation algorithms.

The paper deliberately keeps the *synchronous* protocol (§I) — FedCostAware
is an orthogonal, system-level optimization — so the algorithms here are
the standard synchronous family:

  fedavg   — sample-count weighted parameter average (McMahan et al.)
  fedprox  — fedavg aggregation + proximal term in the client loss
  fedavgm  — fedavg + server momentum on the update direction
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp


def weighted_average(param_list: Sequence, weights: Sequence[float]):
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def avg(*leaves):
        acc = sum(wi * leaf.astype(jnp.float32)
                  for wi, leaf in zip(w, leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *param_list)


def fedprox_penalty(params, global_params, mu: float):
    sq = sum(jnp.sum(jnp.square(p.astype(jnp.float32)
                                - g.astype(jnp.float32)))
             for p, g in zip(jax.tree.leaves(params),
                             jax.tree.leaves(global_params)))
    return 0.5 * mu * sq


class ServerState:
    """Holds the global model + algorithm-specific server state."""

    def __init__(self, params, algorithm: str = "fedavg",
                 server_momentum: float = 0.9, server_lr: float = 1.0):
        self.params = params
        self.algorithm = algorithm
        self.server_momentum = server_momentum
        self.server_lr = server_lr
        self._velocity = None

    def aggregate(self, client_params: Sequence, weights: Sequence[float]):
        new = weighted_average(client_params, weights)
        if self.algorithm in ("fedavg", "fedprox"):
            self.params = new
            return self.params
        if self.algorithm == "fedavgm":
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                self.params, new)
            if self._velocity is None:
                self._velocity = delta
            else:
                self._velocity = jax.tree.map(
                    lambda v, d: self.server_momentum * v + d,
                    self._velocity, delta)
            self.params = jax.tree.map(
                lambda p, v: (p.astype(jnp.float32)
                              - self.server_lr * v).astype(p.dtype),
                self.params, self._velocity)
            return self.params
        raise ValueError(self.algorithm)
