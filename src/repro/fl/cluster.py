"""ClusterManager + DirectiveExecutor: instance lifecycle for FL clients.

`ClusterManager` sits between the cloud simulator and the round engines.
It consumes the cloud-level bus events (`InstanceReady`,
`InstancePreempted`, `InstancePreemptionWarning`), filters out stale
ones (an event for an instance the cluster no longer tracks is dropped
here, so engines never have to guard against races), and re-publishes
client-level events (`ClientReady`, `ClientLost`,
`ClientPreemptionWarning`).

Owns, per client:
  * the tracked instance (at most one),
  * an optional *standby* replacement (forecast pre-warming,
    `repro.core.strategy.ForecastPrewarmStrategy`): a second instance
    spun up alongside a doomed-looking one; the next `request` —
    typically the reclaim recovery — promotes it instead of launching
    cold, collapsing the spin-up gap,
  * freshness (has the instance completed an epoch yet — drives the
    cold/warm duration split and the spin-up observations),
  * pre-warm scheduling with generation counters (a re-issued pre-warm
    invalidates the previous one) honoring §III-D queue adjustments,
  * resume-from-checkpoint requests: `request(..., resume_token=...)`
    stamps the replacement instance so the engine can distinguish a
    recovery ready from a fresh dispatch.

`DirectiveExecutor` is the write-side of the strategy API
(`repro.core.strategy`): strategies answer events with typed directives
(`SpinUp`, `Terminate`, `PreWarm`, `Checkpoint`, `Drain`, `ScreenOut`)
and the executor applies them against the cluster/bus — engines never
execute scheduling decisions themselves.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.checkpoint import snapshots
from repro.cloud.simulator import (RUNNING, SPINNING_UP, CloudSimulator,
                                   Instance)
from repro.common.config import ClientProfile
from repro.core.events import (BudgetExhausted, ClientCheckpointed,
                               ClientLost, ClientPreemptionWarning,
                               ClientReady, ClientScreenedOut,
                               ClientStateChanged, DirectiveIssued,
                               InstancePreempted,
                               InstancePreemptionWarning, InstanceReady)
from repro.core.policies import Policy
from repro.core.scheduler import FedCostAwareScheduler
from repro.core.strategy import (Checkpoint, Directive, Drain, PreWarm,
                                 ScreenOut, SpinUp, Terminate)


class ClusterManager:
    """Per-client instance ownership between the cloud simulator and
    the round engines (see module docstring)."""

    def __init__(self, sim: CloudSimulator, policy: Policy,
                 profiles: Dict[str, ClientProfile],
                 scheduler: Optional[FedCostAwareScheduler] = None,
                 prewarm_target_of: Optional[
                     Callable[[str], Optional[float]]] = None):
        self.sim = sim
        self.policy = policy
        self.profiles = profiles
        self.scheduler = scheduler
        if prewarm_target_of is not None:
            self._prewarm_target = prewarm_target_of
        elif scheduler is not None:
            self._prewarm_target = scheduler.prewarm_queue.get
        else:
            self._prewarm_target = lambda c: None
        self.instances: Dict[str, Optional[Instance]] = {
            c: None for c in profiles}
        self._standby: Dict[str, Instance] = {}
        self._fresh: Dict[int, bool] = {}       # iid -> no epoch done yet
        self._resume_tokens: Dict[int, Any] = {}  # iid -> engine payload
        self._prewarm_gen: Dict[str, int] = {}
        self._shutdown = False
        sim.bus.subscribe(InstanceReady, self._on_instance_ready)
        sim.bus.subscribe(InstancePreempted, self._on_instance_preempted)
        sim.bus.subscribe(InstancePreemptionWarning,
                          self._on_instance_warning)

    # ------------------------------------------------------------------
    # Requests / termination.
    # ------------------------------------------------------------------
    def request(self, client: str, resume_token: Any = None) -> Instance:
        """Request an instance for `client` in its pinned
        (provider, zone), or the currently-cheapest zone under
        cheapest-zone policies — arbitrated across every provider in
        the market when the policy allows cross-provider placement,
        else only on the market's default provider.

        A live standby (forecast pre-warming) is promoted instead of
        launching fresh: it becomes the tracked instance, inherits the
        resume token, and — if already RUNNING — re-announces itself
        as `ClientReady` immediately, which is exactly the collapsed
        spin-up gap the forecast strategy buys."""
        sb = self._standby.pop(client, None)
        if sb is not None and sb.state in (SPINNING_UP, RUNNING):
            self.instances[client] = sb
            if resume_token is not None:
                self._resume_tokens[sb.iid] = resume_token
            self.sim.bus.publish(
                ClientStateChanged(self.sim.now, client, "spinup"))
            if sb.state == RUNNING:
                self.sim.schedule(self.sim.now,
                                  lambda: self._announce_ready(sb))
            return sb
        zone, provider = self._placement(self.profiles[client])
        inst = self.sim.request_instance(client, zone=zone,
                                         on_demand=self.policy.on_demand,
                                         provider=provider)
        self.instances[client] = inst
        self._fresh[inst.iid] = True
        if resume_token is not None:
            self._resume_tokens[inst.iid] = resume_token
        self.sim.bus.publish(
            ClientStateChanged(self.sim.now, client, "spinup"))
        return inst

    def _placement(self, prof: ClientProfile):
        """Resolve a client's (zone, provider) placement: the pinned
        pair when set, else — under cheapest-zone policies — the
        cheapest zone across the providers the policy allows. A (None,
        None) answer defers to the simulator's own cheapest-zone
        fallback."""
        zone, provider = prof.zone, prof.provider
        if zone is None and self.policy.pick_cheapest_zone:
            z, _ = self.sim.market.cheapest_zone(
                self.sim.now, providers=self._placement_providers())
            zone, provider = z.name, z.provider
        return zone, provider

    def request_standby(self, client: str) -> Optional[Instance]:
        """Spin up a standby replacement next to the client's tracked
        instance (forecast pre-warming). At most one standby per
        client; a no-op (returning the existing one) when a standby is
        already up, and None when the client has nothing to back up."""
        existing = self._standby.get(client)
        if existing is not None:
            return existing
        if self.instances.get(client) is None:
            return None
        zone, provider = self._placement(self.profiles[client])
        inst = self.sim.request_instance(client, zone=zone,
                                         on_demand=self.policy.on_demand,
                                         provider=provider)
        self._standby[client] = inst
        self._fresh[inst.iid] = True
        return inst

    def standby_of(self, client: str) -> Optional[Instance]:
        """The client's standby replacement, or None."""
        return self._standby.get(client)

    def cancel_standby(self, client: str) -> Optional[Instance]:
        """Terminate and drop the client's standby (hazard subsided,
        screening excluded the client, or the run is over)."""
        sb = self._standby.pop(client, None)
        if sb is not None:
            self.sim.terminate(sb)
        return sb

    def _announce_ready(self, inst: Instance) -> None:
        """Publish `ClientReady` for a promoted, already-RUNNING
        standby (its original `InstanceReady` was filtered while it
        waited unpromoted). Stale-guarded like every cluster event."""
        cur = self.instances.get(inst.client)
        if cur is None or cur.iid != inst.iid or inst.state != RUNNING:
            return
        token = self._resume_tokens.pop(inst.iid, None)
        self.sim.bus.publish(ClientReady(
            self.sim.now, inst.client, inst, self.is_fresh(inst.iid),
            token))

    def _placement_providers(self) -> Optional[list]:
        """None (all providers) under cross-provider policies, else the
        market's default provider only."""
        if self.policy.cross_provider:
            return None
        return [self.sim.market.default_provider]

    def terminate(self, client: str) -> Optional[Instance]:
        """Deliberately stop the client's tracked instance (if any) and
        untrack it; returns the instance that was terminated. The
        standby (if any) is left alone — a follow-up `request` promotes
        it, which is what `Drain` relies on."""
        inst = self.instances.get(client)
        if inst is not None:
            self.sim.terminate(inst)
            self.instances[client] = None
        return inst

    def instance_of(self, client: str) -> Optional[Instance]:
        """The client's currently tracked instance, or None."""
        return self.instances.get(client)

    def shutdown(self):
        """Stop honoring queued pre-warm fires and release every
        standby (end of run)."""
        self._shutdown = True
        for c in list(self._standby):
            self.cancel_standby(c)

    @property
    def is_shutdown(self) -> bool:
        """Has the run shut the cluster down?"""
        return self._shutdown

    # ------------------------------------------------------------------
    # Market lookups shared with the strategy layer.
    # ------------------------------------------------------------------
    def spot_price_of(self, client: str) -> float:
        """The $/hr price the client's next epoch would pay: its pinned
        zone's current rate, or the cheapest placement the policy
        allows (what §III-E budget screening prices rounds with)."""
        prof = self.profiles[client]
        if prof.zone is None:
            _, p = self.sim.market.cheapest_zone(
                self.sim.now, providers=self._placement_providers())
            return p
        return self.sim.market.price(prof.zone, self.sim.now,
                                     self.policy.on_demand,
                                     provider=prof.provider)

    # ------------------------------------------------------------------
    # Freshness (cold/warm) bookkeeping.
    # ------------------------------------------------------------------
    def is_fresh(self, iid: int) -> bool:
        """Has instance `iid` completed no epoch yet (cold)?"""
        return self._fresh.get(iid, True)

    def mark_warm(self, iid: int):
        """Record that instance `iid` finished an epoch (warm)."""
        self._fresh[iid] = False

    # ------------------------------------------------------------------
    # Pre-warming (scheduler decision -> future spin-up).
    # ------------------------------------------------------------------
    def schedule_prewarm(self, client: str, t: float):
        """Spin the client's next instance up at `t` (the scheduler's
        `F_s - T_spin_up - T_buffer` target). Re-issuing supersedes the
        previous pre-warm; a queue entry moved later (§III-D) defers
        the fire; `shutdown()` cancels all of them."""
        gen = self._prewarm_gen.get(client, 0) + 1
        self._prewarm_gen[client] = gen

        def fire():
            if self._prewarm_gen.get(client) != gen or self._shutdown:
                return
            # stale if queue entry moved later (§III-D adjustment)
            q_t = self._prewarm_target(client)
            if q_t is not None and q_t > self.sim.now + 1e-6:
                self.schedule_prewarm(client, q_t)
                return
            if self.instances.get(client) is None:
                self.request(client)

        self.sim.schedule(max(t, self.sim.now), fire)

    # ------------------------------------------------------------------
    # Cloud-event translation.
    # ------------------------------------------------------------------
    def _on_instance_ready(self, ev: InstanceReady):
        inst = ev.instance
        client = inst.client
        if self.instances.get(client) is not inst:
            return          # stale or standby: not the tracked instance
        token = self._resume_tokens.pop(inst.iid, None)
        self.sim.bus.publish(ClientReady(
            ev.t, client, inst, self.is_fresh(inst.iid), token))

    def _on_instance_preempted(self, ev: InstancePreempted):
        inst = ev.instance
        client = inst.client
        if self._standby.get(client) is inst:
            del self._standby[client]       # standby reclaimed: silent
            return
        cur = self.instances.get(client)
        if cur is None or cur.iid != inst.iid:
            return                              # stale: already replaced
        self.instances[client] = None
        self.sim.bus.publish(ClientLost(ev.t, client, inst))

    def _on_instance_warning(self, ev: InstancePreemptionWarning):
        """Translate a provider reclaim notice into a client-level
        warning, filtered like every other cloud event: a warning for
        an instance the cluster no longer tracks is dropped."""
        inst = ev.instance
        cur = self.instances.get(inst.client)
        if cur is None or cur.iid != inst.iid:
            return                              # stale: already replaced
        self.sim.bus.publish(ClientPreemptionWarning(
            ev.t, inst.client, inst, ev.reclaim_at))


# ---------------------------------------------------------------------------
# Directive execution (the strategy API's write side).
# ---------------------------------------------------------------------------
class DirectiveExecutor:
    """Applies typed strategy directives (`repro.core.strategy`)
    against the cluster and the bus.

    Execution preserves the exact event orderings the engines used to
    produce inline (Listing-1 termination publishes the "savings"
    state *after* the instance teardown; budget screening publishes
    `BudgetExhausted` before the "idle" mark and teardown), which is
    what keeps pre-redesign golden traces bit-identical.

    With `trace=True` (`FLRunConfig.trace_directives`) every applied
    directive additionally publishes a `DirectiveIssued` event before
    executing — off by default so default streams stay unchanged.
    """

    def __init__(self, cluster: ClusterManager, ckpt_store=None,
                 ckpt_size_mb: float = 0.0, trace: bool = False):
        self.cluster = cluster
        self.bus = cluster.sim.bus
        self.ckpt_store = ckpt_store
        self.ckpt_size_mb = ckpt_size_mb
        self.trace = trace

    @property
    def _now(self) -> float:
        return self.cluster.sim.now

    def apply(self, directives: Sequence[Directive]) -> List[Directive]:
        """Execute `directives` in order; returns them for chaining."""
        for d in directives:
            if self.trace:
                self.bus.publish(DirectiveIssued(
                    self._now, type(d).__name__, d.client,
                    self._detail(d)))
            if isinstance(d, SpinUp):
                self._spin_up(d)
            elif isinstance(d, Terminate):
                self._terminate(d)
            elif isinstance(d, PreWarm):
                self.cluster.schedule_prewarm(d.client, d.at_t)
            elif isinstance(d, Checkpoint):
                self._checkpoint(d)
            elif isinstance(d, Drain):
                self._drain(d)
            elif isinstance(d, ScreenOut):
                self._screen_out(d)
            else:
                raise TypeError(
                    f"unknown directive {type(d).__name__}")
        return list(directives)

    # ------------------------------------------------------------------
    @staticmethod
    def _detail(d: Directive) -> str:
        """Short human-readable argument summary for tracing."""
        if isinstance(d, PreWarm):
            return f"at_t={d.at_t:.1f}"
        if isinstance(d, Terminate) and d.standby:
            return "standby"
        if isinstance(d, Checkpoint):
            return f"remaining={d.remaining_s:.1f}"
        if isinstance(d, Drain):
            return f"remaining={d.resume_token['remaining']:.1f}" \
                if d.resume_token else ""
        if isinstance(d, ScreenOut):
            return f"round={d.round_idx}"
        return ""

    def _spin_up(self, d: SpinUp) -> None:
        """Fresh request when untracked, standby otherwise."""
        if self.cluster.instance_of(d.client) is None:
            self.cluster.request(d.client, resume_token=d.resume_token)
        else:
            self.cluster.request_standby(d.client)

    def _terminate(self, d: Terminate) -> None:
        """Listing-1 idle stop (tracked instance + Fig-4 "savings"
        state), or a standby cancellation."""
        if d.standby:
            self.cluster.cancel_standby(d.client)
            return
        self.cluster.terminate(d.client)
        self.bus.publish(
            ClientStateChanged(self._now, d.client, "savings"))

    def _checkpoint(self, d: Checkpoint) -> None:
        """Persist the warning-window snapshot and publish
        `ClientCheckpointed` (stamped with the writing instance's
        provider, whose `StorageRates` bill the write)."""
        snapshots.save_snapshot(self.ckpt_store, d.client,
                                dict(d.payload or {}))
        inst = self.cluster.instance_of(d.client)
        self.bus.publish(ClientCheckpointed(
            self._now, d.client, d.round_idx, d.progress_s,
            d.remaining_s, d.reclaim_at, self.ckpt_size_mb,
            getattr(inst, "provider", "") or ""))

    def _drain(self, d: Drain) -> None:
        """Vacate the doomed instance; re-request (or promote a
        standby) with the resume token."""
        self.cluster.terminate(d.client)
        self.cluster.request(d.client, resume_token=d.resume_token)

    def _screen_out(self, d: ScreenOut) -> None:
        """§III-E exclusion: `BudgetExhausted` + `ClientScreenedOut`,
        then stop paying for whatever the client still runs."""
        self.bus.publish(BudgetExhausted(self._now, d.client))
        self.bus.publish(
            ClientScreenedOut(self._now, d.client, d.round_idx))
        self.cluster.cancel_standby(d.client)
        if self.cluster.instance_of(d.client) is not None:
            self.bus.publish(
                ClientStateChanged(self._now, d.client, "idle"))
            self.cluster.terminate(d.client)
