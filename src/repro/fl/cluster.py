"""ClusterManager: instance lifecycle for FL clients.

Sits between the cloud simulator and the round engines. It consumes the
cloud-level bus events (`InstanceReady`, `InstancePreempted`,
`InstancePreemptionWarning`), filters out stale ones (an event for an
instance the cluster no longer tracks is dropped here, so engines never
have to guard against races), and re-publishes client-level events
(`ClientReady`, `ClientLost`, `ClientPreemptionWarning`).

Owns, per client:
  * the tracked instance (at most one),
  * freshness (has the instance completed an epoch yet — drives the
    cold/warm duration split and the spin-up observations),
  * pre-warm scheduling with generation counters (a re-issued pre-warm
    invalidates the previous one) honoring §III-D queue adjustments,
  * resume-from-checkpoint requests: `request(..., resume_token=...)`
    stamps the replacement instance so the engine can distinguish a
    recovery ready from a fresh dispatch.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.cloud.simulator import CloudSimulator, Instance
from repro.common.config import ClientProfile
from repro.core.events import (ClientLost, ClientPreemptionWarning,
                               ClientReady, ClientStateChanged,
                               InstancePreempted,
                               InstancePreemptionWarning, InstanceReady)
from repro.core.policies import Policy
from repro.core.scheduler import FedCostAwareScheduler


class ClusterManager:
    """Per-client instance ownership between the cloud simulator and
    the round engines (see module docstring)."""

    def __init__(self, sim: CloudSimulator, policy: Policy,
                 profiles: Dict[str, ClientProfile],
                 scheduler: FedCostAwareScheduler):
        self.sim = sim
        self.policy = policy
        self.profiles = profiles
        self.scheduler = scheduler
        self.instances: Dict[str, Optional[Instance]] = {
            c: None for c in profiles}
        self._fresh: Dict[int, bool] = {}       # iid -> no epoch done yet
        self._resume_tokens: Dict[int, Any] = {}  # iid -> engine payload
        self._prewarm_gen: Dict[str, int] = {}
        self._shutdown = False
        sim.bus.subscribe(InstanceReady, self._on_instance_ready)
        sim.bus.subscribe(InstancePreempted, self._on_instance_preempted)
        sim.bus.subscribe(InstancePreemptionWarning,
                          self._on_instance_warning)

    # ------------------------------------------------------------------
    # Requests / termination.
    # ------------------------------------------------------------------
    def request(self, client: str, resume_token: Any = None) -> Instance:
        """Request a fresh instance for `client` in its pinned
        (provider, zone), or the currently-cheapest zone under
        cheapest-zone policies — arbitrated across every provider in
        the market when the policy allows cross-provider placement,
        else only on the market's default provider."""
        prof = self.profiles[client]
        zone, provider = prof.zone, prof.provider
        if zone is None and self.policy.pick_cheapest_zone:
            z, _ = self.sim.market.cheapest_zone(
                self.sim.now, providers=self._placement_providers())
            zone, provider = z.name, z.provider
        inst = self.sim.request_instance(client, zone=zone,
                                         on_demand=self.policy.on_demand,
                                         provider=provider)
        self.instances[client] = inst
        self._fresh[inst.iid] = True
        if resume_token is not None:
            self._resume_tokens[inst.iid] = resume_token
        self.sim.bus.publish(
            ClientStateChanged(self.sim.now, client, "spinup"))
        return inst

    def _placement_providers(self) -> Optional[list]:
        """None (all providers) under cross-provider policies, else the
        market's default provider only."""
        if self.policy.cross_provider:
            return None
        return [self.sim.market.default_provider]

    def terminate(self, client: str) -> Optional[Instance]:
        """Deliberately stop the client's tracked instance (if any) and
        untrack it; returns the instance that was terminated."""
        inst = self.instances.get(client)
        if inst is not None:
            self.sim.terminate(inst)
            self.instances[client] = None
        return inst

    def instance_of(self, client: str) -> Optional[Instance]:
        """The client's currently tracked instance, or None."""
        return self.instances.get(client)

    def shutdown(self):
        """Stop honoring queued pre-warm fires (end of run)."""
        self._shutdown = True

    # ------------------------------------------------------------------
    # Freshness (cold/warm) bookkeeping.
    # ------------------------------------------------------------------
    def is_fresh(self, iid: int) -> bool:
        """Has instance `iid` completed no epoch yet (cold)?"""
        return self._fresh.get(iid, True)

    def mark_warm(self, iid: int):
        """Record that instance `iid` finished an epoch (warm)."""
        self._fresh[iid] = False

    # ------------------------------------------------------------------
    # Pre-warming (scheduler decision -> future spin-up).
    # ------------------------------------------------------------------
    def schedule_prewarm(self, client: str, t: float):
        """Spin the client's next instance up at `t` (the scheduler's
        `F_s - T_spin_up - T_buffer` target). Re-issuing supersedes the
        previous pre-warm; a queue entry moved later (§III-D) defers
        the fire; `shutdown()` cancels all of them."""
        gen = self._prewarm_gen.get(client, 0) + 1
        self._prewarm_gen[client] = gen

        def fire():
            if self._prewarm_gen.get(client) != gen or self._shutdown:
                return
            # stale if queue entry moved later (§III-D adjustment)
            q_t = self.scheduler.prewarm_queue.get(client)
            if q_t is not None and q_t > self.sim.now + 1e-6:
                self.schedule_prewarm(client, q_t)
                return
            if self.instances.get(client) is None:
                self.request(client)

        self.sim.schedule(max(t, self.sim.now), fire)

    # ------------------------------------------------------------------
    # Cloud-event translation.
    # ------------------------------------------------------------------
    def _on_instance_ready(self, ev: InstanceReady):
        inst = ev.instance
        client = inst.client
        if self.instances.get(client) is not inst:
            return                              # stale: no longer tracked
        token = self._resume_tokens.pop(inst.iid, None)
        self.sim.bus.publish(ClientReady(
            ev.t, client, inst, self.is_fresh(inst.iid), token))

    def _on_instance_preempted(self, ev: InstancePreempted):
        inst = ev.instance
        client = inst.client
        cur = self.instances.get(client)
        if cur is None or cur.iid != inst.iid:
            return                              # stale: already replaced
        self.instances[client] = None
        self.sim.bus.publish(ClientLost(ev.t, client, inst))

    def _on_instance_warning(self, ev: InstancePreemptionWarning):
        """Translate a provider reclaim notice into a client-level
        warning, filtered like every other cloud event: a warning for
        an instance the cluster no longer tracks is dropped."""
        inst = ev.instance
        cur = self.instances.get(inst.client)
        if cur is None or cur.iid != inst.iid:
            return                              # stale: already replaced
        self.sim.bus.publish(ClientPreemptionWarning(
            ev.t, inst.client, inst, ev.reclaim_at))
