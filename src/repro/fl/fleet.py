"""FleetRunner: the vectorized sync-barrier round loop over the
struct-of-arrays fleet core (`repro.cloud.fleet`).

The per-object stack (CloudSimulator heap + ClusterManager + SyncEngine)
prices one Python callback chain per instance lifecycle transition. This
runner replays the *same round discipline* as array sweeps — one batch
of spin-ups, one batch of duration draws, one batch of billing
settlements and one batch of preemption draws per FL round — so a
100k-client cohort round costs a handful of numpy passes.

Semantics mirrored from the per-object path (and pinned by
tests/test_fleet.py: identical `RunResult` totals within 1e-9 on
deterministic configs):

  * sync barrier — the round ends at the slowest participant's finish;
    the next round starts 1.0s later; the final terminate lands 1.0s
    after the last round's barrier.
  * billing — opens at instance-ready, settles at terminate/preempt
    with the provider's min-billing floor + granularity rounding.
  * Listing-1 lifecycle (fedcostaware) — each finisher (except the
    round's last) compares its idle window against its *post-update*
    spin-up EMA; terminated clients pre-warm at `F_s - T_spin - T_buf`.
    The per-client F_s is reconstructed order-exactly: sort finishers
    stably by finish time, then F_s at position k is the max of the
    prefix of actual finishes (<= k) and the suffix of registered
    finish predictions (> k).
  * §III-B EMAs — cold/warm epoch EMAs (NaN = no observation, falling
    back to each other) and the spin-up EMA (prior =
    `CloudConfig.spin_up_mean_s`); resumed (preempted) epochs update
    only the spin-up EMA, exactly like `note_resume_result`.
  * §III-E budget screening (round >= 1) — spent = settled + open
    accrual; estimate = (warm-epoch prediction + spin-up EMA) * $/hr /
    3600; screened clients are permanently excluded and torn down.
  * §III-D preemption recovery — reclaim mid-epoch settles the
    instance, loses work back to the last periodic checkpoint
    (`SchedulerConfig.checkpoint_every_s`), respins, and resumes the
    remaining duration (floor 1.0s); reclaims while idle are absorbed
    at the next dispatch. Preemption delays are drawn per step through
    `PreemptionModel.next_preemption_delays`, anchored at the step's
    start and measured from each instance's ready instant.

Documented fleet-mode approximations (why goldens below
`CloudConfig.fleet_threshold` stay on the per-object path): no
per-instance events — each round publishes one `FleetStepSummary`
(eventlog schema v6, carrying the step's per-client settled dollars in
`client_cost_delta` so replays rebuild `per_client_cost` exactly); no
Fig-4 timeline / Fig-5 cost-curve sampling; no standby instances,
preemption-notice reactions or §III-D pre-warm-queue adjustments;
`RunCompleted.client_costs` stays empty (per-client totals live in
`RunResult.per_client_cost`, built once from the settled array, and on
replay from the summed step deltas).

Cohort sampling (`FLRunConfig.population` + `cohort_size`) draws each
round's participants without replacement from a dedicated RNG lane, so
cohort sequences are reproducible per seed.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from repro.cloud.fleet import (ABSENT, RUNNING, SPINNING, ClientArrays,
                               FleetState)
from repro.cloud.preemption import build_preemption_model
from repro.cloud.pricing import SpotMarket
from repro.common.config import CloudConfig, FLRunConfig, SchedulerConfig
from repro.core.events import EventBus, FleetStepSummary
from repro.core.policies import Policy
from repro.core.strategy import BudgetScreenSpec, LifecycleSpec
from repro.fl.types import RunResult

__all__ = ["FleetRunner", "fleet_supported"]


def fleet_supported(policy: Policy) -> bool:
    """Can `policy` run on the vectorized fleet path? Sync-barrier
    engines with at most Listing-1 + budget-screening strategies and no
    preemption-notice reaction — i.e. Table I's on_demand / spot /
    fedcostaware columns. Everything else (async engines, forecast
    pre-warming, warning checkpoint/drain modes) needs the per-object
    event vocabulary."""
    return (policy.engine == "sync"
            and policy.on_warning == "ignore"
            and all(isinstance(s, (LifecycleSpec, BudgetScreenSpec))
                    for s in policy.strategies))


class FleetRunner:
    """One FL run over the struct-of-arrays core (see module
    docstring). Constructed by `FLCloudRunner` when the fleet path is
    engaged; `run()` returns the same `RunResult` shape as the
    per-object engines."""

    def __init__(self, run_cfg: FLRunConfig, cloud_cfg: CloudConfig,
                 sched_cfg: SchedulerConfig, policy: Policy,
                 market: SpotMarket, bus: EventBus, seed: int):
        if not fleet_supported(policy):
            raise ValueError(
                f"policy {policy.name!r} is not fleet-capable (needs the "
                f"per-object path: sync engine, on_warning='ignore', "
                f"lifecycle/budget strategies only)")
        self.run_cfg = run_cfg
        self.cloud_cfg = cloud_cfg
        self.sched_cfg = sched_cfg
        self.policy = policy
        self.market = market
        self.bus = bus
        self.clients = (ClientArrays.from_population(run_cfg.population)
                        if run_cfg.population is not None
                        else ClientArrays.from_profiles(run_cfg.clients))
        n = self.clients.n
        self.state = FleetState(n, market, policy.on_demand)
        self._model = build_preemption_model(cloud_cfg, market)
        # RNG lanes: independent streams per draw family (the per-object
        # path interleaves sim/engine draws per event; the fleet batches
        # them, so it owns its own lanes — equivalence tests pin totals
        # on deterministic configs, not draw-for-draw streams)
        self._rng_spin = np.random.RandomState(seed + 17)
        self._rng_dur = np.random.RandomState(seed + 101)
        self._rng_pre = np.random.RandomState(seed + 307)
        self._rng_cohort = np.random.RandomState(seed + 211)
        # §III-B estimator state (NaN = unobserved; spin prior as EMA init)
        self.ema_cold = np.full(n, np.nan)
        self.ema_warm = np.full(n, np.nan)
        self.ema_spin = np.full(n, float(cloud_cfg.spin_up_mean_s))
        self._alpha = sched_cfg.ema_alpha
        self.excluded = np.zeros(n, dtype=bool)
        self.lost_work_s = 0.0
        self.per_round_participants: List[List[str]] = []
        # pinned placements resolved once; -1 = policy-driven
        self._pinned_zone = np.full(n, -1, dtype=np.int64)
        for i, pz in enumerate(self.clients.pinned):
            if pz is not None:
                self._pinned_zone[i] = self.state.resolve_zone(pz[0],
                                                               pz[1])

    # ------------------------------------------------------------------
    # Placement / pricing / draws.
    # ------------------------------------------------------------------
    def _providers(self) -> Optional[list]:
        """Provider filter for cheapest-zone arbitration (None = all),
        mirroring `ClusterManager._placement_providers`."""
        if self.policy.cross_provider:
            return None
        return [self.market.default_provider]

    def _request_zones(self, idx: np.ndarray, times) -> np.ndarray:
        """Zone-table index each slot in `idx` launches in at its own
        request time: the pinned zone when set, else the cheapest zone
        the policy allows — one market lookup per *distinct* request
        time (a whole dispatch batch shares one)."""
        k = len(idx)
        times = np.broadcast_to(
            np.asarray(times, dtype=np.float64), (k,))
        out = np.empty(k, dtype=np.int64)
        pinned = self._pinned_zone[idx]
        mask = pinned >= 0
        out[mask] = pinned[mask]
        un = ~mask
        if un.any():
            providers = (self._providers() if self.policy.pick_cheapest_zone
                         else None)
            for t in np.unique(times[un]):
                sel = un & (times == t)
                z, _ = self.market.cheapest_zone(float(t),
                                                 providers=providers)
                out[sel] = self.state.zone_index[(z.provider, z.name)]
        return out

    def _prices_of(self, idx: np.ndarray, t: float) -> np.ndarray:
        """$/hr each client's next epoch would pay at `t` (what §III-E
        screening prices rounds with): pinned zone's current rate, or
        the cheapest placement the policy allows."""
        out = np.empty(len(idx))
        pinned = self._pinned_zone[idx]
        un = pinned < 0
        if un.any():
            _, p = self.market.cheapest_zone(t, providers=self._providers())
            out[un] = p
        for z in np.unique(pinned[pinned >= 0]):
            sel = pinned == z
            prov, zname = self.state.zone_table[int(z)]
            out[sel] = self.market.price(zname, t, self.policy.on_demand,
                                         provider=prov)
        return out

    def _draw_spin(self, k: int) -> np.ndarray:
        """Batch of lognormal spin-up delays (same arithmetic as
        `CloudSimulator.sample_spin_up`)."""
        mu = math.log(self.cloud_cfg.spin_up_mean_s)
        return np.exp(mu + self._rng_spin.randn(k)
                      * self.cloud_cfg.spin_up_sigma)

    def _ema_update(self, arr: np.ndarray, idx: np.ndarray,
                    obs: np.ndarray) -> None:
        """Vectorized EMA fold: first observation seeds the value,
        later ones blend at `SchedulerConfig.ema_alpha` — the exact
        `core.estimator.EMA.update` rule."""
        if len(idx) == 0:
            return
        old = arr[idx]
        arr[idx] = np.where(np.isnan(old), obs,
                            self._alpha * obs + (1 - self._alpha) * old)

    # ------------------------------------------------------------------
    # Between-round sweeps.
    # ------------------------------------------------------------------
    def _promote_ready(self, t: float) -> None:
        """SPINNING instances whose ready time has passed become
        RUNNING (billing opens at their own ready instant; spot slots
        get preemption draws)."""
        st = self.state
        sel = np.nonzero((st.status == SPINNING) & (st.t_ready <= t))[0]
        if len(sel):
            st.activate(sel, self._model, self._rng_pre, t)

    def _reclaim_idle(self, t: float) -> None:
        """Absorb spot reclaims that landed while instances sat idle
        (or pre-warmed) between barriers: settle at the true reclaim
        time, free the slot — the next dispatch re-requests."""
        st = self.state
        sel = np.nonzero((st.status == RUNNING) & (st.preempt_at <= t))[0]
        if len(sel):
            st.preempt(sel, st.preempt_at[sel].copy())

    # ------------------------------------------------------------------
    # §III-E screening.
    # ------------------------------------------------------------------
    def _screen(self, idx: np.ndarray, t: float, r: int) -> np.ndarray:
        """Permanently exclude candidates whose remaining budget cannot
        cover the next epoch's estimate, tearing their instances down
        at `t`; returns the surviving participants."""
        st = self.state
        spent = st.settled[idx] + st.open_cost(t, idx)
        remaining = self.clients.budget[idx] - spent
        warm_pred = np.where(np.isnan(self.ema_warm[idx]),
                             np.where(np.isnan(self.ema_cold[idx]), 0.0,
                                      self.ema_cold[idx]),
                             self.ema_warm[idx])
        est = ((warm_pred + self.ema_spin[idx])
               * self._prices_of(idx, t) / 3600.0)
        keep = remaining >= est
        out = idx[~keep]
        if len(out):
            self.excluded[out] = True
            st.terminate(out, np.full(len(out), t))
        return idx[keep]

    # ------------------------------------------------------------------
    # One FL round.
    # ------------------------------------------------------------------
    def _round(self, r: int, t0: float) -> Optional[float]:
        """Run round `r` starting at `t0`; returns the barrier time
        (slowest finish), or None when nobody participates (the run
        ends at `t0`)."""
        st, ca, cfg = self.state, self.clients, self.sched_cfg
        self._promote_ready(t0)
        self._reclaim_idle(t0)

        active = (ca.join_round <= r) & ~self.excluded
        idx = np.nonzero(active)[0]
        cohort = self.run_cfg.cohort_size
        if cohort is not None and len(idx) > cohort:
            idx = np.sort(self._rng_cohort.choice(idx, size=cohort,
                                                  replace=False))
        if r >= 1 and self.policy.enforce_budgets and len(idx):
            idx = self._screen(idx, t0, r)
        if len(idx) == 0:
            return None
        self.per_round_participants.append([ca.name(i) for i in idx])
        k = len(idx)

        # dispatch: absent slots spin up; pre-warmed-but-booting slots
        # keep their schedule; running slots start training immediately
        need = idx[st.status[idx] == ABSENT]
        if len(need):
            st.request(need, self._request_zones(need, t0),
                       np.full(len(need), t0), self._draw_spin(len(need)))
        includes_spin = st.status[idx] == SPINNING
        cold = st.fresh[idx].copy()
        start = np.where(includes_spin, st.t_ready[idx], t0)

        # registered finish predictions (pre-round EMAs, dispatch time
        # t0 — exactly what `register_dispatch` + `predict_finish` see)
        cold_pred = np.where(np.isnan(self.ema_cold[idx]),
                             np.where(np.isnan(self.ema_warm[idx]), 0.0,
                                      self.ema_warm[idx]),
                             self.ema_cold[idx])
        warm_pred = np.where(np.isnan(self.ema_warm[idx]),
                             np.where(np.isnan(self.ema_cold[idx]), 0.0,
                                      self.ema_cold[idx]),
                             self.ema_warm[idx])
        pred = (t0 + np.where(includes_spin, self.ema_spin[idx], 0.0)
                + np.where(cold, cold_pred, warm_pred))
        spin_ema_pre = self.ema_spin[idx].copy()

        # epoch durations (same lognormal-jitter arithmetic as
        # `BaseEngine._sample_duration`)
        base = ca.warm_mean[idx] * np.where(cold, ca.cold_mult[idx], 1.0)
        dur = base * np.exp(self._rng_dur.randn(k) * ca.jitter[idx])
        finish = start + dur

        # booting slots become RUNNING at their ready instant
        st.activate(idx[includes_spin], self._model, self._rng_pre, t0)

        # §III-D absorption: reclaims landing before a finish settle the
        # instance, lose work back to the last periodic checkpoint,
        # respin and resume the remainder — iterated until no reclaim
        # precedes any finish
        resumed = np.zeros(k, dtype=bool)
        ckpt = cfg.checkpoint_every_s
        guard = 0
        while True:
            hit = np.nonzero(st.preempt_at[idx] <= finish)[0]
            if len(hit) == 0:
                break
            guard += 1
            if guard > 10000:
                raise RuntimeError(
                    "preemption absorption failed to converge")
            gi = idx[hit]
            t_p = st.preempt_at[gi].copy()
            st.preempt(gi, t_p)
            elapsed = t_p - start[hit]
            preserved = (np.floor(elapsed / ckpt) * ckpt if ckpt > 0.0
                         else np.zeros(len(hit)))
            remaining = np.maximum(dur[hit] - preserved, 1.0)
            self.lost_work_s += float(
                np.maximum(elapsed - preserved, 0.0).sum())
            ready = st.request(gi, self._request_zones(gi, t_p), t_p,
                               self._draw_spin(len(gi)))
            st.activate(gi, self._model, self._rng_pre, t0)
            start[hit] = ready
            dur[hit] = remaining
            finish[hit] = ready + remaining
            # §III-D recovery estimate replaces the registered prediction
            pred[hit] = t_p + spin_ema_pre[hit] + remaining
            resumed[hit] = True

        # §III-B updates at each finish: full epochs feed the cold/warm
        # EMAs; resumed (partial) epochs feed only the spin-up EMA; any
        # finish on a fresh instance contributes its spin-up observation
        cold_at_finish = st.fresh[idx].copy()
        full = ~resumed
        spin_obs = st.t_ready[idx] - st.t_request[idx]
        self._ema_update(self.ema_cold, idx[full & cold],
                         (finish - start)[full & cold])
        self._ema_update(self.ema_warm, idx[full & ~cold],
                         (finish - start)[full & ~cold])
        self._ema_update(self.ema_spin, idx[cold_at_finish],
                         spin_obs[cold_at_finish])
        st.fresh[idx] = False

        # Listing-1 lifecycle at each finish (order-exact, vectorized)
        if (self.policy.manage_lifecycle
                and r >= cfg.calibration_rounds and k > 1):
            self._lifecycle(idx, finish, pred, r)

        f_s = float(finish.max())
        self._summary(f_s, r, k)
        return f_s

    def _lifecycle(self, idx: np.ndarray, finish: np.ndarray,
                   pred: np.ndarray, r: int) -> None:
        """Vectorized `evaluate_termination` for every finisher of the
        round, in finish order: F_s at sorted position p is
        max(prefix-max of actual finishes <= p, suffix-max of
        registered predictions > p); a finisher whose idle window beats
        its (post-update) spin-up EMA by more than `t_threshold_s`
        terminates at its finish and — when more rounds remain —
        pre-warms at `F_s - T_spin - T_buffer` (never before its own
        finish). The round's last finisher never evaluates (the barrier
        has already closed)."""
        st, cfg = self.state, self.sched_cfg
        order = np.argsort(finish, kind="stable")
        f_sorted = finish[order]
        prefix = np.maximum.accumulate(f_sorted)
        pred_sorted = pred[order]
        sfx = np.full(len(order), -np.inf)
        if len(order) > 1:
            sfx[:-1] = np.maximum.accumulate(
                pred_sorted[::-1])[::-1][1:]
        f_s_each = np.maximum(prefix, sfx)
        idle = f_s_each - f_sorted
        t_spin = self.ema_spin[idx][order]
        term = (idle - t_spin) > cfg.t_threshold_s
        term[-1] = False
        if not term.any():
            return
        gi = idx[order[term]]
        st.terminate(gi, f_sorted[term])
        if r + 1 < self.run_cfg.n_epochs:
            pw_t = np.maximum(f_s_each[term] - t_spin[term]
                              - cfg.t_buffer_s, f_sorted[term])
            st.request(gi, self._request_zones(gi, pw_t), pw_t,
                       self._draw_spin(len(gi)))

    def _summary(self, t: float, step_idx: int, k: int) -> None:
        """Publish the round's `FleetStepSummary` (schema v6): settled
        dollars + lifecycle counts since the previous summary, the
        informational open accrual at the barrier, and the per-client
        attribution of the settled dollars (only clients that settled
        this step — names materialize per touched slot, not per
        fleet)."""
        cost_delta, by_zone, touched, amounts = self.state.flush_step()
        self.bus.publish(FleetStepSummary(
            t, step_idx, k,
            int(sum(z.get("spinups", 0.0) for z in by_zone.values())),
            int(sum(z.get("preemptions", 0.0) for z in by_zone.values())),
            int(sum(z.get("terminations", 0.0)
                    for z in by_zone.values())),
            cost_delta,
            float(self.state.open_cost(t).sum()),
            by_zone,
            {self.clients.name(int(i)): float(a)
             for i, a in zip(touched, amounts)}))

    # ------------------------------------------------------------------
    def run(self) -> RunResult:
        """Execute every round and the final teardown; returns the
        fleet-mode `RunResult` (empty timeline/cost-curve — see module
        docstring)."""
        t = 0.0
        completed = 0
        for r in range(self.run_cfg.n_epochs):
            end = self._round(r, t)
            if end is None:
                break
            completed += 1
            t = end + 1.0
        # final teardown at t: absorb in-flight readies/reclaims, then
        # terminate everything still up (min-billing floors apply)
        st = self.state
        self._promote_ready(t)
        self._reclaim_idle(t)
        alive = np.nonzero(st.status != ABSENT)[0]
        st.terminate(alive, np.full(len(alive), t))
        self._summary(t, completed, 0)

        names = self.clients.names()
        per_client = {names[i]: float(st.settled[i])
                      for i in range(self.clients.n)}
        return RunResult(
            total_cost=float(st.settled.sum()),
            per_client_cost=per_client,
            makespan_s=t,
            timeline=[], cost_curve=[],
            rounds_completed=completed,
            excluded_clients=[names[i]
                              for i in np.nonzero(self.excluded)[0]],
            per_round_participants=self.per_round_participants,
            lost_work_s=self.lost_work_s,
            n_preemptions=st.n_preemptions)
