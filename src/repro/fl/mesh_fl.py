"""FL-in-the-mesh: federated learning mapped onto the multi-pod mesh.

TPU-idiomatic adaptation of the paper's client/server communication
pattern (DESIGN.md §2): each *pod* of the ``(pod, data, model)`` mesh
hosts one FL client. Client-stacked parameters carry a leading
``fl_clients`` dim sharded on the ``pod`` axis, so

  * local training steps touch only ``data``/``model`` axes (zero
    cross-pod traffic — exactly the paper's "no data leaves the client"),
  * the synchronous FedAvg round boundary is a single weighted reduction
    over the client dim, which GSPMD lowers to a cross-pod all-reduce.

Two aggregation paths:
  fedavg_sync            — plain weighted average (bf16 collective)
  fedavg_sync_compressed — int8-quantized ring aggregation via shard_map
                           + collective_permute (beyond-paper optimization;
                           ~4x less cross-pod traffic, see EXPERIMENTS §Perf)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import compat
from repro.models import lm
from repro.sharding.rules import ShardingCtx


# ---------------------------------------------------------------------------
# Plain FedAvg over the client (pod) axis.
# ---------------------------------------------------------------------------
def fedavg_sync(params_stacked, weights):
    """params_stacked: (C, ...) pytree; weights: (C,). Returns the averaged
    params re-broadcast to every client slot (all clients leave the round
    with the identical global model, as synchronous FL requires)."""
    w = (weights / jnp.sum(weights)).astype(jnp.float32)

    def avg(p):
        m = jnp.einsum("c...,c->...", p.astype(jnp.float32), w)
        return jnp.broadcast_to(m[None].astype(p.dtype), p.shape)

    return jax.tree.map(avg, params_stacked)


# ---------------------------------------------------------------------------
# Compressed FedAvg: int8 ring all-reduce over the pod axis (shard_map).
# ---------------------------------------------------------------------------
def _quantize_int8(x):
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def fedavg_sync_compressed(params_stacked, global_params, weights,
                           mesh: Mesh, n_pods: int,
                           stacked_specs=None, global_specs=None):
    """Aggregate client *deltas* (client - global) in int8 over a ring of
    pods, then add back to the global model.

    Deltas (not raw weights) are quantized — their dynamic range is ~100x
    smaller after a round of local training, so int8 error is negligible
    (validated in tests against the exact average).

    CRITICAL sharding note (hypothesis->refuted->fixed, EXPERIMENTS §Perf):
    the shard_map specs must PRESERVE each leaf's within-pod (data, model)
    sharding — mapping only the `pod` axis and leaving the rest None makes
    shard_map replicate the full tensor per device (a 16GB all-gather for
    phi3). With shard-preserving specs the ring permutes only the local
    int8 shard (params/chips_per_pod bytes per step).
    """
    wn = (weights / jnp.sum(weights)).astype(jnp.float32)

    def ring_avg(delta_stk, w_all):
        # Executes per-device: delta_stk is this device's local shard of
        # its pod's client delta, client dim sharded to size 1.
        d = delta_stk[0]
        my_w = w_all[0]                    # (1,) local slice of weights
        q, scale = _quantize_int8(d)
        acc = _dequantize_int8(q, scale) * my_w
        perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]
        for _ in range(n_pods - 1):
            q = lax.ppermute(q, "pod", perm)
            scale = lax.ppermute(scale, "pod", perm)
            my_w = lax.ppermute(my_w, "pod", perm)
            acc = acc + _dequantize_int8(q, scale) * my_w
        # every pod now holds the identical weighted average of its shard
        return acc[None].astype(delta_stk.dtype)

    def one_leaf(p_stk, g, spec_stk):
        delta = p_stk.astype(jnp.float32) - g.astype(jnp.float32)[None]
        fn = compat.shard_map(
            ring_avg, mesh=mesh,
            in_specs=(spec_stk, P("pod")),
            out_specs=spec_stk,
            check_vma=False)
        avg_delta = fn(delta, wn)
        return (g.astype(jnp.float32)[None]
                + jnp.broadcast_to(avg_delta, p_stk.shape)
                ).astype(p_stk.dtype)

    if stacked_specs is None:
        stacked_specs = jax.tree.map(
            lambda p: P("pod", *([None] * (p.ndim - 1))), params_stacked)
    return jax.tree.map(one_leaf, params_stacked, global_params,
                        stacked_specs)


# ---------------------------------------------------------------------------
# The full FL round step (lowered in the dry-run as the paper-representative
# program: N local steps then the synchronous aggregation barrier).
# ---------------------------------------------------------------------------
def make_fl_round_step(cfg, opt, shard: ShardingCtx, local_steps: int,
                       compressed: bool = False, mesh: Optional[Mesh] = None,
                       n_pods: int = 1, stacked_specs=None):
    """Returns round_step(params_stacked, opt_mu_stacked, batches, weights).

    params_stacked : (C, ...) model params, client dim on the pod axis
    batches        : dict of (C, local_steps, B_local, S) arrays
    weights        : (C,) FedAvg weights (client sample counts)
    """

    def local_train(params, mu, client_batches):
        def step(carry, batch):
            p, m = carry
            loss, g = jax.value_and_grad(
                lambda pp: lm.loss_fn(pp, cfg, batch, shard=shard))(p)
            # SGD-momentum inline (keeps per-client opt state to one slot)
            m = jax.tree.map(
                lambda mi, gi: 0.9 * mi + gi.astype(jnp.float32), m, g)
            p = jax.tree.map(
                lambda pi, mi: (pi.astype(jnp.float32)
                                - opt * mi).astype(pi.dtype), p, m)
            return (p, m), loss

        (params, mu), losses = lax.scan(step, (params, mu), client_batches)
        return params, mu, jnp.mean(losses)

    def round_step(params_stacked, mu_stacked, batches, weights):
        global_params = jax.tree.map(lambda p: p[0], params_stacked)
        new_p, new_mu, losses = jax.vmap(local_train)(
            params_stacked, mu_stacked, batches)
        if compressed:
            agg = fedavg_sync_compressed(new_p, global_params, weights,
                                         mesh, n_pods,
                                         stacked_specs=stacked_specs)
        else:
            agg = fedavg_sync(new_p, weights)
        return agg, new_mu, losses

    return round_step


def stack_params_for_clients(params, n_clients: int):
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_clients,) + p.shape), params)
