"""FL client: owns a local dataset shard and a jitted local-train step.

The client periodically checkpoints its TrainState to the (simulated)
cloud object store — the paper's fault-tolerance mechanism (§III-D) — and
can resume a local epoch from the latest checkpoint after preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.algorithms import fedprox_penalty
from repro.optim.optimizers import Optimizer
from repro.checkpoint.ckpt import Checkpointer


@dataclasses.dataclass
class LocalMetrics:
    loss: float
    n_batches: int
    n_samples: int


class FLClient:
    def __init__(self, name: str, apply_fn: Callable, optimizer: Optimizer,
                 data_fn: Callable[[int], Iterator[Tuple[np.ndarray, np.ndarray]]],
                 n_samples: int,
                 algorithm: str = "fedavg", fedprox_mu: float = 0.01,
                 checkpointer: Optional[Checkpointer] = None,
                 checkpoint_every: int = 10):
        self.name = name
        self.apply_fn = apply_fn
        self.opt = optimizer
        self.data_fn = data_fn
        self.n_samples = n_samples
        self.algorithm = algorithm
        self.mu = fedprox_mu
        self.ckpt = checkpointer
        self.checkpoint_every = checkpoint_every
        self._step = self._build_step()

    def _build_step(self):
        def loss_fn(params, x, y, global_params):
            logits = self.apply_fn(params, x)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))
            if self.algorithm == "fedprox":
                ce = ce + fedprox_penalty(params, global_params, self.mu)
            return ce

        @jax.jit
        def step(params, opt_state, x, y, global_params):
            loss, grads = jax.value_and_grad(loss_fn)(
                params, x, y, global_params)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return params, opt_state, loss

        return step

    # ------------------------------------------------------------------
    def train_epoch(self, global_params, round_idx: int,
                    resume_from_batch: int = 0):
        """One local epoch from `global_params`; returns (params, metrics).

        Checkpoints every `checkpoint_every` batches; `resume_from_batch`
        restarts mid-epoch after a (simulated) preemption.
        """
        params = global_params
        opt_state = self.opt.init(params)
        start = 0
        if resume_from_batch > 0 and self.ckpt is not None:
            template = {"params": params, "opt_state": opt_state, "batch": 0}
            saved = self.ckpt.restore(self._key(round_idx), template=template)
            if saved is not None:
                params, opt_state = saved["params"], saved["opt_state"]
                start = int(saved["batch"])
        losses = []
        nb = 0
        for bi, (x, y) in enumerate(self.data_fn(round_idx)):
            if bi < start:
                continue
            params, opt_state, loss = self._step(
                params, opt_state, jnp.asarray(x), jnp.asarray(y),
                global_params)
            losses.append(float(loss))
            nb += 1
            if self.ckpt is not None and (bi + 1) % self.checkpoint_every == 0:
                self.ckpt.save(self._key(round_idx), {
                    "params": params, "opt_state": opt_state,
                    "batch": bi + 1})
        metrics = LocalMetrics(
            float(np.mean(losses)) if losses else float("nan"),
            nb, self.n_samples)
        return params, metrics

    def _key(self, round_idx: int) -> str:
        return f"client={self.name}/round={round_idx}"
