"""Synchronous FL server + a TrainerHooks adapter binding real JAX
training into the cloud runner (so a FedCostAware run produces an actual
trained global model while the simulator produces the dollar costs).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.fl.algorithms import ServerState
from repro.fl.client import FLClient
from repro.fl.runner import TrainerHooks


class FederatedServer:
    """Plain synchronous server (no cloud): used in unit tests and as the
    aggregation engine inside the cloud-attached trainer below."""

    def __init__(self, init_params, algorithm: str = "fedavg",
                 server_momentum: float = 0.9):
        self.state = ServerState(init_params, algorithm, server_momentum)
        self.history: List[Dict] = []

    @property
    def params(self):
        return self.state.params

    def run_round(self, clients: List[FLClient], round_idx: int):
        updates, weights, losses = [], [], []
        for c in clients:
            p, m = c.train_epoch(self.params, round_idx)
            updates.append(p)
            weights.append(m.n_samples)
            losses.append(m.loss)
        self.state.aggregate(updates, weights)
        rec = {"round": round_idx,
               "mean_client_loss": float(np.mean(losses))}
        self.history.append(rec)
        return rec

    def fit(self, clients: List[FLClient], n_rounds: int):
        for r in range(n_rounds):
            self.run_round(clients, r)
        return self.history


class JaxTrainerHooks(TrainerHooks):
    """Adapter: the cloud runner calls `run_local`/`aggregate` as simulated
    time advances; we execute the corresponding real JAX computation."""

    def __init__(self, server: FederatedServer, clients: Dict[str, FLClient]):
        self.server = server
        self.clients = clients
        self._pending: Dict[str, object] = {}
        self._weights: Dict[str, float] = {}
        self._losses: Dict[str, float] = {}

    def run_local(self, client: str, round_idx: int) -> None:
        c = self.clients[client]
        params, metrics = c.train_epoch(self.server.params, round_idx)
        self._pending[client] = params
        self._weights[client] = metrics.n_samples
        self._losses[client] = metrics.loss

    @staticmethod
    def staleness_discount(staleness: int) -> float:
        """FedBuff (arXiv:2106.06639) polynomial staleness weight: a
        fresh update keeps its full sample weight, an update `s` rounds
        stale is discounted by 1/sqrt(1+s)."""
        return 1.0 / math.sqrt(1.0 + max(staleness, 0))

    def aggregate(self, participants: List[str], round_idx: int,
                  staleness: Optional[Dict[str, int]] = None) -> None:
        stale = staleness or {}
        ups = [self._pending[c] for c in participants if c in self._pending]
        ws = [self._weights[c] * self.staleness_discount(stale.get(c, 0))
              for c in participants if c in self._pending]
        if ups:
            self.server.state.aggregate(ups, ws)
            self.server.history.append({
                "round": round_idx,
                "mean_client_loss": float(np.mean(
                    [self._losses[c] for c in participants
                     if c in self._losses]))})
        self._pending.clear()
        self._weights.clear()
        self._losses.clear()
