"""SyncEngine: the paper's synchronous round barrier (Table I).

Behavior-preserving port of the seed `FLCloudRunner` round logic — for a
fixed seed it schedules the identical event sequence, so `RunResult`
totals for the on_demand / spot / fedcostaware policies match the
pre-refactor values (pinned by tests/test_engines.py). One deliberate
deviation: the seed's preemption recovery ignored a client's pinned
zone under cheapest-zone policies (recovering in the cheapest zone);
`ClusterManager.request` now honors the pin on every request, initial
or recovery.

One FL round dispatches every participant, waits for all results (the
synchronous barrier), aggregates, then starts the next round. The
engine itself makes no scheduling decisions: results, dispatches and
recoveries are reported to the `StrategyStack`, whose components
(Listing-1 lifecycle, §III-E budget screening — `repro.core.strategy`)
answer with directives the `DirectiveExecutor` applies.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.cloud.simulator import RUNNING, SPINNING_UP
from repro.core.events import ClientLost, ClientReady
from repro.fl.engines.base import BaseEngine, EngineContext


class SyncEngine(BaseEngine):
    name = "sync"

    def __init__(self, ctx: EngineContext):
        super().__init__(ctx)
        self._pending_task: Dict[str, Optional[int]] = {}  # client->round
        self._resumed: set = set()
        self._round_pending: set = set()
        self._participants: List[str] = []

    # ------------------------------------------------------------------
    def start(self):
        self.sim.schedule(0.0, lambda: self._start_round(0))

    # ------------------------------------------------------------------
    # Round lifecycle.
    # ------------------------------------------------------------------
    def _start_round(self, r: int):
        if r >= self.run_cfg.n_epochs:
            self._finish_run()
            return
        self.strategies.begin_round(r)
        # elastic scaling: clients may join at a later round (§V future
        # work); budget exhaustion below is the symmetric leave path.
        clients = [c for c, p in self.profiles.items()
                   if p.join_round <= r]
        if r >= 1:
            clients = self._screen_round(r, clients)
        if not clients:
            # nobody makes it into round r: it never ran, so leave
            # _round_idx at the last *completed* round (keeps
            # rounds_completed == #RoundCompleted events).
            self._finish_run()
            return
        self._round_idx = r
        self._participants = clients
        self.per_round_participants.append(list(clients))
        self._round_pending = set(clients)
        self._publish_round_started(r, clients)
        for c in clients:
            self._dispatch(c, r)

    def _dispatch(self, c: str, r: int):
        inst = self.cluster.instance_of(c)
        t = self.sim.now
        if inst is not None and inst.state == RUNNING:
            cold = self.cluster.is_fresh(inst.iid)
            self.strategies.note_dispatch(c, t, cold, False)
            self._begin_training(c, cold)
        elif inst is not None and inst.state == SPINNING_UP:
            # pre-warmed instance still booting: task queued until ready
            self._pending_task[c] = r
            self.strategies.note_dispatch(c, t, True, True)
        else:
            self._pending_task[c] = r
            self.strategies.note_dispatch(c, t, True, True)
            self.cluster.request(c)

    def _on_client_ready(self, ev: ClientReady):
        c = ev.client
        if ev.resume_token is not None:
            self._resume(c, ev)
        elif self._pending_task.get(c) is not None:
            self._pending_task[c] = None
            self._begin_training(c, cold=True)
        else:
            self._mark(c, "idle")  # pre-warmed, waits for next round

    def _is_training(self, c: str) -> bool:
        """Mid-epoch iff the round still owes `c` a result and its
        tracked instance is RUNNING (a resuming client's replacement is
        still SPINNING_UP, an aggregated client left `_round_pending`,
        an uploading client's epoch compute is already done)."""
        inst = self.cluster.instance_of(c)
        return (c in self._round_pending and c in self._train_start
                and c not in self._uploading
                and inst is not None and inst.state == RUNNING)

    # ------------------------------------------------------------------
    # Local training execution (simulated duration; real JAX via hooks).
    # ------------------------------------------------------------------
    def _begin_training(self, c: str, cold: bool):
        r = self._round_idx
        dur = self._sample_duration(c, cold)
        self._train_start[c] = self.sim.now
        self._train_duration[c] = dur
        self._mark(c, "training")
        iid = self.cluster.instance_of(c).iid
        self.sim.schedule_in(dur, lambda: self._finish_training(c, r, iid))

    def _finish_training(self, c: str, r: int, iid: int):
        inst = self.cluster.instance_of(c)
        if inst is None or inst.iid != iid or r != self._round_idx:
            return                                  # stale (preempted)
        if c not in self._round_pending:
            return
        self.strategies.invalidate_ckpt(c)  # epoch done: snapshot stale
        t = self.sim.now
        dur = t - self._train_start[c]
        cold = self.cluster.is_fresh(inst.iid)
        spin_obs = None
        if cold and inst.t_ready is not None:
            spin_obs = inst.t_ready - inst.t_request
        self.cluster.mark_warm(inst.iid)
        if c in self._resumed:
            # Partial (resumed) epochs would corrupt the epoch-time EMAs;
            # only the spin-up observation is still valid.
            self._resumed.discard(c)
            self.strategies.note_resume_result(c, t, spin_obs)
        else:
            self.strategies.note_result(c, t, dur, cold, spin_obs)
        if self.hooks:
            self.hooks.run_local(c, r)
        if self.comms is not None:
            self._begin_upload(c, r)
            return
        self._complete_result(c, r)

    def _begin_upload(self, c: str, r: int):
        """Comms modeling: the finished update occupies the client's
        uplink before the barrier can count it. The update itself is
        already committed (`run_local` buffered it), so a reclaim
        mid-upload loses no work — only the modeled transfer time
        stretches the round."""
        xfer = self._publish_update_sent(c, r)
        if xfer <= 0.0:
            self._complete_result(c, r)
            return
        self._uploading.add(c)
        self._mark(c, "uploading")
        self.sim.schedule_in(xfer, lambda: self._finish_upload(c, r))

    def _finish_upload(self, c: str, r: int):
        self._uploading.discard(c)
        if r != self._round_idx or c not in self._round_pending:
            return                                  # stale (run moved on)
        self._complete_result(c, r)

    def _complete_result(self, c: str, r: int):
        """The barrier receives `c`'s round-`r` update: release the
        client and end the round when it was the last one owed."""
        self._round_pending.discard(c)
        self._mark(c, "idle")

        if self._round_pending:
            more = (r + 1) < self.run_cfg.n_epochs
            self.strategies.client_result(c, self.sim.now, more)

        if not self._round_pending:
            self._end_round(r)

    # ------------------------------------------------------------------
    # Preemption (§III-D).
    # ------------------------------------------------------------------
    def _on_client_lost(self, ev: ClientLost):
        c = ev.client
        was_training = (c in self._round_pending and c in self._train_start
                        and c not in self._uploading)
        if not was_training:
            # idle / pre-warmed / mid-upload instance lost: an uploading
            # client's update is already committed (no redo) — the
            # upload completes on schedule; next dispatch re-requests
            self._mark(c, "savings")
            return
        # Progress up to the best surviving checkpoint survives: the
        # warning-window snapshot when the provider's notice let us
        # write one (§III-D fault tolerance + notice-aware extension),
        # else the last periodic checkpoint. The client reloads from
        # cloud storage and resumes mid-epoch.
        remaining, source = self._preemption_remaining(c)
        self.note_lost_work(c, remaining)
        r = self._round_idx
        self.cluster.request(
            c, resume_token={"round": r, "remaining": remaining,
                             "source": source})
        self.strategies.recovered(c, remaining)

    def after_drain(self, c: str, remaining: float):
        """Drain vacates the instance and re-requests immediately —
        the same recovery shape as a reclaim, so the peers' pre-warm
        targets move by the same §III-D adjustment (otherwise they
        would spin up at their original targets and idle at the
        barrier while `c` redoes `remaining` seconds)."""
        self.strategies.recovered(c, remaining)

    def _resume(self, c: str, ev: ClientReady):
        tok = ev.resume_token
        if tok["round"] != self._round_idx:
            return
        remaining = tok["remaining"]
        self._resumed.add(c)
        self._train_start[c] = self.sim.now
        self._train_duration[c] = remaining
        if tok.get("source") == "warning":
            self._publish_resumed_from_checkpoint(
                c, self._round_idx, remaining)
        self._mark(c, "training")
        r = self._round_idx
        iid = ev.instance.iid
        self.sim.schedule_in(
            remaining, lambda: self._finish_training(c, r, iid))

    # ------------------------------------------------------------------
    def _end_round(self, r: int):
        # barrier semantics: every update aggregated here is fresh
        self._call_aggregate(list(self._participants), r)
        snap = self._cost_snapshot()
        self._record_costs(snap)
        self._publish_round_completed(r, self._participants, snap)
        self.sim.schedule_in(1.0, lambda: self._start_round(r + 1))

    def _finish_run(self):
        self._done = True
        self.cluster.shutdown()
        for c in self.profiles:
            if self.cluster.instance_of(c) is not None:
                self.cluster.terminate(c)
            self._mark(c, "done")
        self._record_costs()
