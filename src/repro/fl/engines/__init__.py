"""RoundEngine registry.

Policies name their engine by registry key (`Policy.engine`); the
composition root resolves it here. To add a new round discipline,
subclass `BaseEngine`, register it, and point a policy at it — no
changes to the cloud, cluster, or accounting layers required.
"""
from __future__ import annotations

from typing import Dict, Type

from repro.fl.engines.base import BaseEngine, EngineContext
from repro.fl.engines.sync import SyncEngine
from repro.fl.engines.async_buffered import AsyncBufferedEngine

ENGINES: Dict[str, Type[BaseEngine]] = {
    "sync": SyncEngine,
    "async_buffered": AsyncBufferedEngine,
    "fedbuff": AsyncBufferedEngine,       # alias: the algorithm's name
}


def get_engine(name: str) -> Type[BaseEngine]:
    """Resolve a registry key to an engine class (KeyError lists the
    known keys)."""
    try:
        return ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown round engine {name!r}; known: {sorted(ENGINES)}")


__all__ = ["BaseEngine", "EngineContext", "SyncEngine",
           "AsyncBufferedEngine", "ENGINES", "get_engine"]
