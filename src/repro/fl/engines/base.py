"""RoundEngine protocol + the shared engine machinery.

A `RoundEngine` owns the FL-round semantics of a run: when clients are
dispatched, what constitutes a completed round, and when aggregation
fires. Engines are driven entirely by client-level bus events
(`ClientReady`, `ClientLost`, `ClientPreemptionWarning`) plus the
simulator clock — they never talk to raw instance callbacks, which is
what makes new round disciplines (async buffering, straggler cut-offs,
hierarchical rounds) addable without touching the cloud or cluster
layers.

Contract:
  * `start()` schedules the initial work at t=0; the composition root
    then drains the simulator.
  * `result()` is called after the event heap drains and returns the
    engine's `RunResult`.

Preemption-notice handling (`Policy.on_warning`, docs/events.md) is
shared here: when a provider's reclaim warning reaches a client that is
mid-epoch, the engine can snapshot its training state to the checkpoint
store inside the notice window ("checkpoint"), additionally terminate
and re-request before the reclaim lands ("drain"), or do nothing
("ignore", the historical lost-work behavior). Subclasses opt in by
implementing `_is_training` and maintaining the `_train_start` /
`_train_duration` bookkeeping both built-in engines already keep.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import snapshots
from repro.checkpoint.store import MemoryStore, ObjectStore
from repro.cloud.accounting import CostAccountant
from repro.cloud.simulator import RUNNING, CloudSimulator
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 SchedulerConfig)
from repro.core.events import (BudgetExhausted, ClientCheckpointed,
                               ClientLost, ClientPreemptionWarning,
                               ClientReady, ClientResumedFromCheckpoint,
                               ClientStateChanged, RoundCompleted,
                               RoundStarted)
from repro.core.policies import Policy
from repro.core.scheduler import FedCostAwareScheduler
from repro.fl.cluster import ClusterManager
from repro.fl.telemetry import TimelineRecorder
from repro.fl.types import RunResult, TrainerHooks


@dataclasses.dataclass
class EngineContext:
    """Everything a round engine needs, wired by the composition root."""
    run_cfg: FLRunConfig
    cloud_cfg: CloudConfig
    sched_cfg: SchedulerConfig
    policy: Policy
    sim: CloudSimulator
    cluster: ClusterManager
    scheduler: FedCostAwareScheduler
    accountant: CostAccountant
    timeline: TimelineRecorder
    rng: np.random.RandomState
    hooks: Optional[TrainerHooks] = None
    ckpt_store: Optional[ObjectStore] = None   # None -> private MemoryStore


class BaseEngine:
    """Shared state + helpers; subclasses implement the round discipline."""

    name = "base"

    def __init__(self, ctx: EngineContext):
        self.ctx = ctx
        self.run_cfg = ctx.run_cfg
        self.cloud_cfg = ctx.cloud_cfg
        self.sched_cfg = ctx.sched_cfg
        self.policy = ctx.policy
        self.sim = ctx.sim
        self.cluster = ctx.cluster
        self.scheduler = ctx.scheduler
        self.accountant = ctx.accountant
        self.timeline = ctx.timeline
        self.hooks = ctx.hooks
        self._rng = ctx.rng
        self.ckpt_store = ctx.ckpt_store or MemoryStore()
        self.profiles: Dict[str, ClientProfile] = {
            c.name: c for c in ctx.run_cfg.clients}
        self.cost_curve: List[dict] = []
        self.per_round_participants: List[List[str]] = []
        self.excluded: List[str] = []
        self._round_idx = -1
        self._done = False
        self._makespan: Optional[float] = None
        # notice-aware checkpointing state + resilience metrics
        self._warning_ckpt: Dict[str, dict] = {}   # client -> snapshot
        self.lost_work_s = 0.0
        self.n_preemptions = 0
        self.sim.bus.subscribe(ClientLost, self._count_client_lost)
        self.sim.bus.subscribe(ClientReady, self._on_client_ready)
        self.sim.bus.subscribe(ClientLost, self._on_client_lost)
        self.sim.bus.subscribe(ClientPreemptionWarning,
                               self._on_client_warning)

    # ------------------------------------------------------------------
    # Round discipline (subclass responsibility).
    # ------------------------------------------------------------------
    def start(self):
        """Schedule the engine's initial work at t=0; the composition
        root then drains the simulator."""
        raise NotImplementedError

    def _on_client_ready(self, ev: ClientReady):
        raise NotImplementedError

    def _on_client_lost(self, ev: ClientLost):
        raise NotImplementedError

    def _is_training(self, c: str) -> bool:
        """Is `c` mid-epoch on a RUNNING instance right now? Gates the
        preemption-warning path; engines that keep the shared
        `_train_start`/`_train_duration` bookkeeping override this.
        The conservative default opts an engine out of notice-aware
        checkpointing entirely (warnings no-op)."""
        return False

    # ------------------------------------------------------------------
    # Shared helpers.
    # ------------------------------------------------------------------
    def _sample_duration(self, c: str, cold: bool) -> float:
        prof = self.profiles[c]
        base = prof.mean_epoch_s * (prof.cold_multiplier if cold else 1.0)
        jit = float(np.exp(self._rng.randn() * prof.jitter))
        return base * jit

    def _checkpoint_remaining(self, c: str, train_start: float,
                              train_duration: float) -> float:
        """§III-D: work since the last periodic checkpoint is lost on
        preemption; returns the epoch time still owed after a resume."""
        elapsed = max(self.sim.now - train_start, 0.0)
        ck = self.sched_cfg.checkpoint_every_s
        preserved = math.floor(elapsed / ck) * ck
        return max(train_duration - preserved, 1.0)

    # ------------------------------------------------------------------
    # Preemption-notice handling (shared across engines).
    # ------------------------------------------------------------------
    def _on_client_warning(self, ev: ClientPreemptionWarning):
        """Provider reclaim notice for a tracked client. Under the
        "checkpoint"/"drain" policies, start writing a training-state
        snapshot if (a) the client is actually mid-epoch and (b) the
        write can finish inside the notice window; otherwise the
        warning is informational and the reclaim falls back to
        periodic-checkpoint (lost-work) semantics."""
        mode = self.policy.on_warning
        if mode == "ignore" or self._done:
            return
        c = ev.client
        inst = self.cluster.instance_of(c)
        if inst is None or inst.iid != ev.instance.iid:
            return                              # stale: already replaced
        if not self._is_training(c):
            return                              # idle/pre-warmed: no state
        write_s = self.sched_cfg.warning_ckpt_write_s
        if ev.reclaim_at - self.sim.now + 1e-9 < write_s:
            return      # window too short: checkpoint cannot land
        # the snapshot captures progress at write *start*; work done
        # during the write itself is not in it (and is lost on reclaim)
        epoch_started = self._train_start[c]
        progress_s = self.sim.now - epoch_started
        self.sim.schedule_in(write_s, lambda: (
            self._complete_warning_checkpoint(c, ev.instance, mode,
                                              ev.reclaim_at, progress_s,
                                              epoch_started)))

    def _complete_warning_checkpoint(self, c: str, inst, mode: str,
                                     reclaim_at: float, progress_s: float,
                                     epoch_started: float):
        """The notice-triggered snapshot finished writing: persist it,
        publish `ClientCheckpointed`, and under "drain" proactively
        vacate the instance. A no-op when the world moved on during the
        write (instance terminated/preempted, epoch finished — or a new
        epoch began on the same warm instance, which `epoch_started`
        detects: pairing the old epoch's progress with the new epoch's
        duration would make the resume skip unperformed work)."""
        if self._done:
            return
        cur = self.cluster.instance_of(c)
        if cur is None or cur.iid != inst.iid or cur.state != RUNNING:
            return          # terminated or reclaimed during the write
        if not self._is_training(c):
            return          # epoch finished inside the write window
        if self._train_start[c] != epoch_started:
            return          # a different epoch is running now
        r = self._round_idx
        remaining = max(self._train_duration[c] - progress_s, 1.0)
        payload = {"client": c, "round": r, "remaining": remaining,
                   "progress": progress_s, "t": self.sim.now}
        snapshots.save_snapshot(self.ckpt_store, c, payload)
        self._warning_ckpt[c] = payload
        self.sim.bus.publish(ClientCheckpointed(
            self.sim.now, c, r, progress_s, remaining, reclaim_at))
        if mode == "drain":
            self._drain_after_checkpoint(c, remaining)

    def _drain_after_checkpoint(self, c: str, remaining: float):
        """"drain": the snapshot is durable, so stop paying for a
        doomed instance — terminate it now (billing closes at the
        warning, not the reclaim) and immediately request the
        replacement with a resume token, giving its spin-up a head
        start on the reclaim."""
        # work done during the snapshot write is redone after resume
        self._note_lost_work(c, remaining)
        self._warning_ckpt.pop(c, None)     # consumed by this resume
        self.cluster.terminate(c)
        self.cluster.request(c, resume_token={
            "round": self._round_idx, "remaining": remaining,
            "source": "warning"})

    def _preemption_remaining(self, c: str) -> Tuple[float, str]:
        """Epoch time still owed after a reclaim, from the best
        surviving checkpoint: the warning-window snapshot when it
        preserves more than the last periodic checkpoint (coarse
        `checkpoint_every_s` cadences are where the notice pays off),
        else the periodic one. Returns `(remaining_s, source)` with
        source "warning" | "periodic"."""
        periodic = self._checkpoint_remaining(
            c, self._train_start[c], self._train_duration[c])
        snap = self._warning_ckpt.pop(c, None)
        if snap is not None:
            stored = snapshots.load_snapshot(self.ckpt_store, c) or snap
            warn_remaining = float(stored["remaining"])
            if warn_remaining < periodic:
                return warn_remaining, "warning"
        return periodic, "periodic"

    def _note_lost_work(self, c: str, remaining: float):
        """Account the client-seconds of training that must be redone:
        time spent this epoch minus what the surviving checkpoint
        preserves."""
        elapsed = max(self.sim.now - self._train_start[c], 0.0)
        preserved = max(self._train_duration[c] - remaining, 0.0)
        self.lost_work_s += max(elapsed - preserved, 0.0)

    def _count_client_lost(self, ev: ClientLost):
        """Every cluster-filtered `ClientLost` is a real spot reclaim
        of a tracked instance; count it for `RunResult.n_preemptions`."""
        self.n_preemptions += 1

    def _publish_resumed_from_checkpoint(self, c: str, r: int,
                                         remaining: float):
        """Telemetry for a resume that starts from a warning-window
        snapshot (periodic-checkpoint resumes stay un-evented to keep
        default streams unchanged)."""
        self.sim.bus.publish(ClientResumedFromCheckpoint(
            self.sim.now, c, r, remaining))

    def _call_aggregate(self, participants: List[str], round_idx: int,
                        staleness: Optional[Dict[str, int]] = None):
        """Invoke `hooks.aggregate`, forwarding per-client staleness to
        hooks that accept it (legacy 2-argument overrides still work)."""
        if self.hooks is None:
            return
        try:
            params = inspect.signature(self.hooks.aggregate).parameters
        except (TypeError, ValueError):  # builtins / C callables
            params = {}
        accepts = ("staleness" in params
                   or any(p.kind is inspect.Parameter.VAR_KEYWORD
                          for p in params.values()))
        if accepts:
            self.hooks.aggregate(participants, round_idx,
                                 staleness=staleness)
        else:
            self.hooks.aggregate(participants, round_idx)

    def _sync_budgets(self):
        for c in self.profiles:
            self.scheduler.ledger.sync_spend(
                c, self.accountant.client_cost(c))

    def _spot_price_of(self, c: str) -> float:
        prof = self.profiles[c]
        if prof.zone is None:
            _, p = self.sim.market.cheapest_zone(
                self.sim.now,
                providers=self.cluster._placement_providers())
            return p
        return self.sim.market.price(prof.zone, self.sim.now,
                                     self.policy.on_demand,
                                     provider=prof.provider)

    # ------------------------------------------------------------------
    # Telemetry publication. Engines never write to the timeline or the
    # recorder directly — every observation goes through the bus, so
    # record/replay consumers (core.eventlog, fl.telemetry) see exactly
    # what the live consumers see.
    # ------------------------------------------------------------------
    def _mark(self, c: str, state: str):
        self.sim.bus.publish(ClientStateChanged(self.sim.now, c, state))

    def _publish_round_started(self, r: int, participants):
        self.sim.bus.publish(
            RoundStarted(self.sim.now, r, tuple(participants)))

    def _publish_round_completed(self, r: int, participants, snapshot):
        self.sim.bus.publish(RoundCompleted(
            self.sim.now, r, tuple(participants), snapshot))

    def _publish_budget_exhausted(self, c: str):
        self.sim.bus.publish(BudgetExhausted(self.sim.now, c))

    def _cost_snapshot(self) -> Dict[str, float]:
        return {c: self.accountant.client_cost(c) for c in self.profiles}

    def _record_costs(self, snapshot: Optional[Dict[str, float]] = None):
        snap = snapshot if snapshot is not None else self._cost_snapshot()
        for c, cost in snap.items():
            self.cost_curve.append({
                "t": self.sim.now, "client": c,
                "cum_cost": cost,
                "round": self._round_idx,
            })

    # ------------------------------------------------------------------
    def result(self) -> RunResult:
        """Assemble the engine's `RunResult` after the heap drains."""
        return RunResult(
            total_cost=self.accountant.total_cost(),
            per_client_cost={c: self.accountant.client_cost(c)
                             for c in self.profiles},
            makespan_s=(self._makespan if self._makespan is not None
                        else self.sim.now),
            timeline=self.timeline.segments,
            cost_curve=self.cost_curve,
            rounds_completed=self._round_idx + 1,
            excluded_clients=list(self.excluded),
            per_round_participants=self.per_round_participants,
            lost_work_s=self.lost_work_s,
            n_preemptions=self.n_preemptions)
