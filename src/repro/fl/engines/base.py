"""RoundEngine protocol + the shared engine machinery.

A `RoundEngine` owns the FL-round semantics of a run: when clients are
dispatched, what constitutes a completed round, and when aggregation
fires. Engines are driven entirely by client-level bus events
(`ClientReady`, `ClientLost`) plus the simulator clock — they never
talk to raw instance callbacks, which is what makes new round
disciplines (async buffering, straggler cut-offs, hierarchical rounds)
addable without touching the cloud or cluster layers.

Scheduling decisions are not made here either: engines report
observations to the run's `StrategyStack` (`repro.core.strategy`) and
invoke its decision points; the strategy components answer with typed
directives that the `DirectiveExecutor` (`repro.fl.cluster`) applies.
The engine's remaining job is purely the round discipline — which is
why a policy can swap lifecycle/budget/warning behavior without any
engine edit.

Contract:
  * `start()` schedules the initial work at t=0; the composition root
    then drains the simulator.
  * `result()` is called after the event heap drains and returns the
    engine's `RunResult`.

Engines also serve as the *view* the `WarningReaction` strategy reads
per-epoch facts from (`is_training` / `train_start` / …): subclasses
opt in to notice-aware checkpointing by implementing `_is_training`
and keeping the `_train_start` / `_train_duration` bookkeeping both
built-in engines already keep.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.checkpoint.store import MemoryStore, ObjectStore
from repro.cloud.accounting import CostAccountant
from repro.cloud.simulator import CloudSimulator
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 SchedulerConfig)
from repro.core.events import (ClientLost, ClientReady,
                               ClientResumedFromCheckpoint,
                               ClientStateChanged, RoundCompleted,
                               RoundStarted)
from repro.core.policies import Policy
from repro.core.strategy import StrategyStack
from repro.fl.cluster import ClusterManager
from repro.fl.telemetry import TimelineRecorder
from repro.comms.channel import CommsModel
from repro.core.events import ClientUpdateSent
from repro.fl.types import (RunResult, TrainerHooks,
                            aggregate_accepts_staleness)


@dataclasses.dataclass
class EngineContext:
    """Everything a round engine needs, wired by the composition root."""
    run_cfg: FLRunConfig
    cloud_cfg: CloudConfig
    sched_cfg: SchedulerConfig
    policy: Policy
    sim: CloudSimulator
    cluster: ClusterManager
    strategies: StrategyStack
    accountant: CostAccountant
    timeline: TimelineRecorder
    rng: np.random.RandomState
    hooks: Optional[TrainerHooks] = None
    ckpt_store: Optional[ObjectStore] = None   # None -> private MemoryStore
    # None -> no comms modeling: uploads are instantaneous and free,
    # no ClientUpdateSent events — the pre-v7 default path, bit-exact
    comms: Optional[CommsModel] = None


class BaseEngine:
    """Shared state + helpers; subclasses implement the round discipline."""

    name = "base"

    def __init__(self, ctx: EngineContext):
        self.ctx = ctx
        self.run_cfg = ctx.run_cfg
        self.cloud_cfg = ctx.cloud_cfg
        self.sched_cfg = ctx.sched_cfg
        self.policy = ctx.policy
        self.sim = ctx.sim
        self.cluster = ctx.cluster
        self.strategies = ctx.strategies
        self.accountant = ctx.accountant
        self.timeline = ctx.timeline
        self.hooks = ctx.hooks
        self.comms = ctx.comms
        # sniffed once here, not per round (fl.types helper warns on
        # the deprecated 2-argument aggregate override)
        self._aggregate_accepts_staleness = aggregate_accepts_staleness(
            ctx.hooks)
        # clients whose finished update is still occupying the uplink
        # (comms modeling only); they are not "training" for the
        # warning path, and losing their instance costs no redo
        self._uploading: Set[str] = set()
        self._rng = ctx.rng
        self.ckpt_store = ctx.ckpt_store or MemoryStore()
        self.profiles: Dict[str, ClientProfile] = {
            c.name: c for c in ctx.run_cfg.clients}
        self.cost_curve: List[dict] = []
        self.per_round_participants: List[List[str]] = []
        self.excluded: List[str] = []
        self._round_idx = -1
        self._done = False
        self._makespan: Optional[float] = None
        # per-epoch bookkeeping (also read by the WarningReaction
        # strategy through the view methods below)
        self._train_start: Dict[str, float] = {}
        self._train_duration: Dict[str, float] = {}
        self.lost_work_s = 0.0
        self.n_preemptions = 0
        self.strategies.attach_engine(self)
        self.sim.bus.subscribe(ClientLost, self._count_client_lost)
        self.sim.bus.subscribe(ClientReady, self._on_client_ready)
        self.sim.bus.subscribe(ClientLost, self._on_client_lost)

    # ------------------------------------------------------------------
    # Round discipline (subclass responsibility).
    # ------------------------------------------------------------------
    def start(self):
        """Schedule the engine's initial work at t=0; the composition
        root then drains the simulator."""
        raise NotImplementedError

    def _on_client_ready(self, ev: ClientReady):
        raise NotImplementedError

    def _on_client_lost(self, ev: ClientLost):
        raise NotImplementedError

    def _is_training(self, c: str) -> bool:
        """Is `c` mid-epoch on a RUNNING instance right now? Gates the
        preemption-warning path; engines that keep the shared
        `_train_start`/`_train_duration` bookkeeping override this.
        The conservative default opts an engine out of notice-aware
        checkpointing entirely (warnings no-op)."""
        return False

    # ------------------------------------------------------------------
    # Strategy view: the per-epoch facts the WarningReaction strategy
    # reads (and the two engine-side reactions it triggers).
    # ------------------------------------------------------------------
    def is_done(self) -> bool:
        """Has the run finished (strategies stop reacting)?"""
        return self._done

    def is_training(self, c: str) -> bool:
        """Public view of `_is_training` for the strategy layer."""
        return self._is_training(c)

    def train_start(self, c: str) -> float:
        """When the client's current epoch started (simulated s)."""
        return self._train_start[c]

    def train_duration(self, c: str) -> float:
        """The client's current epoch's total duration (simulated s)."""
        return self._train_duration[c]

    def current_round(self) -> int:
        """The engine's current round index."""
        return self._round_idx

    def note_lost_work(self, c: str, remaining: float):
        """Account the client-seconds of training that must be redone:
        time spent this epoch minus what the surviving checkpoint
        preserves."""
        elapsed = max(self.sim.now - self._train_start[c], 0.0)
        preserved = max(self._train_duration[c] - remaining, 0.0)
        self.lost_work_s += max(elapsed - preserved, 0.0)

    def after_drain(self, c: str, remaining: float):
        """Engine reaction after a `Drain` directive re-requested the
        client's replacement. Default: nothing; the sync barrier
        additionally runs the §III-D schedule adjustment."""

    # ------------------------------------------------------------------
    # Shared helpers.
    # ------------------------------------------------------------------
    def _sample_duration(self, c: str, cold: bool) -> float:
        prof = self.profiles[c]
        base = prof.mean_epoch_s * (prof.cold_multiplier if cold else 1.0)
        jit = float(np.exp(self._rng.randn() * prof.jitter))
        return base * jit

    def _checkpoint_remaining(self, c: str, train_start: float,
                              train_duration: float) -> float:
        """§III-D: work since the last periodic checkpoint is lost on
        preemption; returns the epoch time still owed after a resume."""
        elapsed = max(self.sim.now - train_start, 0.0)
        ck = self.sched_cfg.checkpoint_every_s
        preserved = math.floor(elapsed / ck) * ck
        return max(train_duration - preserved, 1.0)

    def _preemption_remaining(self, c: str) -> Tuple[float, str]:
        """Epoch time still owed after a reclaim, from the best
        surviving checkpoint: the warning-window snapshot when a
        strategy holds one that preserves more than the last periodic
        checkpoint, else the periodic one. Returns `(remaining_s,
        source)` with source "warning" | "periodic"."""
        periodic = self._checkpoint_remaining(
            c, self._train_start[c], self._train_duration[c])
        return self.strategies.preemption_remaining(c, periodic)

    def _count_client_lost(self, ev: ClientLost):
        """Every cluster-filtered `ClientLost` is a real spot reclaim
        of a tracked instance; count it for `RunResult.n_preemptions`."""
        self.n_preemptions += 1

    def _publish_resumed_from_checkpoint(self, c: str, r: int,
                                         remaining: float):
        """Telemetry for a resume that starts from a warning-window
        snapshot (periodic-checkpoint resumes stay un-evented to keep
        default streams unchanged)."""
        self.sim.bus.publish(ClientResumedFromCheckpoint(
            self.sim.now, c, r, remaining))

    def _call_aggregate(self, participants: List[str], round_idx: int,
                        staleness: Optional[Dict[str, int]] = None):
        """Invoke `hooks.aggregate`, forwarding per-client staleness to
        hooks that accept it (legacy 2-argument overrides still work)."""
        if self.hooks is None:
            return
        if self._aggregate_accepts_staleness:
            self.hooks.aggregate(participants, round_idx,
                                 staleness=staleness)
        else:
            self.hooks.aggregate(participants, round_idx)

    def _publish_update_sent(self, c: str, round_idx: int) -> float:
        """Comms modeling: publish `ClientUpdateSent` for `c`'s finished
        round-`round_idx` update and return the modeled uplink seconds
        the upload occupies (0.0 when bandwidth is unmodeled). Only
        called when `self.comms` is attached, so default runs publish
        nothing."""
        inst = self.cluster.instance_of(c)
        provider = getattr(inst, "provider", "") or ""
        zone = getattr(inst, "zone", "") or ""
        xfer = self.comms.transfer_s(provider, zone)
        self.sim.bus.publish(ClientUpdateSent(
            self.sim.now, c, round_idx, self.comms.size_mb,
            self.comms.quantized, provider, zone, xfer))
        return xfer

    def _screen_round(self, round_idx: int,
                      candidates: List[str]) -> List[str]:
        """Run the strategy stack's §III-E screening pass; records the
        newly screened-out clients in `excluded` (their `ScreenOut`
        directives — `BudgetExhausted`, teardown — were already
        applied) and returns the surviving participants."""
        keep, screened = self.strategies.screen(round_idx, candidates)
        self.excluded.extend(screened)
        return keep

    # ------------------------------------------------------------------
    # Telemetry publication. Engines never write to the timeline or the
    # recorder directly — every observation goes through the bus, so
    # record/replay consumers (core.eventlog, fl.telemetry) see exactly
    # what the live consumers see.
    # ------------------------------------------------------------------
    def _mark(self, c: str, state: str):
        self.sim.bus.publish(ClientStateChanged(self.sim.now, c, state))

    def _publish_round_started(self, r: int, participants):
        self.sim.bus.publish(
            RoundStarted(self.sim.now, r, tuple(participants)))

    def _publish_round_completed(self, r: int, participants, snapshot):
        self.sim.bus.publish(RoundCompleted(
            self.sim.now, r, tuple(participants), snapshot))

    def _cost_snapshot(self) -> Dict[str, float]:
        return {c: self.accountant.client_cost(c) for c in self.profiles}

    def _record_costs(self, snapshot: Optional[Dict[str, float]] = None):
        snap = snapshot if snapshot is not None else self._cost_snapshot()
        for c, cost in snap.items():
            self.cost_curve.append({
                "t": self.sim.now, "client": c,
                "cum_cost": cost,
                "round": self._round_idx,
            })

    # ------------------------------------------------------------------
    def result(self) -> RunResult:
        """Assemble the engine's `RunResult` after the heap drains."""
        return RunResult(
            total_cost=self.accountant.total_cost(),
            per_client_cost={c: self.accountant.client_cost(c)
                             for c in self.profiles},
            makespan_s=(self._makespan if self._makespan is not None
                        else self.sim.now),
            timeline=self.timeline.segments,
            cost_curve=self.cost_curve,
            rounds_completed=self._round_idx + 1,
            excluded_clients=list(self.excluded),
            per_round_participants=self.per_round_participants,
            lost_work_s=self.lost_work_s,
            n_preemptions=self.n_preemptions,
            checkpoint_cost=self.accountant.checkpoint_cost_total(),
            comm_cost=self.accountant.transfer_cost_total())
