"""RoundEngine protocol + the shared engine machinery.

A `RoundEngine` owns the FL-round semantics of a run: when clients are
dispatched, what constitutes a completed round, and when aggregation
fires. Engines are driven entirely by client-level bus events
(`ClientReady`, `ClientLost`) plus the simulator clock — they never talk
to raw instance callbacks, which is what makes new round disciplines
(async buffering, straggler cut-offs, hierarchical rounds) addable
without touching the cloud or cluster layers.

Contract:
  * `start()` schedules the initial work at t=0; the composition root
    then drains the simulator.
  * `result()` is called after the event heap drains and returns the
    engine's `RunResult`.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Dict, List, Optional

import numpy as np

from repro.cloud.accounting import CostAccountant
from repro.cloud.simulator import CloudSimulator
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 SchedulerConfig)
from repro.core.events import (BudgetExhausted, ClientLost, ClientReady,
                               ClientStateChanged, RoundCompleted,
                               RoundStarted)
from repro.core.policies import Policy
from repro.core.scheduler import FedCostAwareScheduler
from repro.fl.cluster import ClusterManager
from repro.fl.telemetry import TimelineRecorder
from repro.fl.types import RunResult, TrainerHooks


@dataclasses.dataclass
class EngineContext:
    """Everything a round engine needs, wired by the composition root."""
    run_cfg: FLRunConfig
    cloud_cfg: CloudConfig
    sched_cfg: SchedulerConfig
    policy: Policy
    sim: CloudSimulator
    cluster: ClusterManager
    scheduler: FedCostAwareScheduler
    accountant: CostAccountant
    timeline: TimelineRecorder
    rng: np.random.RandomState
    hooks: Optional[TrainerHooks] = None


class BaseEngine:
    """Shared state + helpers; subclasses implement the round discipline."""

    name = "base"

    def __init__(self, ctx: EngineContext):
        self.ctx = ctx
        self.run_cfg = ctx.run_cfg
        self.cloud_cfg = ctx.cloud_cfg
        self.sched_cfg = ctx.sched_cfg
        self.policy = ctx.policy
        self.sim = ctx.sim
        self.cluster = ctx.cluster
        self.scheduler = ctx.scheduler
        self.accountant = ctx.accountant
        self.timeline = ctx.timeline
        self.hooks = ctx.hooks
        self._rng = ctx.rng
        self.profiles: Dict[str, ClientProfile] = {
            c.name: c for c in ctx.run_cfg.clients}
        self.cost_curve: List[dict] = []
        self.per_round_participants: List[List[str]] = []
        self.excluded: List[str] = []
        self._round_idx = -1
        self._done = False
        self._makespan: Optional[float] = None
        self.sim.bus.subscribe(ClientReady, self._on_client_ready)
        self.sim.bus.subscribe(ClientLost, self._on_client_lost)

    # ------------------------------------------------------------------
    # Round discipline (subclass responsibility).
    # ------------------------------------------------------------------
    def start(self):
        raise NotImplementedError

    def _on_client_ready(self, ev: ClientReady):
        raise NotImplementedError

    def _on_client_lost(self, ev: ClientLost):
        raise NotImplementedError

    # ------------------------------------------------------------------
    # Shared helpers.
    # ------------------------------------------------------------------
    def _sample_duration(self, c: str, cold: bool) -> float:
        prof = self.profiles[c]
        base = prof.mean_epoch_s * (prof.cold_multiplier if cold else 1.0)
        jit = float(np.exp(self._rng.randn() * prof.jitter))
        return base * jit

    def _checkpoint_remaining(self, c: str, train_start: float,
                              train_duration: float) -> float:
        """§III-D: work since the last periodic checkpoint is lost on
        preemption; returns the epoch time still owed after a resume."""
        elapsed = max(self.sim.now - train_start, 0.0)
        ck = self.sched_cfg.checkpoint_every_s
        preserved = math.floor(elapsed / ck) * ck
        return max(train_duration - preserved, 1.0)

    def _call_aggregate(self, participants: List[str], round_idx: int,
                        staleness: Optional[Dict[str, int]] = None):
        """Invoke `hooks.aggregate`, forwarding per-client staleness to
        hooks that accept it (legacy 2-argument overrides still work)."""
        if self.hooks is None:
            return
        try:
            params = inspect.signature(self.hooks.aggregate).parameters
        except (TypeError, ValueError):  # builtins / C callables
            params = {}
        accepts = ("staleness" in params
                   or any(p.kind is inspect.Parameter.VAR_KEYWORD
                          for p in params.values()))
        if accepts:
            self.hooks.aggregate(participants, round_idx,
                                 staleness=staleness)
        else:
            self.hooks.aggregate(participants, round_idx)

    def _sync_budgets(self):
        for c in self.profiles:
            self.scheduler.ledger.sync_spend(
                c, self.accountant.client_cost(c))

    def _spot_price_of(self, c: str) -> float:
        prof = self.profiles[c]
        if prof.zone is None:
            _, p = self.sim.market.cheapest_zone(
                self.sim.now,
                providers=self.cluster._placement_providers())
            return p
        return self.sim.market.price(prof.zone, self.sim.now,
                                     self.policy.on_demand,
                                     provider=prof.provider)

    # ------------------------------------------------------------------
    # Telemetry publication. Engines never write to the timeline or the
    # recorder directly — every observation goes through the bus, so
    # record/replay consumers (core.eventlog, fl.telemetry) see exactly
    # what the live consumers see.
    # ------------------------------------------------------------------
    def _mark(self, c: str, state: str):
        self.sim.bus.publish(ClientStateChanged(self.sim.now, c, state))

    def _publish_round_started(self, r: int, participants):
        self.sim.bus.publish(
            RoundStarted(self.sim.now, r, tuple(participants)))

    def _publish_round_completed(self, r: int, participants, snapshot):
        self.sim.bus.publish(RoundCompleted(
            self.sim.now, r, tuple(participants), snapshot))

    def _publish_budget_exhausted(self, c: str):
        self.sim.bus.publish(BudgetExhausted(self.sim.now, c))

    def _cost_snapshot(self) -> Dict[str, float]:
        return {c: self.accountant.client_cost(c) for c in self.profiles}

    def _record_costs(self, snapshot: Optional[Dict[str, float]] = None):
        snap = snapshot if snapshot is not None else self._cost_snapshot()
        for c, cost in snap.items():
            self.cost_curve.append({
                "t": self.sim.now, "client": c,
                "cum_cost": cost,
                "round": self._round_idx,
            })

    # ------------------------------------------------------------------
    def result(self) -> RunResult:
        return RunResult(
            total_cost=self.accountant.total_cost(),
            per_client_cost={c: self.accountant.client_cost(c)
                             for c in self.profiles},
            makespan_s=(self._makespan if self._makespan is not None
                        else self.sim.now),
            timeline=self.timeline.segments,
            cost_curve=self.cost_curve,
            rounds_completed=self._round_idx + 1,
            excluded_clients=list(self.excluded),
            per_round_participants=self.per_round_participants)
