"""AsyncBufferedEngine: FedBuff-style buffered asynchronous rounds.

A workload the synchronous barrier cannot express: the server aggregates
as soon as `K = buffer_k` client results are buffered (default: all but
one client), then immediately opens the next round. Clients never wait
at a barrier — each one is re-dispatched on the freshest global model
the moment its previous epoch finishes, and a straggler's in-flight
result simply rolls into whichever round's buffer is open when it lands
(FedBuff, arXiv:2106.06639). Each buffered result is tagged with the
round it was dispatched in; at aggregation the engine reports
`staleness = aggregating_round - dispatch_round` per participant to
`TrainerHooks.aggregate`, and the JAX hook discounts stale updates by
the FedBuff weight 1/sqrt(1+staleness).

Cost behavior: instances are never idle-at-the-barrier, so there is
nothing for Listing-1 terminate/pre-warm decisions to reclaim — the
saving comes from finishing the same number of aggregations in far less
wall-clock (lower makespan => fewer billed instance-seconds for the fast
clients' peers). Budget screening (§III-E) still runs at every round
boundary, and per-client spend is tracked by the same `CostAccountant`
as the sync engine.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.cloud.simulator import RUNNING
from repro.core.events import ClientLost, ClientReady
from repro.fl.engines.base import BaseEngine, EngineContext


class AsyncBufferedEngine(BaseEngine):
    name = "async_buffered"

    def __init__(self, ctx: EngineContext):
        super().__init__(ctx)
        n = len(self.profiles)
        k = ctx.run_cfg.buffer_k
        self.buffer_k = max(1, min(k if k is not None else n - 1, n))
        self._buffer: List[str] = []       # results awaiting aggregation
        self._buffer_round: Dict[str, int] = {}  # client -> dispatch round
        self._dispatch_round: Dict[str, int] = {}
        self._active: List[str] = []       # participating clients, ordered
        self._task: Dict[str, int] = {}    # client -> in-flight task iid
        self._resumed: set = set()            # partial epochs: skip EMAs
        self._pending_dispatch: set = set()   # waiting for instance ready

    # ------------------------------------------------------------------
    def start(self):
        self.sim.schedule(0.0, self._launch)

    def _launch(self):
        self._round_idx = 0
        joins = [c for c, p in self.profiles.items() if p.join_round <= 0]
        self._publish_round_started(0, joins)
        for c in joins:
            self._join(c)

    def _join(self, c: str):
        self._active.append(c)
        self._dispatch(c)

    # ------------------------------------------------------------------
    # Dispatch / local training.
    # ------------------------------------------------------------------
    def _dispatch(self, c: str):
        inst = self.cluster.instance_of(c)
        if inst is not None and inst.t_ready is not None:
            self._begin_training(c, cold=self.cluster.is_fresh(inst.iid))
        else:
            self._pending_dispatch.add(c)
            if inst is None:
                self.cluster.request(c)

    def _begin_training(self, c: str, cold: bool,
                        duration: Optional[float] = None):
        """`duration` overrides the sampled epoch time for checkpoint
        resumes (the task only owes the post-checkpoint remainder)."""
        dur = duration if duration is not None \
            else self._sample_duration(c, cold)
        self._train_start[c] = self.sim.now
        self._train_duration[c] = dur
        # checkpoint resumes keep the original dispatch round: the
        # update is still based on that round's global model
        if duration is None:
            self._dispatch_round[c] = self._round_idx
        self._mark(c, "training")
        iid = self.cluster.instance_of(c).iid
        self._task[c] = iid
        if duration is not None:
            self._resumed.add(c)
        self.sim.schedule_in(dur, lambda: self._finish_training(c, iid))

    def _is_training(self, c: str) -> bool:
        """Mid-epoch iff the client's in-flight task is bound to its
        currently tracked, RUNNING instance."""
        iid = self._task.get(c)
        inst = self.cluster.instance_of(c)
        return (iid is not None and inst is not None
                and inst.iid == iid and inst.state == RUNNING)

    def _finish_training(self, c: str, iid: int):
        if self._done:
            return
        inst = self.cluster.instance_of(c)
        if inst is None or inst.iid != iid or self._task.get(c) != iid:
            return                                  # stale (preempted)
        if c not in self._active:
            return                                  # excluded mid-flight
        self.strategies.invalidate_ckpt(c)  # epoch done: snapshot stale
        t = self.sim.now
        dur = t - self._train_start[c]
        cold = self.cluster.is_fresh(inst.iid)
        spin_obs = None
        if cold and inst.t_ready is not None:
            spin_obs = inst.t_ready - inst.t_request
        self.cluster.mark_warm(inst.iid)
        del self._task[c]
        # keep the estimator EMAs fresh — budget screening prices the
        # next epoch off them, exactly as in the sync engine. Partial
        # (checkpoint-resumed) epochs would corrupt the epoch EMAs, so
        # only the spin-up observation survives for those.
        if c in self._resumed:
            self._resumed.discard(c)
        else:
            self.strategies.note_observation(c, epoch_s=dur, cold=cold)
        if spin_obs is not None:
            self.strategies.note_observation(c, spin_up_s=spin_obs)
        if self.hooks:
            self.hooks.run_local(c, self._round_idx)
        dr = self._dispatch_round.get(c, self._round_idx)
        if self.comms is not None:
            self._begin_upload(c, dr)
            return
        self._complete_result(c, dr)

    def _begin_upload(self, c: str, dr: int):
        """Comms modeling: the finished update occupies the client's
        uplink before it can enter the buffer (and before the client is
        re-dispatched). `dr` pins the update's dispatch round now — a
        reclaim mid-upload may start the client's *next* epoch before
        the upload lands, clobbering `_dispatch_round`."""
        xfer = self._publish_update_sent(c, self._round_idx)
        if xfer <= 0.0:
            self._complete_result(c, dr)
            return
        self._uploading.add(c)
        self._mark(c, "uploading")
        self.sim.schedule_in(xfer, lambda: self._finish_upload(c, dr))

    def _finish_upload(self, c: str, dr: int):
        self._uploading.discard(c)
        if self._done or c not in self._active:
            return                                  # excluded mid-upload
        self._complete_result(c, dr)

    def _complete_result(self, c: str, dr: int):
        """`c`'s round-`dr` update reaches the server: buffer it,
        aggregate when the buffer fills, put the client back to work."""
        self._buffer.append(c)
        self._buffer_round[c] = dr
        if c not in self._task:
            self._mark(c, "idle")
        # exclusions may shrink the pool below buffer_k; clamp so the
        # run can still make progress (else it would spin forever)
        k_eff = min(self.buffer_k, max(1, len(self._active)))
        if len(self._buffer) >= k_eff:
            self._aggregate()
        # a reclaim mid-upload may already have re-requested (or even
        # restarted) the client; only dispatch when nothing is in flight
        if (not self._done and c in self._active
                and self._task.get(c) is None
                and c not in self._pending_dispatch):
            self._dispatch(c)       # straight back to work, no barrier

    # ------------------------------------------------------------------
    # Buffered aggregation = one async "round".
    # ------------------------------------------------------------------
    def _aggregate(self):
        r = self._round_idx
        participants = list(self._buffer)
        self._buffer.clear()
        # FedBuff staleness: rounds elapsed since each buffered result's
        # dispatch (a straggler dispatched in round r-k lands with
        # staleness k; the hook discounts it by 1/sqrt(1+k)). A fast
        # client can appear in `participants` twice per aggregation;
        # hooks keyed on client (JaxTrainerHooks) then see only its
        # latest update, and this dict matches that update's dispatch
        # round — the surviving entry, not the overwritten one.
        staleness = {c: max(r - self._buffer_round.get(c, r), 0)
                     for c in participants}
        self._buffer_round.clear()
        self._call_aggregate(participants, r, staleness)
        self.per_round_participants.append(participants)
        snap = self._cost_snapshot()
        self._record_costs(snap)
        self._publish_round_completed(r, participants, snap)
        if r + 1 >= self.run_cfg.n_epochs:
            self._finish_run()
            return
        self._screen_budgets(r + 1)
        if not self._active and not self._buffer:
            # round r+1 never opens: keep _round_idx at the last
            # completed round so rounds_completed == #RoundCompleted.
            self._finish_run()
            return
        self._round_idx = r + 1
        joins = [c for c, p in self.profiles.items()
                 if c not in self._active and c not in self.excluded
                 and p.join_round <= self._round_idx]
        self._publish_round_started(
            self._round_idx, list(self._active) + joins)
        for c in joins:
            self._join(c)

    def _screen_budgets(self, round_idx: int):
        """§III-E screening at the round boundary: the strategy stack
        excludes the unaffordable clients (publishing and tearing
        down through `ScreenOut` directives); the engine only drops
        them from its own dispatch bookkeeping."""
        keep = self._screen_round(round_idx, list(self._active))
        for c in [c for c in self._active if c not in keep]:
            self._active.remove(c)
            self._task.pop(c, None)
            self._pending_dispatch.discard(c)

    # ------------------------------------------------------------------
    # Bus events.
    # ------------------------------------------------------------------
    def _on_client_ready(self, ev: ClientReady):
        c = ev.client
        if self._done or c not in self._active:
            return
        if ev.resume_token is not None:
            if ev.resume_token.get("source") == "warning":
                self._publish_resumed_from_checkpoint(
                    c, self._round_idx, ev.resume_token["remaining"])
            self._begin_training(c, cold=True,
                                 duration=ev.resume_token["remaining"])
        elif c in self._pending_dispatch:
            self._pending_dispatch.discard(c)
            self._begin_training(c, cold=True)

    def _on_client_lost(self, ev: ClientLost):
        c = ev.client
        if self._done or c not in self._active:
            return
        if self._task.pop(c, None) is None:
            # idle or mid-upload (the committed update still lands on
            # schedule — only instance-seconds were lost, no redo)
            self._mark(c, "savings")
            self._pending_dispatch.add(c)       # re-request on next need
            self.cluster.request(c)
            return
        # resume from the best surviving checkpoint: the warning-window
        # snapshot when the provider's notice let us write one, else
        # the last periodic checkpoint (§III-D)
        remaining, source = self._preemption_remaining(c)
        self.note_lost_work(c, remaining)
        self.cluster.request(c, resume_token={"remaining": remaining,
                                              "source": source})

    # ------------------------------------------------------------------
    def _finish_run(self):
        self._done = True
        self._makespan = self.sim.now
        self.cluster.shutdown()
        for c in self.profiles:
            if self.cluster.instance_of(c) is not None:
                self.cluster.terminate(c)       # stragglers cut off here
            self._mark(c, "done")
        self._record_costs()
