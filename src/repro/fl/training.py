"""Real-training bridge: sharded jax_pallas client steps in the FL loop.

`MeshTrainerHooks` is the `TrainerHooks` implementation that replaces
hand-set epoch times and toy NumPy clients with the repo's real model
stack: `models/lm.py` forward/backward (flash-attention path included)
on a `(pod, data, model)` mesh where each pod hosts one FL client
(`fl/mesh_fl.py`, DESIGN.md §2). On CPU the mesh runs via the XLA
host-device trick — callers must set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax is
imported (see `examples/mesh_fl_lm.py`; `benchmarks/table1.py
--real-training` and tests/test_training.py both do this).

Engine protocol mapping: the simulator calls `run_local(c, r)` at each
client's simulated epoch-completion instant — the hooks only mark the
client as a round participant there — and the actual jitted compute
runs once per round inside `aggregate`, which local-trains every client
slot in one vmapped scan and folds the *participants'* updates into the
global model (non-participants get weight 0 and keep their previous
momentum). Staleness folds into the FedAvg weights by the FedBuff
1/sqrt(1+s) discount, so the async engine's reports are honored.

Quantized updates (`quantize=True`) round-trip every participant's
per-leaf delta through the `kernels/grad_quant` int8 block codec before
the weighted average — the int8 payload the comms subsystem bills
(`comms/payload.py` mirrors the codec's exact byte layout) is the same
one the real `aggregate()` consumes.

Calibration (`calibrate` / `calibrated_profiles`) anchors simulated
time to real compute: it wall-clocks the jitted round, cross-checks the
measurement against a roofline estimate built from the compiled HLO's
FLOP/byte counts and *measured host peaks*
(`launch.roofline.estimate_step_time`), and rewrites
`ClientProfile.mean_epoch_s` from the measurement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import configs
from repro.common import compat
from repro.common.config import ClientProfile
from repro.comms.payload import UpdatePayload
from repro.data.synthetic import token_stream
from repro.fl.server import JaxTrainerHooks
from repro.fl.types import TrainerHooks
from repro.kernels.grad_quant import ops as gq
from repro.models import lm
from repro.sharding import rules as R


def _client_mesh(n_clients: int) -> jax.sharding.Mesh:
    """A `(pod=n, data=1, model=1)` mesh over the first `n` host
    devices — `jax.make_mesh` insists on using every device, so subsets
    build the mesh directly."""
    devices = jax.devices()
    if len(devices) < n_clients:
        raise ValueError(
            f"need {n_clients} devices for {n_clients} clients, have "
            f"{len(devices)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_clients} "
            f"before importing jax")
    return jax.sharding.Mesh(
        np.array(devices[:n_clients]).reshape(n_clients, 1, 1),
        ("pod", "data", "model"))


class MeshTrainerHooks(TrainerHooks):
    """Real sharded LM training behind the engine hook protocol (see
    module docstring for the round mapping)."""

    def __init__(self, clients: Sequence[str],
                 model: str = "phi3-mini-3.8b", smoke: bool = True,
                 local_steps: int = 4, batch: int = 8, seq: int = 32,
                 lr: float = 5e-3, quantize: bool = False,
                 use_pallas: bool = False, seed: int = 0,
                 weights: Optional[Dict[str, float]] = None):
        self.clients = list(clients)
        self.slot = {c: i for i, c in enumerate(self.clients)}
        if len(self.slot) != len(self.clients):
            raise ValueError("duplicate client names")
        self.cfg = configs.get_config(model, smoke=smoke)
        self.local_steps = local_steps
        self.batch = batch
        self.seq = seq
        self.quantize = quantize
        self.use_pallas = use_pallas
        self._lr = lr
        n = len(self.clients)
        self.mesh = _client_mesh(n)
        self.shard = R.ShardingCtx(self.mesh, R.make_rules("train"))
        params = lm.init_params(self.cfg, jax.random.PRNGKey(seed))
        from repro.fl import mesh_fl
        self.params_stk = mesh_fl.stack_params_for_clients(params, n)
        self.mu_stk = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), self.params_stk)
        self._base_w = np.array(
            [float((weights or {}).get(c, 1.0)) for c in self.clients])
        self._streams = [token_stream(self.cfg.vocab_size, batch, seq,
                                      seed=seed + 17 * i)
                         for i in range(n)]
        self._participants: Dict[str, int] = {}   # client -> last round
        self.losses: List[dict] = []              # per-aggregation record
        self._local_fn = jax.jit(jax.vmap(self._local_train))
        self._avg_fn = jax.jit(self._weighted_delta_avg)

    # ------------------------------------------------------------------
    # Jitted pieces.
    # ------------------------------------------------------------------
    def _local_train(self, params, mu, client_batches):
        """`local_steps` SGD-momentum steps on one client slot (the
        same inline optimizer as `mesh_fl.make_fl_round_step`)."""
        cfg, lr = self.cfg, self._lr

        def step(carry, batch):
            p, m = carry
            loss, g = jax.value_and_grad(
                lambda pp: lm.loss_fn(pp, cfg, batch,
                                      shard=self.shard))(p)
            m = jax.tree.map(
                lambda mi, gi: 0.9 * mi + gi.astype(jnp.float32), m, g)
            p = jax.tree.map(
                lambda pi, mi: (pi.astype(jnp.float32)
                                - lr * mi).astype(pi.dtype), p, m)
            return (p, m), loss

        (params, mu), losses = lax.scan(step, (params, mu),
                                        client_batches)
        return params, mu, losses

    @staticmethod
    def _weighted_delta_avg(deltas, global_p, w):
        """Weighted mean of per-client fp32 deltas, applied to the
        global model and re-broadcast to every client slot."""
        wn = w / jnp.maximum(jnp.sum(w), 1e-12)

        def one(d, g):
            avg = jnp.einsum("c...,c->...", d, wn)
            new_g = g.astype(jnp.float32) + avg
            return jnp.broadcast_to(new_g[None].astype(g.dtype),
                                    d.shape)

        return jax.tree.map(one, deltas, global_p)

    def _quant_roundtrip(self, deltas):
        """Round-trip every participant's per-leaf delta through the
        grad_quant int8 block codec — the aggregated update is built
        from exactly the payload the comms subsystem bills."""
        def one_leaf(d):
            per_client = d.shape[1:]

            def rt(x):
                q, s = gq.quantize(x, use_pallas=self.use_pallas)
                return gq.dequantize(q, s, per_client, jnp.float32,
                                     use_pallas=self.use_pallas)

            return jax.vmap(rt)(d)

        return jax.tree.map(one_leaf, deltas)

    # ------------------------------------------------------------------
    # TrainerHooks protocol.
    # ------------------------------------------------------------------
    def run_local(self, client: str, round_idx: int) -> None:
        """Mark the client's round-`round_idx` update as produced; the
        jitted compute itself batches into `aggregate` (one vmapped
        round per aggregation, every pod training in parallel)."""
        if client not in self.slot:
            raise KeyError(f"unknown client {client!r}")
        self._participants[client] = round_idx

    def aggregate(self, participants: List[str], round_idx: int,
                  staleness: Optional[Dict[str, int]] = None) -> None:
        """Run the real round: vmapped local training on every slot,
        then fold the participants' (optionally int8-round-tripped)
        deltas into the global model with staleness-discounted FedAvg
        weights."""
        live = [c for c in participants if c in self._participants]
        if not live:
            return
        stale = staleness or {}
        batches = self._next_batches()
        new_p, new_mu, losses = self._run_round(batches)
        mask = np.zeros(len(self.clients))
        for c in set(live):
            mask[self.slot[c]] = (
                self._base_w[self.slot[c]]
                * JaxTrainerHooks.staleness_discount(stale.get(c, 0)))
        w = jnp.asarray(mask, jnp.float32)
        global_p = jax.tree.map(lambda p: p[0], self.params_stk)
        deltas = jax.tree.map(
            lambda np_, g: np_.astype(jnp.float32)
            - g.astype(jnp.float32)[None], new_p, global_p)
        if self.quantize:
            deltas = self._quant_roundtrip(deltas)
        with compat.set_mesh(self.mesh):
            self.params_stk = self._avg_fn(deltas, global_p, w)
        # only participants actually trained: the rest keep their
        # momentum (their slot's compute was masked out of the average)
        keep = jnp.asarray(mask > 0)
        self.mu_stk = jax.tree.map(
            lambda new, old: jnp.where(
                keep.reshape((-1,) + (1,) * (old.ndim - 1)), new, old),
            new_mu, self.mu_stk)
        losses = np.asarray(losses)
        self.losses.append({
            "round": round_idx,
            "mean_loss": float(np.mean(
                [losses[self.slot[c]].mean() for c in set(live)]))})
        for c in live:
            self._participants.pop(c, None)

    def update_payload(self, quantized: bool = False) -> UpdatePayload:
        """Byte-exact size of one client's update: the global param
        pytree in the requested wire format."""
        global_p = jax.tree.map(lambda p: p[0], self.params_stk)
        return UpdatePayload.from_tree(global_p, quantized=quantized)

    # ------------------------------------------------------------------
    # Round execution + measurement.
    # ------------------------------------------------------------------
    def _next_batches(self):
        stacked = {"tokens": [], "labels": []}
        for s in self._streams:
            rows = [next(s) for _ in range(self.local_steps)]
            stacked["tokens"].append(np.stack([r["tokens"] for r in rows]))
            stacked["labels"].append(np.stack([r["labels"] for r in rows]))
        return {k: jnp.asarray(np.stack(v)) for k, v in stacked.items()}

    def _run_round(self, batches):
        with compat.set_mesh(self.mesh):
            return self._local_fn(self.params_stk, self.mu_stk, batches)

    def global_params(self):
        """The current global model (slot 0 of the stacked params — all
        slots are identical after every aggregation)."""
        return jax.tree.map(lambda p: p[0], self.params_stk)

    def final_loss(self) -> float:
        """Mean participant loss of the last aggregation (inf before
        the first one) — the accuracy side of the egress trade."""
        return self.losses[-1]["mean_loss"] if self.losses \
            else float("inf")

    def measure_round_s(self, warmup: int = 1, iters: int = 2) -> float:
        """Wall-clock one jitted round (local training of every slot)
        on held-out batches, after `warmup` compile/warm runs. State is
        not advanced."""
        batches = self._next_batches()
        for _ in range(max(warmup, 1)):
            out = self._run_round(batches)
            jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(max(iters, 1)):
            out = self._run_round(batches)
            jax.block_until_ready(out)
        return (time.perf_counter() - t0) / max(iters, 1)


# ---------------------------------------------------------------------------
# Calibration: measured step time -> simulated ClientProfile epoch times,
# cross-checked against a measured-peak roofline estimate.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StepCalibration:
    """One calibration measurement and its roofline cross-check."""
    measured_round_s: float      # wall-clock of one jitted round
    roofline_round_s: float      # estimate from HLO counts + host peaks
    flops: float                 # total HLO FLOPs across all host devices
    bytes_accessed: float        # total HLO HBM-proxy bytes
    host_peak_flops: float       # measured matmul throughput (FLOP/s)
    host_bw: float               # measured memory bandwidth (bytes/s)

    @property
    def ratio(self) -> float:
        """measured / roofline — the cross-check the tests bound."""
        return self.measured_round_s / self.roofline_round_s

    def mean_epoch_s(self, time_scale: float = 1.0) -> float:
        """The simulated epoch duration this measurement anchors:
        one local-training round scaled by `time_scale` (the paper's
        scaled-duration simulation knob)."""
        return self.measured_round_s * time_scale


def _measure_host_peaks(dim: int = 256, iters: int = 8):
    """Measured host peaks for the roofline cross-check: achievable
    matmul FLOP/s and memory copy bandwidth at a scale comparable to
    the smoke model's ops, so the estimate carries the same dispatch
    overhead the measured step pays."""
    a = jnp.asarray(np.random.RandomState(0).randn(dim, dim), jnp.float32)
    f = jax.jit(lambda x: x @ x)
    jax.block_until_ready(f(a))
    t0 = time.perf_counter()
    for _ in range(iters):
        a = f(a)
    jax.block_until_ready(a)
    flops_s = iters * 2.0 * dim ** 3 / (time.perf_counter() - t0)

    big = jnp.asarray(np.zeros((1 << 22,), np.float32))  # 16 MB
    g = jax.jit(lambda x: x + 1.0)
    jax.block_until_ready(g(big))
    t0 = time.perf_counter()
    out = big
    for _ in range(iters):
        out = g(out)
    jax.block_until_ready(out)
    bw = iters * 2.0 * big.size * 4 / (time.perf_counter() - t0)
    return flops_s, bw


def calibrate(hooks: MeshTrainerHooks, warmup: int = 1,
              iters: int = 2) -> StepCalibration:
    """Measure one round's wall-clock and cross-check it against the
    roofline estimate built from the compiled module's HLO FLOP/byte
    counts and measured host peaks. Host devices share one physical
    CPU, so per-device counts scale by the device (client) count and
    the terms combine serially (`combine="sum"`)."""
    from repro.launch import hlo_analysis as HA
    from repro.launch.roofline import estimate_step_time

    measured = hooks.measure_round_s(warmup=warmup, iters=iters)
    batches = hooks._next_batches()
    with compat.set_mesh(hooks.mesh):
        compiled = hooks._local_fn.lower(
            hooks.params_stk, hooks.mu_stk, batches).compile()
    hc = HA.analyze_hlo_text(compiled.as_text())
    n = len(hooks.clients)
    flops, nbytes = hc.flops * n, hc.hbm_bytes * n
    peak_flops, bw = _measure_host_peaks()
    roofline = estimate_step_time(flops, nbytes, peak_flops=peak_flops,
                                  hbm_bw=bw, combine="sum")
    return StepCalibration(measured_round_s=measured,
                           roofline_round_s=roofline, flops=flops,
                           bytes_accessed=nbytes,
                           host_peak_flops=peak_flops, host_bw=bw)


def calibrated_profiles(profiles: Sequence[ClientProfile],
                        cal: StepCalibration,
                        time_scale: float = 1.0) -> List[ClientProfile]:
    """Rewrite each profile's `mean_epoch_s` from the measurement —
    simulated durations anchored to real compute instead of config
    guesses. Relative client speed (each profile's epoch time vs the
    cohort mean) is preserved so heterogeneity survives calibration."""
    base = float(np.mean([p.mean_epoch_s for p in profiles]))
    anchor = cal.mean_epoch_s(time_scale)
    return [dataclasses.replace(
        p, mean_epoch_s=anchor * (p.mean_epoch_s / base if base > 0
                                  else 1.0))
            for p in profiles]
