"""Shared FL-runner types: the trainer attachment interface and the run
result record. Engine-agnostic — both the sync barrier engine and the
async buffered engine produce the same `RunResult` shape, which is what
lets `benchmarks/table1.py` treat `fedcostaware_async` as just another
column."""
from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import Dict, List, Optional

from repro.fl.telemetry import Segment


class TrainerHooks:
    """Optional attachment for real model training."""

    def run_local(self, client: str, round_idx: int) -> None:  # pragma: no cover
        """Execute the client's local training for `round_idx` (called
        at the simulated completion instant of the epoch)."""
        pass

    def aggregate(self, participants: List[str], round_idx: int,
                  staleness: Optional[Dict[str, int]] = None) -> None:  # pragma: no cover
        """Fold the participants' buffered updates into the global model.

        `staleness` maps each participant to the number of aggregation
        rounds that fired between its dispatch and this aggregation
        (always 0 under the synchronous barrier; FedBuff-style async
        engines report how stale each buffered update is so the
        implementation can discount it, e.g. by 1/sqrt(1+staleness)).
        Implementations overriding the legacy 2-argument signature keep
        working — engines sniff once at construction
        (`aggregate_accepts_staleness`) and only pass `staleness` to
        hooks that accept it, with a `DeprecationWarning` for the
        legacy form.
        """
        pass

    def update_payload(self, quantized: bool = False):  # pragma: no cover
        """The wire size of one client update these hooks produce, as a
        `repro.comms.payload.UpdatePayload` — or None when the hooks
        have no real parameters to size (the default). When non-None,
        the runner builds a comms model from it (it wins over the
        modeled `FLRunConfig.update_payload_mb`)."""
        return None


def aggregate_accepts_staleness(hooks: Optional[TrainerHooks]) -> bool:
    """Whether `hooks.aggregate` accepts the modern `staleness` kwarg.

    Engines call this exactly once at construction and cache the answer
    — the per-round `inspect.signature` sniffing it replaces showed up
    in profiles and re-warned nothing. The legacy 2-argument override
    (`aggregate(participants, round_idx)`) still works but now draws a
    `DeprecationWarning` naming the hook class; hooks whose signature
    cannot be inspected (builtins, C callables) are conservatively
    treated as legacy, silently.
    """
    if hooks is None:
        return False
    try:
        sig = inspect.signature(hooks.aggregate)
    except (TypeError, ValueError):
        return False
    accepts = ("staleness" in sig.parameters
               or any(p.kind is inspect.Parameter.VAR_KEYWORD
                      for p in sig.parameters.values()))
    if not accepts:
        warnings.warn(
            f"{type(hooks).__name__}.aggregate uses the legacy "
            f"2-argument signature; add a `staleness=None` keyword "
            f"(async engines report per-update staleness through it)",
            DeprecationWarning, stacklevel=2)
    return accepts


@dataclasses.dataclass
class RunResult:
    """Everything a finished (or replayed) run reports."""
    total_cost: float
    per_client_cost: Dict[str, float]
    makespan_s: float
    timeline: List[Segment]
    cost_curve: List[dict]            # {t, client, cum_cost} at round ends
    rounds_completed: int
    excluded_clients: List[str]
    per_round_participants: List[List[str]]
    # preemption-resilience metrics (live runs only; replayed results
    # keep the defaults — the event log does not record lost work):
    # client-seconds of training redone because a reclaim landed after
    # the last surviving checkpoint, and how many tracked instances the
    # spot market took (deliberate drain terminations not included)
    lost_work_s: float = 0.0
    n_preemptions: int = 0
    # storage dollars of warning-window checkpoint writes (S3 PUT +
    # per-MB egress, the provider's StorageRates) — a subset of
    # total_cost; rebuilt on replay from CheckpointBilled events
    checkpoint_cost: float = 0.0
    # egress dollars of client-update uploads (per-MB TransferRates of
    # the sending provider) — a subset of total_cost; rebuilt on
    # replay from TransferBilled events. Zero unless the run models
    # comms (repro.comms) with non-zero rates.
    comm_cost: float = 0.0
    # False when `per_client_cost` does not account for `total_cost`:
    # a replay of a pre-v6 fleet trace folds step totals whose
    # summaries carry no per-client attribution, so the breakdown is
    # *absent* (empty), not a claim that every client cost zero
    has_client_costs: bool = True
