"""Run telemetry: the Fig-4 style client-state timeline.

Split out of the old monolithic runner so every `RoundEngine` (sync,
async, future engines) records state transitions through one small,
engine-agnostic recorder.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List


@dataclasses.dataclass
class Segment:
    client: str
    state: str          # spinup | training | idle | savings
    t0: float
    t1: float


class TimelineRecorder:
    """Per-client open/close segment bookkeeping against simulated time."""

    def __init__(self, clock: Callable[[], float]):
        self._clock = clock
        self.segments: List[Segment] = []

    def mark(self, client: str, state: str):
        """Close the client's previous timeline segment, open `state`.
        `state == "done"` closes without opening a new segment."""
        t = self._clock()
        for seg in reversed(self.segments):
            if seg.client == client and seg.t1 < 0:
                seg.t1 = t
                break
        if state != "done":
            self.segments.append(Segment(client, state, t, -1.0))

    def close(self):
        """End of run: close every still-open segment at the current time."""
        t = self._clock()
        for seg in self.segments:
            if seg.t1 < 0:
                seg.t1 = t
