"""Run telemetry as pure bus consumers.

`TimelineRecorder` (the Fig-4 client-state timeline) and
`CostCurveRecorder` (the Fig-5 cumulative cost curve) are driven
entirely by engine-level telemetry events (`ClientStateChanged`,
`RoundCompleted`, `RunCompleted`) — they never read the simulator
clock. The same consumer therefore works in two modes:

  live    — subscribed to the run's bus while the simulation executes
  replay  — subscribed to a fresh bus fed by `EventReplayer`
            (core.eventlog), rebuilding timelines / costs offline from
            a recorded `.events.jsonl` without invoking `CloudSimulator`

`replay_result` is the offline entry point: it wires replay-mode
consumers (including a price-book-free `CostAccountant`) to a fresh bus,
replays a trace, and assembles a full `RunResult` — what
`benchmarks/fig4_timeline.py --replay` / `fig5_costs.py --replay` render
from.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, List, Tuple, Union

from repro.core.events import (ClientStateChanged, EventBus, RoundCompleted,
                               RunCompleted)


@dataclasses.dataclass
class Segment:
    """One closed span of a client's Fig-4 operational state."""
    client: str
    state: str          # spinup | training | uploading | idle | savings
    t0: float
    t1: float


class TimelineRecorder:
    """Per-client open/close segment bookkeeping off `ClientStateChanged`
    events: each event closes the client's previous segment at `ev.t`
    and opens `ev.state` ("done" closes without opening)."""

    def __init__(self, bus: EventBus):
        self.segments: List[Segment] = []
        bus.subscribe(ClientStateChanged, self._on_state)

    def _on_state(self, ev: ClientStateChanged):
        self.mark(ev.client, ev.state, ev.t)

    def mark(self, client: str, state: str, t: float):
        """Close the client's open segment at `t` and open `state`
        ("done" closes without opening)."""
        for seg in reversed(self.segments):
            if seg.client == client and seg.t1 < 0:
                seg.t1 = t
                break
        if state != "done":
            self.segments.append(Segment(client, state, t, -1.0))

    def close(self, t: float):
        """Safety net: close every still-open segment at `t`. A no-op on
        complete streams — engines publish "done" for every client."""
        for seg in self.segments:
            if seg.t1 < 0:
                seg.t1 = t

def state_totals(segments: List[Segment]) -> Dict[Tuple[str, str], float]:
    """`TimelineRecorder.state_totals` over an already-built segment list
    (e.g. a `RunResult.timeline`)."""
    totals: Dict[Tuple[str, str], float] = {}
    for seg in segments:
        key = (seg.client, seg.state)
        totals[key] = totals.get(key, 0.0) + (seg.t1 - seg.t0)
    return totals


class CostCurveRecorder:
    """Rebuilds the Fig-5 cost curve from `RoundCompleted` /
    `RunCompleted` events: one `{t, client, cum_cost, round}` record per
    (event, client), reading the cost snapshots the engine embedded at
    aggregation time. The final (`RunCompleted`) records carry the
    drain-time `t` rather than the engine-finish `t` of a live run's
    last snapshot; costs are frozen by then, so the dollar values are
    identical.
    """

    def __init__(self, bus: EventBus):
        self.records: List[dict] = []
        bus.subscribe(RoundCompleted, self._on_round)
        bus.subscribe(RunCompleted, self._on_run)

    def _append(self, t: float, round_idx: int, client_costs):
        for c, cost in client_costs.items():
            self.records.append({"t": t, "client": c, "cum_cost": cost,
                                 "round": round_idx})

    def _on_round(self, ev: RoundCompleted):
        self._append(ev.t, ev.round_idx, ev.client_costs)

    def _on_run(self, ev: RunCompleted):
        self._append(ev.t, ev.final_round_idx, ev.client_costs)


# ---------------------------------------------------------------------------
# Offline replay -> RunResult.
# ---------------------------------------------------------------------------
def replay_result(source: Union[str, Path, "EventReplayer"]) -> "RunResult":
    """Rebuild a `RunResult` from a recorded event log.

    Costs come from a replay-mode `CostAccountant` folding the recorded
    `BillingTick`s (not from the `RunCompleted` summary), so replayed
    totals are an independent check against the live run — the
    differential oracle the golden-trace tests rely on.
    """
    from repro.cloud.accounting import CostAccountant
    from repro.core.eventlog import EventReplayer
    from repro.fl.types import RunResult

    replayer = source if isinstance(source, EventReplayer) \
        else EventReplayer.load(source)

    bus = EventBus()
    accountant = CostAccountant(bus)
    timeline = TimelineRecorder(bus)
    curve = CostCurveRecorder(bus)
    per_round: List[List[str]] = []
    summary: List[RunCompleted] = []
    bus.subscribe(RoundCompleted,
                  lambda ev: per_round.append(list(ev.participants)))
    bus.subscribe(RunCompleted, summary.append)

    replayer.replay(bus)

    if not summary:
        raise ValueError("event log has no RunCompleted summary "
                         "(truncated recording?)")
    done = summary[-1]
    timeline.close(done.t)
    # union of the summary's clients and everyone the accountant saw a
    # dollar for — fleet traces leave `RunCompleted.client_costs` empty
    # and attribute through FleetStepSummary.client_cost_delta instead
    clients = sorted(set(done.client_costs) | set(accountant.per_client()))
    has_clients = accountant.has_client_costs()
    return RunResult(
        total_cost=accountant.total_cost(),
        per_client_cost=({c: accountant.client_cost(c) for c in clients}
                         if has_clients else {}),
        makespan_s=done.makespan_s,
        timeline=timeline.segments,
        cost_curve=curve.records,
        rounds_completed=done.rounds_completed,
        excluded_clients=list(done.excluded_clients),
        per_round_participants=per_round,
        checkpoint_cost=accountant.checkpoint_cost_total(),
        comm_cost=accountant.transfer_cost_total(),
        has_client_costs=has_clients)
