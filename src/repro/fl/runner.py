"""FL-on-cloud runner: drives synchronous FL rounds through the cloud
simulator under a scheduling policy (on_demand / spot / fedcostaware).

This reproduces the paper's experiment harness: client epoch durations
come from heterogeneity profiles (`ClientProfile`), instances accrue real
(simulated) dollar costs, and the FedCostAware scheduler terminates /
pre-warms instances per Listing 1. Optionally a `TrainerHooks` object
attaches *real JAX training* so the run produces an actual global model
(used by the end-to-end examples); simulation time is decoupled from
wall-clock, mirroring the paper's scaled-duration simulation setup for
MNIST/CIFAR.

Outputs: per-client costs, a Fig-4 style state timeline, a Fig-5 style
cumulative cost curve, and the trained model (when hooks attached).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.common.config import (CloudConfig, FLRunConfig, SchedulerConfig,
                                 ClientProfile)
from repro.cloud.simulator import (CloudSimulator, Instance, RUNNING,
                                   SPINNING_UP)
from repro.core.policies import Policy, get_policy, make_scheduler


@dataclasses.dataclass
class Segment:
    client: str
    state: str          # spinup | training | idle | savings
    t0: float
    t1: float


class TrainerHooks:
    """Optional attachment for real model training."""

    def run_local(self, client: str, round_idx: int) -> None:  # pragma: no cover
        pass

    def aggregate(self, participants: List[str], round_idx: int) -> None:  # pragma: no cover
        pass


@dataclasses.dataclass
class RunResult:
    total_cost: float
    per_client_cost: Dict[str, float]
    makespan_s: float
    timeline: List[Segment]
    cost_curve: List[dict]            # {t, client, cum_cost} at round ends
    rounds_completed: int
    excluded_clients: List[str]
    per_round_participants: List[List[str]]


class FLCloudRunner:
    def __init__(self, run_cfg: FLRunConfig,
                 cloud_cfg: Optional[CloudConfig] = None,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 hooks: Optional[TrainerHooks] = None,
                 seed: Optional[int] = None):
        self.run_cfg = run_cfg
        self.cloud_cfg = cloud_cfg or CloudConfig()
        self.sched_cfg = sched_cfg or SchedulerConfig()
        self.policy: Policy = get_policy(run_cfg.policy)
        seed = run_cfg.seed if seed is None else seed
        self.sim = CloudSimulator(self.cloud_cfg, seed=seed)
        self.scheduler = make_scheduler(
            self.policy, self.sched_cfg, self.cloud_cfg.spin_up_mean_s)
        self.hooks = hooks
        self._rng = np.random.RandomState(seed + 101)

        self.profiles: Dict[str, ClientProfile] = {
            c.name: c for c in run_cfg.clients}
        for c in run_cfg.clients:
            self.scheduler.ledger.register(c.name, c.budget)

        self.instances: Dict[str, Optional[Instance]] = {
            c.name: None for c in run_cfg.clients}
        self._fresh: Dict[int, bool] = {}       # iid -> no epoch done yet
        self._pending_task: Dict[str, Optional[int]] = {}  # client->round
        self._train_start: Dict[str, float] = {}
        self._train_duration: Dict[str, float] = {}
        self._resumed: set = set()
        self._prewarm_gen: Dict[str, int] = {}
        self.timeline: List[Segment] = []
        self.cost_curve: List[dict] = []
        self._round_pending: set = set()
        self._round_idx = -1
        self._participants: List[str] = []
        self.per_round_participants: List[List[str]] = []
        self.excluded: List[str] = []
        self._done = False

    # ------------------------------------------------------------------
    def run(self) -> RunResult:
        self.sim.schedule(0.0, lambda: self._start_round(0))
        self.sim.run_until_idle()
        total = self.sim.total_cost()
        per_client = {c: self.sim.client_cost(c) for c in self.profiles}
        return RunResult(
            total_cost=total, per_client_cost=per_client,
            makespan_s=self.sim.now, timeline=self.timeline,
            cost_curve=self.cost_curve,
            rounds_completed=self._round_idx + 1,
            excluded_clients=list(self.excluded),
            per_round_participants=self.per_round_participants)

    # ------------------------------------------------------------------
    # Round lifecycle.
    # ------------------------------------------------------------------
    def _start_round(self, r: int):
        if r >= self.run_cfg.n_epochs:
            self._finish_run()
            return
        self._round_idx = r
        self.scheduler.begin_round(r)
        # elastic scaling: clients may join at a later round (§V future
        # work); budget exhaustion below is the symmetric leave path.
        clients = [c for c, p in self.profiles.items()
                   if p.join_round <= r]
        if self.policy.enforce_budgets and r >= 1:
            before = set(c for c in clients
                         if not self.scheduler.ledger.is_excluded(c))
            self._sync_budgets()
            clients = self.scheduler.screen_participants(
                [c for c in clients], self._spot_price_of)
            newly_excluded = before - set(clients)
            for c in newly_excluded:
                self.excluded.append(c)
                inst = self.instances.get(c)
                if inst is not None:
                    self._mark(c, "idle")
                    self.sim.terminate(inst)
                    self.instances[c] = None
        if not clients:
            self._finish_run()
            return
        self._participants = clients
        self.per_round_participants.append(list(clients))
        self._round_pending = set(clients)
        for c in clients:
            self._dispatch(c, r)

    def _dispatch(self, c: str, r: int):
        inst = self.instances.get(c)
        t = self.sim.now
        if inst is not None and inst.state == RUNNING:
            cold = self._fresh.get(inst.iid, True)
            self.scheduler.register_dispatch(c, t, cold, False)
            self._begin_training(c, cold)
        elif inst is not None and inst.state == SPINNING_UP:
            # pre-warmed instance still booting: task queued until ready
            self._pending_task[c] = r
            self.scheduler.register_dispatch(c, t, True, True)
        else:
            self._pending_task[c] = r
            self.scheduler.register_dispatch(c, t, True, True)
            self._request_instance(c)

    def _request_instance(self, c: str):
        prof = self.profiles[c]
        zone = prof.zone
        if zone is None and self.policy.pick_cheapest_zone:
            zone, _ = self.sim.prices.cheapest_zone(self.sim.now)
        inst = self.sim.request_instance(
            c, zone=zone, on_demand=self.policy.on_demand,
            on_ready=self._on_ready, on_preempt=self._on_preempt)
        self.instances[c] = inst
        self._fresh[inst.iid] = True
        self._mark(c, "spinup")
        return inst

    def _on_ready(self, inst: Instance):
        c = inst.client
        if self._pending_task.get(c) is not None:
            self._pending_task[c] = None
            self._begin_training(c, cold=True)
        else:
            self._mark(c, "idle")   # pre-warmed and waiting for next round

    # ------------------------------------------------------------------
    # Local training execution (simulated duration + optional real JAX).
    # ------------------------------------------------------------------
    def _sample_duration(self, c: str, cold: bool) -> float:
        prof = self.profiles[c]
        base = prof.mean_epoch_s * (prof.cold_multiplier if cold else 1.0)
        jit = float(np.exp(self._rng.randn() * prof.jitter))
        return base * jit

    def _begin_training(self, c: str, cold: bool):
        r = self._round_idx
        dur = self._sample_duration(c, cold)
        self._train_start[c] = self.sim.now
        self._train_duration[c] = dur
        self._mark(c, "training")
        inst = self.instances[c]
        iid = inst.iid
        self.sim.schedule_in(dur, lambda: self._finish_training(c, r, iid))

    def _finish_training(self, c: str, r: int, iid: int):
        inst = self.instances.get(c)
        if inst is None or inst.iid != iid or r != self._round_idx:
            return                                  # stale (preempted)
        if c not in self._round_pending:
            return
        t = self.sim.now
        dur = t - self._train_start[c]
        cold = self._fresh.get(inst.iid, True)
        spin_obs = None
        if cold and inst.t_ready is not None:
            spin_obs = inst.t_ready - inst.t_request
        self._fresh[inst.iid] = False
        if c in self._resumed:
            # Partial (resumed) epochs would corrupt the epoch-time EMAs;
            # only the spin-up observation is still valid.
            self._resumed.discard(c)
            s = self.scheduler.states[c]
            s.finished = True
            s.finish_time = t
            if spin_obs is not None:
                self.scheduler.est.observe_spin_up(c, spin_obs)
        else:
            self.scheduler.on_result(c, t, dur, cold, spin_obs)
        if self.hooks:
            self.hooks.run_local(c, r)
        self._round_pending.discard(c)
        self._mark(c, "idle")

        if self.policy.manage_lifecycle and self._round_pending:
            more = (r + 1) < self.run_cfg.n_epochs
            prewarm_t = self.scheduler.evaluate_termination(c, t, more)
            if prewarm_t is not None:
                self.sim.terminate(inst)
                self.instances[c] = None
                self._mark(c, "savings")
                if math.isfinite(prewarm_t):
                    self._schedule_prewarm(c, prewarm_t)

        if not self._round_pending:
            self._end_round(r)

    def _schedule_prewarm(self, c: str, t: float):
        gen = self._prewarm_gen.get(c, 0) + 1
        self._prewarm_gen[c] = gen

        def fire():
            if self._prewarm_gen.get(c) != gen or self._done:
                return
            # stale if queue entry moved later (§III-D adjustment)
            q_t = self.scheduler.prewarm_queue.get(c)
            if q_t is not None and q_t > self.sim.now + 1e-6:
                self._schedule_prewarm(c, q_t)
                return
            if self.instances.get(c) is None:
                self._request_instance(c)

        self.sim.schedule(max(t, self.sim.now), fire)

    # ------------------------------------------------------------------
    # Preemption (§III-D).
    # ------------------------------------------------------------------
    def _on_preempt(self, inst: Instance):
        c = inst.client
        if self.instances.get(c) is None or self.instances[c].iid != inst.iid:
            return
        self.instances[c] = None
        was_training = c in self._round_pending and c in self._train_start
        if not was_training:
            # idle / pre-warmed instance lost: next dispatch will re-request
            self._mark(c, "savings")
            return
        # Progress up to the last periodic checkpoint survives (§III-D):
        # the client reloads from cloud storage and resumes mid-epoch.
        start = self._train_start[c]
        elapsed = max(self.sim.now - start, 0.0)
        ck = self.sched_cfg.checkpoint_every_s
        preserved = math.floor(elapsed / ck) * ck
        remaining = max(self._train_duration[c] - preserved, 1.0)
        r = self._round_idx

        def resume(i: Instance):
            if self.instances.get(c) is not i or r != self._round_idx:
                return
            self._resumed.add(c)
            self._train_start[c] = self.sim.now
            self._train_duration[c] = remaining
            self._mark(c, "training")
            self.sim.schedule_in(
                remaining, lambda: self._finish_training(c, r, i.iid))

        zone = None
        if not self.policy.pick_cheapest_zone:
            zone = self.profiles[c].zone
        inst2 = self.sim.request_instance(
            c, zone=zone, on_demand=self.policy.on_demand,
            on_ready=resume, on_preempt=self._on_preempt)
        self.instances[c] = inst2
        self._fresh[inst2.iid] = True
        self._mark(c, "spinup")
        # §III-D dynamic schedule adjustment: push back pre-warm targets of
        # already-terminated clients so they stay off while this client
        # recovers; runner reschedules each moved spin-up event.
        spin_est = self.scheduler.est.model(c).spin_up.get(
            self.cloud_cfg.spin_up_mean_s)
        recovery_finish = self.sim.now + spin_est + remaining
        moved = self.scheduler.on_preemption_recovery(c, recovery_finish)
        for other, new_t in moved.items():
            self._schedule_prewarm(other, new_t)

    # ------------------------------------------------------------------
    def _end_round(self, r: int):
        if self.hooks:
            self.hooks.aggregate(list(self._participants), r)
        self._record_costs()
        self.sim.schedule_in(1.0, lambda: self._start_round(r + 1))

    def _finish_run(self):
        self._done = True
        for c, inst in self.instances.items():
            if inst is not None:
                self.sim.terminate(inst)
                self.instances[c] = None
                self._mark(c, "done")
        self._record_costs()
        self.close_timeline()

    # ------------------------------------------------------------------
    # Accounting / reporting.
    # ------------------------------------------------------------------
    def _sync_budgets(self):
        for c in self.profiles:
            self.scheduler.ledger.sync_spend(c, self.sim.client_cost(c))

    def _spot_price_of(self, c: str) -> float:
        zone = self.profiles[c].zone
        if zone is None:
            _, p = self.sim.prices.cheapest_zone(self.sim.now)
            return p
        return self.sim.prices.price(zone, self.sim.now,
                                     self.policy.on_demand)

    def _record_costs(self):
        for c in self.profiles:
            self.cost_curve.append({
                "t": self.sim.now, "client": c,
                "cum_cost": self.sim.client_cost(c),
                "round": self._round_idx,
            })

    def _mark(self, c: str, state: str):
        """Close the client's previous timeline segment, open `state`."""
        t = self.sim.now
        for seg in reversed(self.timeline):
            if seg.client == c and seg.t1 < 0:
                seg.t1 = t
                break
        if state != "done":
            self.timeline.append(Segment(c, state, t, -1.0))

    def close_timeline(self):
        for seg in self.timeline:
            if seg.t1 < 0:
                seg.t1 = self.sim.now
