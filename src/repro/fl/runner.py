"""FL-on-cloud runner: the thin composition root.

Wires the layered stack together and drains the simulator:

  EventBus          typed pub/sub connecting every layer (core.events)
  CloudSimulator    discrete-event cloud; publishes instance lifecycle +
                    billing events (cloud.simulator)
  CostAccountant    incremental per-client dollar accounting off the
                    billing events (cloud.accounting)
  ClusterManager    instance lifecycle: request / terminate / pre-warm /
                    standby / resume-from-checkpoint (fl.cluster)
  DirectiveExecutor applies typed strategy directives against the
                    cluster (fl.cluster)
  StrategyStack     the policy's composed scheduling discipline —
                    Listing-1 lifecycle, §III-E budget screening,
                    preemption-notice reaction, forecast pre-warming
                    (core.strategy), sharing one FedCostAware decision
                    core (core.scheduler)
  RoundEngine       FL-round semantics — SyncEngine reproduces the
                    paper's synchronous barrier (Table I); the
                    AsyncBufferedEngine adds FedBuff-style buffered
                    asynchronous rounds (fl.engines)

The policy (`on_demand` / `spot` / `fedcostaware` / `fedcostaware_async`
or any `register_policy`-ed composition) selects the market, the
strategy composition, and the engine. Optionally a `TrainerHooks`
object attaches *real JAX training* so the run produces an actual
global model; simulated time stays decoupled from wall-clock, mirroring
the paper's scaled-duration simulation setup for MNIST/CIFAR.

Outputs (`RunResult`): per-client costs, a Fig-4 style state timeline, a
Fig-5 style cumulative cost curve, and the trained model (when hooks
attached).

Every run is recordable: `record=True` attaches an `EventRecorder`
(core.eventlog) capturing the full typed event stream in memory, and
`record_to=<path>` additionally persists it as JSONL at the end of
`run()`. A recorded trace replays offline through
`repro.fl.telemetry.replay_result` — same timelines, same costs, no
simulation.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional, Union

import numpy as np

from repro.checkpoint.store import MemoryStore, ObjectStore
from repro.cloud.accounting import CostAccountant
from repro.cloud.pricing import SpotMarket
from repro.comms.channel import CommsModel, UplinkChannel
from repro.comms.payload import UpdatePayload
from repro.cloud.simulator import CloudSimulator
from repro.common.config import CloudConfig, FLRunConfig, SchedulerConfig
from repro.core.events import EventBus, RunCompleted
from repro.core.eventlog import EventRecorder
from repro.core.policies import Policy, get_policy, make_scheduler
from repro.core.strategy import StrategyContext, StrategyStack
from repro.fl.cluster import ClusterManager, DirectiveExecutor
from repro.fl.engines import EngineContext, get_engine
from repro.forecast.feed import ObservableFeed
from repro.fl.fleet import FleetRunner, fleet_supported
from repro.fl.telemetry import Segment, TimelineRecorder
from repro.fl.types import RunResult, TrainerHooks

__all__ = ["FLCloudRunner", "RunResult", "Segment", "TrainerHooks"]


class FLCloudRunner:
    """Compose a full FL-on-cloud run and execute it (see module
    docstring for the layer map; docs/architecture.md for the long
    form)."""

    def __init__(self, run_cfg: FLRunConfig,
                 cloud_cfg: Optional[CloudConfig] = None,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 hooks: Optional[TrainerHooks] = None,
                 seed: Optional[int] = None,
                 record_to: Optional[Union[str, Path]] = None,
                 record: bool = False,
                 ckpt_store: Optional[ObjectStore] = None):
        self.run_cfg = run_cfg
        self.cloud_cfg = cloud_cfg or CloudConfig()
        self.sched_cfg = sched_cfg or SchedulerConfig()
        self.policy: Policy = get_policy(run_cfg.policy)
        if run_cfg.cross_provider is not None:
            self.policy = dataclasses.replace(
                self.policy, cross_provider=run_cfg.cross_provider)
        if run_cfg.on_warning is not None:
            self.policy = dataclasses.replace(
                self.policy, on_warning=run_cfg.on_warning)
        if run_cfg.engine is not None:
            self.policy = dataclasses.replace(
                self.policy, engine=run_cfg.engine)
        seed = run_cfg.seed if seed is None else seed
        self.record_to = record_to
        # the simulated S3: warning-window client snapshots land here
        # (checkpoint.snapshots); callers may pass a FileStore to keep
        # them on disk
        self.ckpt_store = ckpt_store or MemoryStore()

        # fleet dispatch: population runs, fleet=True, or explicit
        # client lists at/above CloudConfig.fleet_threshold under a
        # fleet-capable policy take the struct-of-arrays hot path
        # (repro.fl.fleet) instead of the per-object event stack below
        self._fleet: Optional[FleetRunner] = None
        if self._fleet_mode():
            if hooks is not None:
                raise ValueError(
                    "the fleet path does not support TrainerHooks; "
                    "pass fleet=False to force the per-object engines")
            if run_cfg.update_payload_mb is not None:
                raise ValueError(
                    "the fleet path does not model comms; unset "
                    "update_payload_mb or pass fleet=False")
            self.bus = EventBus()
            self.recorder = None
            if record or record_to is not None:
                self.recorder = EventRecorder(self.bus, meta={
                    "dataset": run_cfg.dataset, "policy": run_cfg.policy,
                    "seed": seed, "n_epochs": run_cfg.n_epochs,
                    "clients": [c.name for c in run_cfg.clients]})
            market = SpotMarket.for_cloud_config(self.cloud_cfg,
                                                 seed=seed)
            self._fleet = FleetRunner(run_cfg, self.cloud_cfg,
                                      self.sched_cfg, self.policy,
                                      market, self.bus, seed)
            # the per-object layers are never built on this path
            self.sim = None
            self.accountant = None
            self.scheduler = None
            self.cluster = None
            self.executor = None
            self.feed = None
            self.strategies = None
            self.timeline = None
            self.engine = None
            self.hooks = hooks
            return

        # layer wiring — construction order fixes bus subscription order:
        # the recorder (wildcard) sees everything first, accounting sees
        # cloud events before the cluster re-publishes them as client
        # events, and engines only ever see client events.
        self.bus = EventBus()
        # only attached on request: encoding every event and retaining
        # the stream is pure overhead for callers that just want a
        # RunResult. `record=True` keeps it in memory (self.recorder);
        # `record_to` additionally persists it after run().
        self.recorder: Optional[EventRecorder] = None
        if record or record_to is not None:
            self.recorder = EventRecorder(self.bus, meta={
                "dataset": run_cfg.dataset, "policy": run_cfg.policy,
                "seed": seed, "n_epochs": run_cfg.n_epochs,
                "clients": [c.name for c in run_cfg.clients]})
        self.sim = CloudSimulator(self.cloud_cfg, seed=seed, bus=self.bus)
        self.accountant = CostAccountant(self.bus, self.sim.market,
                                         clock=lambda: self.sim.now)
        # the FedCostAware decision core (estimator + ledger): shared
        # state behind every strategy component; engines never touch it
        self.scheduler = make_scheduler(
            self.policy, self.sched_cfg, self.cloud_cfg.spin_up_mean_s)
        self.profiles = {c.name: c for c in run_cfg.clients}
        for c in run_cfg.clients:
            self.scheduler.ledger.register(c.name, c.budget)
        self.timeline = TimelineRecorder(self.bus)
        # the fire-time staleness check reads pre-warm targets through
        # the strategy stack (constructed just below; targets are only
        # consulted at simulated fire time, long after __init__)
        self.cluster = ClusterManager(
            self.sim, self.policy, self.profiles, self.scheduler,
            prewarm_target_of=lambda c: self.strategies.prewarm_target(c))
        self.executor = DirectiveExecutor(
            self.cluster, ckpt_store=self.ckpt_store,
            ckpt_size_mb=self.sched_cfg.warning_ckpt_size_mb,
            trace=run_cfg.trace_directives)
        # the tenant-observable market surface (repro.forecast):
        # learned strategies attach their predictors here, and the
        # observable hazard fallback below routes through it. Built
        # after every simulator/accounting subscription so its pure
        # observer handlers run last and cannot reorder anything.
        self.feed = ObservableFeed.for_market(
            self.sim.market, self.cloud_cfg.preemption_rate_per_hr,
            bus=self.bus)
        self.strategies = StrategyStack.from_policy(
            self.policy, StrategyContext(
                policy=self.policy, sched=self.scheduler,
                sched_cfg=self.sched_cfg, bus=self.bus,
                now=lambda: self.sim.now,
                schedule_in=self.sim.schedule_in,
                clients=tuple(self.profiles),
                spin_up_default=self.cloud_cfg.spin_up_mean_s,
                instance_of=self.cluster.instance_of,
                standby_of=self.cluster.standby_of,
                spot_price_of=self.cluster.spot_price_of,
                spend_of=self.accountant.client_cost,
                hazard_of=self._hazard_of,
                observable_hazard_of=self._observable_hazard_of,
                ckpt_cost_of=lambda provider, mb: (
                    self.sim.market.provider_of(provider)
                    .storage.checkpoint_cost(mb)),
                is_shutdown=lambda: self.cluster.is_shutdown,
                feed=self.feed,
                ckpt_store=self.ckpt_store,
                executor=self.executor))
        self.hooks = hooks
        self.comms = self._build_comms()
        self.engine = get_engine(self.policy.engine)(EngineContext(
            run_cfg=run_cfg, cloud_cfg=self.cloud_cfg,
            sched_cfg=self.sched_cfg, policy=self.policy, sim=self.sim,
            cluster=self.cluster, strategies=self.strategies,
            accountant=self.accountant, timeline=self.timeline,
            rng=np.random.RandomState(seed + 101), hooks=hooks,
            ckpt_store=self.ckpt_store, comms=self.comms))

    def _build_comms(self) -> Optional[CommsModel]:
        """Comms modeling is strictly opt-in: hooks that expose a real
        payload win over the modeled `FLRunConfig.update_payload_mb`;
        with neither, uploads stay instantaneous and free and no comms
        events are published (every pre-v7 stream is unchanged)."""
        quantized = self.run_cfg.quantize_updates
        payload: Optional[UpdatePayload] = None
        if self.hooks is not None:
            # getattr: duck-typed hooks predating `update_payload` pass
            sizer = getattr(self.hooks, "update_payload", None)
            payload = sizer(quantized=quantized) if sizer else None
        if payload is None and self.run_cfg.update_payload_mb is not None:
            payload = UpdatePayload.from_mb(self.run_cfg.update_payload_mb,
                                            quantized=quantized)
        if payload is None:
            return None
        return CommsModel(payload, UplinkChannel.from_market(
            self.sim.market))

    # ------------------------------------------------------------------
    def _fleet_mode(self) -> bool:
        """Decide the execution path: `FLRunConfig.fleet` forces it
        either way (population runs and cohort sampling *require* the
        fleet path); with no override, explicit client lists at or
        above `CloudConfig.fleet_threshold` under a fleet-capable
        policy are auto-promoted."""
        rc = self.run_cfg
        if rc.fleet is False:
            if rc.population is not None:
                raise ValueError(
                    "population runs require the fleet path; "
                    "fleet=False is contradictory")
            mode = False
        elif rc.population is not None or rc.fleet is True:
            if not fleet_supported(self.policy):
                raise ValueError(
                    f"policy {self.policy.name!r} cannot run on the "
                    f"fleet path (sync engine, on_warning='ignore', "
                    f"lifecycle/budget strategies only)")
            mode = True
        else:
            mode = (fleet_supported(self.policy)
                    and len(rc.clients) >= self.cloud_cfg.fleet_threshold)
        if rc.cohort_size is not None and not mode:
            raise ValueError("cohort_size requires the fleet path "
                             "(population runs or fleet=True)")
        return mode

    # ------------------------------------------------------------------
    def _stamp_hazard_source(self, source: str) -> None:
        """Record which hazard signal the run's strategies actually
        consulted in the trace header (`hazard_source`: "oracle" |
        "observable" | "mixed"). Stamped lazily on first use, so runs
        whose strategies never poll a hazard — every default policy —
        record headers without the key, byte-identical to before."""
        if self.recorder is None:
            return
        prev = self.recorder.header.get("hazard_source")
        if prev is None:
            self.recorder.header["hazard_source"] = source
        elif prev != source:
            self.recorder.header["hazard_source"] = "mixed"

    def _observable_hazard_of(self, client: str) -> float:
        """The tenant-observable reclaim-hazard estimate (events/hour)
        for the client's tracked spot instance right now; 0 when
        untracked or on-demand. Routed through the run's
        `ObservableFeed` (`repro.forecast`): the price-derived
        price-coupled formula evaluated on published prices — how a
        real scheduler reads an interruption forecast off the market,
        with no model internals involved."""
        inst = self.cluster.instance_of(client)
        if inst is None or inst.on_demand:
            return 0.0
        self._stamp_hazard_source("observable")
        return self.feed.price_derived_hazard(
            inst.provider, inst.zone, self.sim.now) * 3600.0

    def _hazard_of(self, client: str) -> float:
        """The *oracle* reclaim hazard (events/hour) for the client's
        tracked spot instance right now; 0 when untracked or
        on-demand. Uses the driving preemption model's own hazard when
        it exposes one (`PriceCoupledModel`); otherwise — e.g. under
        recorded-interruption replay, where the true reclaim times are
        not observable in advance — it falls back to the observable
        estimate, and the recorded trace header says so
        (`hazard_source: "observable"`) instead of silently
        substituting."""
        inst = self.cluster.instance_of(client)
        if inst is None or inst.on_demand:
            return 0.0
        hazard = getattr(self.sim.preemption_model, "hazard", None)
        if hazard is None:
            return self._observable_hazard_of(client)
        self._stamp_hazard_source("oracle")
        return hazard(inst.provider, inst.zone, self.sim.now) * 3600.0

    # ------------------------------------------------------------------
    def run(self) -> RunResult:
        """Execute the run to completion: start the engine, drain the
        simulator, publish the terminal `RunCompleted` summary, persist
        the event log if requested, and return the `RunResult`."""
        if self._fleet is not None:
            res = self._fleet.run()
            # fleet-mode terminal summary: per-client costs live in
            # RunResult.per_client_cost and, per step, in
            # FleetStepSummary.client_cost_delta (schema v6) — the
            # terminal event stays aggregate, so client_costs is
            # deliberately empty
            self.bus.publish(RunCompleted(
                res.makespan_s, makespan_s=res.makespan_s,
                total_cost=res.total_cost, client_costs={},
                rounds_completed=res.rounds_completed,
                excluded_clients=tuple(res.excluded_clients),
                final_round_idx=res.rounds_completed - 1))
            if self.record_to is not None:
                self.recorder.dump(self.record_to)
            return res
        self.engine.start()
        self.sim.run_until_idle()
        self.timeline.close(self.sim.now)   # no-op on complete runs
        res = self.engine.result()
        # terminal summary, published after the drain: the sync engine's
        # makespan includes post-finish drain time, so only here is the
        # true makespan known. Costs are frozen once the engine finishes,
        # making this snapshot == the accountant's state at finish.
        self.bus.publish(RunCompleted(
            self.sim.now, makespan_s=res.makespan_s,
            total_cost=res.total_cost,
            client_costs=dict(res.per_client_cost),
            rounds_completed=res.rounds_completed,
            excluded_clients=tuple(res.excluded_clients),
            final_round_idx=res.rounds_completed - 1))
        if self.record_to is not None:
            self.recorder.dump(self.record_to)
        return res
