"""Core transformer layers: norms, RoPE, attention (GQA / sliding-window /
cross), MLPs, and GShard capacity-routed MoE.

All layers are pure functions over param pytrees. Shapes use
B=batch, S=query seq, T=kv seq, D=d_model, N=q heads, K=kv heads,
G=N//K (GQA group), H=head_dim, F=d_ff, E=experts, C=capacity.

Attention is computed in query chunks with the softmax row kept full —
O(chunk * T) live memory instead of O(S * T) — which is what lets the
32k-prefill cells fit during the dry-run. The Pallas flash-attention
kernel (repro.kernels.flash_attention) replaces the inner chunk loop on
TPU when ``cfg.use_pallas`` is set.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.rules import ShardingCtx, INERT


# ---------------------------------------------------------------------------
# Param schema plumbing.
# ---------------------------------------------------------------------------
class ParamSpec:
    """Declarative parameter: shape + logical axes + initializer."""

    __slots__ = ("shape", "axes", "init", "dtype")

    def __init__(self, shape, axes, init="normal", dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        self.shape = tuple(shape)
        self.axes = tuple(axes)
        self.init = init
        self.dtype = dtype

    def materialize(self, key, dtype):
        dtype = self.dtype or dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "normal":
            fan_in = self.shape[0] if self.shape else 1
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape, jnp.float32)
                    * scale).astype(dtype)
        if self.init == "embed":
            return (jax.random.normal(key, self.shape, jnp.float32)
                    * 0.02).astype(dtype)
        if callable(self.init):
            return self.init(key, self.shape).astype(dtype)
        raise ValueError(self.init)


def is_spec(x):
    return isinstance(x, ParamSpec)


def materialize_tree(schema, key, dtype):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [l.materialize(k, dtype) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(schema, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        schema, is_leaf=is_spec)


def axes_tree(schema):
    return jax.tree.map(lambda s: s.axes, schema, is_leaf=is_spec)


def stack_specs(schema, n, axis_name="layers"):
    """Prefix every spec with a stacked leading dim (for scan-over-layers)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                            s.dtype),
        schema, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------
def rms_norm_schema(d):
    return {"scale": ParamSpec((d,), ("norm",), "ones", dtype=jnp.float32)}


def rms_norm(x, p, eps):
    """RMSNorm with fp32 statistics but no materialized fp32 activation:
    the fp32 square fuses into the variance reduce, and the normalization
    multiply stays in the input dtype. (A full fp32 intermediate on the
    residual path doubles the SP-boundary all-gather bytes — GSPMD
    gathers whatever tensor feeds the projections.)"""
    dt = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = lax.rsqrt(var + eps).astype(dt)
    return x * inv * p["scale"].astype(dt)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------
def rope(x, positions, theta):
    """x: (..., S, n, H) rotated in (S) by `positions` (..., S)."""
    h = x.shape[-1]
    half = h // 2
    freq = jnp.arange(0, half, dtype=jnp.float32)
    inv = theta ** (-freq / half)                      # (half,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                             # (..., S, 1, half)
    cos = cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------
def attention_schema(cfg, cross=False):
    d, h = cfg.d_model, cfg.resolved_head_dim
    nq, nk = cfg.num_heads, cfg.num_kv_heads
    s = {
        "wq": ParamSpec((d, nq, h), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, nk, h), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, nk, h), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((nq, h, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((nq, h), ("heads", "head_dim"), "zeros")
        s["bk"] = ParamSpec((nk, h), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = ParamSpec((nk, h), ("kv_heads", "head_dim"), "zeros")
    return s


def _soft_cap(scores, cap):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _chunked_attn(q, k, v, q_positions, kv_positions, *, causal, window,
                  softcap, chunk, shard: ShardingCtx):
    """q, k, v: (B,S|T,N,H) with kv already expanded to N heads.

    Query-chunked (full softmax row per chunk): O(chunk*T) live memory —
    what lets prefill_32k compile within HBM without the Pallas kernel.
    Flat head layout (no (K,G) split) keeps GSPMD on the standard
    attention partitioning path (heads on `model`).
    """
    B, S, N, H = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(H)
    nc = max(S // chunk, 1)
    chunk = S // nc
    qr = q.reshape(B, nc, chunk, N, H).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nc, chunk)

    def body(_, qi_pi):
        qi, pi = qi_pi                              # (B,c,N,H), (c,)
        s = jnp.einsum("bqnh,btnh->bnqt", qi, k,
                       preferred_element_type=jnp.float32) * scale
        s = _soft_cap(s, softcap)
        # additive bias (chunk,T) — small, fuses into the softmax; a
        # boolean select at full score shape gets hoisted out of the layer
        # scan by XLA as a ~0.5GB loop-invariant carry.
        bias = jnp.zeros((chunk, T), jnp.float32)
        if causal:
            bias = jnp.where(kv_positions[None, :] <= pi[:, None],
                             bias, -1e30)
        if window is not None:
            bias = jnp.where(kv_positions[None, :] > pi[:, None] - window,
                             bias, -1e30)
        s = s + bias[None, None]
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bnqt,btnh->bqnh", p, v)
        return None, o

    _, out = lax.scan(body, None, (qr, qp))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, N, H)


def attention(p, x, cfg, *, kind, shard: ShardingCtx = INERT,
              cond=None, positions=None):
    """Self / sliding-window / cross attention. x: (B,S,D) -> (B,S,D)."""
    from repro.common import config as C
    B, S, D = x.shape
    nq, nk, h = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nq // nk
    cross = kind == C.CROSS_ATTN
    src = cond if cross else x
    T = src.shape[1]

    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("btd,dnh->btnh", src, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    # Inner tensors claim `model` for heads (TP) with priority over seq
    # (SP): when the head count divides the axis this is plain TP with the
    # residual stream sequence-sharded at block boundaries (Megatron-SP);
    # when it does not (24 heads on a 16-way axis), `resolve_spec` frees
    # the axis and seq claims it — attention runs sequence-parallel
    # instead of replicated.
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", None, "kv_heads", "head_dim")
    v = shard(v, "batch", None, "kv_heads", "head_dim")

    if positions is None:
        positions = jnp.arange(S)
    kv_positions = jnp.arange(T)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)

    # GQA: expand kv to the full head count. The expansion keeps GSPMD on
    # the plain-attention partitioning path and makes the head dim
    # shardable even when num_kv_heads < mesh model-axis (e.g. kv=8 on a
    # 16-way axis); the repeat of a replicated kv shard is local.
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard(k, "batch", None, "heads", "head_dim")
    v = shard(v, "batch", None, "heads", "head_dim")
    window = cfg.window_size if kind == C.LOCAL_ATTN else None
    if cfg.use_pallas and not cross:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(
            q, k, v, causal=True, window=window,
            softcap=cfg.logit_softcap)
    else:
        out = _chunked_attn(
            q, k, v, positions, kv_positions,
            causal=not cross, window=window, softcap=cfg.logit_softcap,
            chunk=min(cfg.attn_chunk, S), shard=shard)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    return shard(y, "batch", "seq", "embed_act")


def decode_attention(p, x, cfg, *, kind, cache, pos, shard: ShardingCtx = INERT,
                     cond_kv=None):
    """One-token decode. x: (B,1,D); cache: dict(k,v: (B,L,K,H)).

    Returns (y, new_cache). `pos`: (B,) current position per sequence.
    """
    from repro.common import config as C
    B, _, D = x.shape
    nq, nk, h = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = nq // nk
    cross = kind == C.CROSS_ATTN

    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]

    if cross:
        # static cross KV, precomputed at prefill time
        k, v = cond_kv["k"], cond_kv["v"]
        L = k.shape[1]
        valid = jnp.ones((B, L), bool)
        new_cache = cache
    else:
        knew = jnp.einsum("btd,dnh->btnh", x, p["wk"])
        vnew = jnp.einsum("btd,dnh->btnh", x, p["wv"])
        if cfg.qkv_bias:
            knew = knew + p["bk"]
            vnew = vnew + p["bv"]
        q = rope(q, pos[:, None], cfg.rope_theta)
        knew = rope(knew, pos[:, None], cfg.rope_theta)
        L = cache["k"].shape[1]
        if kind == C.LOCAL_ATTN:
            # ring buffer of size window
            slot = (pos % L)
        else:
            slot = pos
        bidx = jnp.arange(B)
        k = cache["k"].at[bidx, slot].set(knew[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[bidx, slot].set(vnew[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": k, "v": v}
        idx = jnp.arange(L)
        if kind == C.LOCAL_ATTN:
            valid = (idx[None] <= slot[:, None]) | (pos[:, None] >= L)
        else:
            valid = idx[None] <= pos[:, None]

    qf = q.reshape(B, nk, g, h).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qf, k.astype(jnp.float32))
    s = s / math.sqrt(h)
    s = _soft_cap(s, cfg.logit_softcap)
    s = jnp.where(valid[:, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", pr, v.astype(jnp.float32))
    o = o.reshape(B, 1, nq, h).astype(x.dtype)
    y = jnp.einsum("bsnh,nhd->bsd", o, p["wo"])
    return shard(y, "batch", None, "embed_act"), new_cache


# ---------------------------------------------------------------------------
# Dense MLP.
# ---------------------------------------------------------------------------
def mlp_schema(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp(p, x, cfg, shard: ShardingCtx = INERT):
    if cfg.mlp_kind == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
        up = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]))
    h = shard(h, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return shard(y, "batch", "seq", "embed_act")


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard capacity routing, top-k).
# ---------------------------------------------------------------------------
def moe_schema(cfg):
    d = cfg.d_model
    e, f = cfg.moe.num_experts, cfg.moe.d_ff
    s = {"router": ParamSpec((d, e), ("embed", None))}
    if cfg.mlp_kind == "swiglu":
        s["wi_gate"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"))
        s["wi_up"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"))
        s["wo"] = ParamSpec((e, f, d), ("experts", "mlp", "embed"))
    else:
        s["wi"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"))
        s["wo"] = ParamSpec((e, f, d), ("experts", "mlp", "embed"))
    return s


def moe_capacity(cfg, group_tokens: int) -> int:
    m = cfg.moe
    cap = int(math.ceil(group_tokens * m.top_k * m.capacity_factor
                        / m.num_experts))
    return max(cap, m.top_k)


def moe(p, x, cfg, shard: ShardingCtx = INERT):
    """x: (B,S,D). GShard one-hot dispatch with per-group capacity."""
    m = cfg.moe
    B, S, D = x.shape
    gs = min(m.group_size, B * S)
    assert (B * S) % gs == 0, (B, S, gs)
    ng = B * S // gs
    E, K = m.num_experts, m.top_k
    C = moe_capacity(cfg, gs)

    xg = x.reshape(ng, gs, D)
    xg = shard(xg, "batch", None, "embed_act")
    logits = jnp.einsum("gsd,de->gse", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, K)       # (ng, gs, K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (ng,gs,K,E)
    # position of each (token, slot) within its expert queue, priority by
    # (slot-major, token) order as in GShard.
    flat = onehot.transpose(0, 2, 1, 3).reshape(ng, gs * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat              # (ng, gs*K, E)
    pos = pos.reshape(ng, K, gs, E).transpose(0, 2, 1, 3)  # (ng,gs,K,E)
    pos = jnp.sum(pos * onehot, axis=-1)               # (ng, gs, K)
    within = (pos < C).astype(jnp.float32)

    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * within[..., None]
    # dispatch: (ng, gs, E, C)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, onehot, pos_oh)

    dispatch = dispatch.astype(x.dtype)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)    # (ng,E,C,D)
    xe = shard(xe, "batch", "experts", None, "embed_act")
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xe, p["wi_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["wi"]))
    h = shard(h, "batch", "experts", None, "mlp")
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])      # (ng,E,C,D)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)
    return shard(y, "batch", "seq", "embed_act"), _aux_loss(probs, onehot)


def _aux_loss(probs, onehot):
    """Load-balancing auxiliary loss (Switch-style)."""
    # probs: (ng, gs, E); onehot: (ng, gs, K, E)
    E = probs.shape[-1]
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2), axis=1)   # (ng, E)
    frac_probs = jnp.mean(probs, axis=1)                       # (ng, E)
    return jnp.mean(jnp.sum(frac_tokens * frac_probs, -1)) * E
