"""State-space layers: Mamba2 (SSD, chunked scan) and RG-LRU (Griffin).

Shapes: b=batch, s=seq, d=d_model, i=d_inner, h=ssm heads, p=head_dim,
n=d_state, g=B/C groups, w=lru width, c=chunks, q=chunk len.

The chunked SSD here is the pure-JAX reference; the Pallas kernel in
repro.kernels.ssd implements the identical chunk decomposition with VMEM
tiling and is validated against `ssd_reference` below.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import ParamSpec, rms_norm
from repro.sharding.rules import ShardingCtx, INERT


# ===========================================================================
# Mamba2 (SSD).
# ===========================================================================
def mamba2_dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nheads, conv_dim


def mamba2_schema(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = mamba2_dims(cfg)
    gn = s.n_groups * s.d_state

    def a_init(key, shape):
        lo, hi = s.a_init_range
        u = jax.random.uniform(key, shape, jnp.float32, lo, hi)
        return jnp.log(u)

    def dt_bias_init(key, shape):
        u = jax.random.uniform(key, shape, jnp.float32)
        dt = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min))
                     + math.log(s.dt_min))
        # inverse softplus
        return dt + jnp.log(-jnp.expm1(-dt))

    return {
        "wz": ParamSpec((d, d_in), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, d_in), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, gn), ("embed", None)),
        "wC": ParamSpec((d, gn), ("embed", None)),
        "wdt": ParamSpec((d, nh), ("embed", "ssm_heads")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), (None, None)),
        "conv_b": ParamSpec((conv_dim,), (None,), "zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), a_init, dtype=jnp.float32),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), dt_bias_init,
                             dtype=jnp.float32),
        "D": ParamSpec((nh,), ("ssm_heads",), "ones", dtype=jnp.float32),
        "norm": ParamSpec((d_in,), ("ssm_inner",), "ones", dtype=jnp.float32),
        "wo": ParamSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv. u: (b,s,c); w: (k,c); b: (c,)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(k))
    return out + b


def _segsum(a):
    """a: (..., q) -> (..., q, q) with out[i,j]=sum_{k=j+1..i} a_k, i>=j."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(xbar, log_a, Bm, Cm, chunk, initial_state=None):
    """Chunked state-space-duality scan (Mamba2 §6 minimal algorithm).

    xbar: (b,s,h,p)  inputs already scaled by dt
    log_a: (b,s,h)   dt * A  (negative)
    Bm, Cm: (b,s,h,n) input/output projections (already group-broadcast)
    Returns y: (b,s,h,p), final_state: (b,h,p,n)
    """
    b, s, h, p = xbar.shape
    n = Bm.shape[-1]
    nc = max(s // chunk, 1)
    q = s // nc
    xb = xbar.reshape(b, nc, q, h, p).astype(jnp.float32)
    la = log_a.reshape(b, nc, q, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, q, h, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, q, h, n).astype(jnp.float32)

    la_cs = jnp.cumsum(la, axis=2)                     # (b,c,q,h) inclusive
    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(la.transpose(0, 1, 3, 2)))     # (b,c,h,q,q)
    att = jnp.einsum("bcihn,bcjhn->bchij", Cc, Bc)
    y_diag = jnp.einsum("bchij,bchij,bcjhp->bcihp", att, L, xb)
    # 2. per-chunk end states
    decay_end = jnp.exp(la_cs[:, :, -1:, :] - la_cs)   # (b,c,q,h)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchpn", Bc, decay_end, xb)
    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(la_cs[:, :, -1, :])          # (b,c,h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                  # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + st
        return new, carry                              # emit state BEFORE chunk

    final, prev_states = lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)
    # 4. contribution of carried state to each position
    state_decay = jnp.exp(la_cs)                        # (b,c,q,h)
    y_off = jnp.einsum("bcihn,bchpn,bcih->bcihp", Cc, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(xbar.dtype), final


def mamba2_mix(p, x, cfg, shard: ShardingCtx = INERT):
    """Full Mamba2 mixing layer. x: (b,s,d) -> (b,s,d)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in, nh, conv_dim = mamba2_dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state

    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xi = jnp.einsum("bsd,di->bsi", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xi = conv_out[..., :d_in]
    Bm = conv_out[..., d_in:d_in + gn]
    Cm = conv_out[..., d_in + gn:]
    xi = shard(xi, "batch", "seq", "ssm_inner")

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                           # (nh,)
    xh = xi.reshape(b, s, nh, s_cfg.head_dim)
    hpg = nh // s_cfg.n_groups
    Bh = jnp.repeat(Bm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state),
                    hpg, axis=2)
    Ch = jnp.repeat(Cm.reshape(b, s, s_cfg.n_groups, s_cfg.d_state),
                    hpg, axis=2)

    xbar = xh * dt[..., None].astype(xh.dtype)
    log_a = dt * A
    if cfg.use_pallas:
        from repro.kernels.ssd import ops as ssd_ops
        y, _ = ssd_ops.ssd(xbar, log_a, Bh, Ch, chunk=s_cfg.chunk_size)
    else:
        y, _ = ssd_reference(xbar, log_a, Bh, Ch,
                             chunk=min(s_cfg.chunk_size, s))
    y = y + xh * p["D"][:, None].astype(y.dtype)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), {"scale": p["norm"]}, cfg.norm_eps)
    y = shard(y, "batch", "seq", "ssm_inner")
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    return shard(out, "batch", "seq", "embed_act")


def mamba2_init_state(cfg, batch, dtype):
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def mamba2_decode(p, x, cfg, state, shard: ShardingCtx = INERT):
    """One-token decode. x: (b,1,d)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    d_in, nh, _ = mamba2_dims(cfg)
    gn = s_cfg.n_groups * s_cfg.d_state

    z = jnp.einsum("bsd,di->bsi", x, p["wz"])[:, 0]
    xi = jnp.einsum("bsd,di->bsi", x, p["wx"])[:, 0]
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])[:, 0]
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])[:, 0]

    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)   # (b, conv_dim)
    window = jnp.concatenate([state["conv"], conv_in[:, None]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xi = conv_out[:, :d_in]
    Bm = conv_out[:, d_in:d_in + gn]
    Cm = conv_out[:, d_in + gn:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                # (b, nh)
    xh = xi.reshape(b, nh, s_cfg.head_dim).astype(jnp.float32)
    hpg = nh // s_cfg.n_groups
    Bh = jnp.repeat(Bm.reshape(b, s_cfg.n_groups, s_cfg.d_state), hpg, 1)
    Ch = jnp.repeat(Cm.reshape(b, s_cfg.n_groups, s_cfg.d_state), hpg, 1)
    Bh = Bh.astype(jnp.float32)

    xbar = xh * dt[..., None]
    new_ssm = (state["ssm"] * a[..., None, None]
               + jnp.einsum("bhp,bhn->bhpn", xbar, Bh))
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Ch.astype(jnp.float32))
    y = y + xh * p["D"][:, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z)[:, None], {"scale": p["norm"]},
                 cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    return out, {"conv": new_conv, "ssm": new_ssm}


# ===========================================================================
# RG-LRU (Griffin / RecurrentGemma recurrent block).
# ===========================================================================
def rglru_schema(cfg):
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    k = cfg.rglru.conv_width

    def lam_init(key, shape):
        # a = sigmoid(lam) ~ U(0.9, 0.999) as in Griffin
        u = jax.random.uniform(key, shape, jnp.float32, 0.9, 0.999)
        return jnp.log(u) - jnp.log1p(-u)

    return {
        "w_gate": ParamSpec((d, w), ("embed", "lru_width")),
        "w_in": ParamSpec((d, w), ("embed", "lru_width")),
        "conv_w": ParamSpec((k, w), (None, "lru_width")),
        "conv_b": ParamSpec((w,), ("lru_width",), "zeros"),
        "ra_w": ParamSpec((w,), ("lru_width",), "normal", dtype=jnp.float32),
        "ra_b": ParamSpec((w,), ("lru_width",), "zeros", dtype=jnp.float32),
        "ix_w": ParamSpec((w,), ("lru_width",), "normal", dtype=jnp.float32),
        "ix_b": ParamSpec((w,), ("lru_width",), "zeros", dtype=jnp.float32),
        "lam": ParamSpec((w,), ("lru_width",), lam_init, dtype=jnp.float32),
        "wo": ParamSpec((w, d), ("lru_width", "embed")),
    }


def _rglru_coeffs(p, u, cfg):
    """u: (..., w) fp32 -> (a, b) recurrence coefficients."""
    c = cfg.rglru.c_constant
    r = jax.nn.sigmoid(u * p["ra_w"] + p["ra_b"])
    i = jax.nn.sigmoid(u * p["ix_w"] + p["ix_b"])
    log_a = -c * r * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalization (Griffin eq. 4)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * u)


def rglru_mix(p, x, cfg, shard: ShardingCtx = INERT):
    """Griffin recurrent block. x: (b,s,d) -> (b,s,d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"])
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    u = shard(u, "batch", "seq", "lru_width")
    a, bvec = _rglru_coeffs(p, u.astype(jnp.float32), cfg)

    if cfg.use_pallas:
        from repro.kernels.rglru import ops as rglru_ops
        h = rglru_ops.rglru_scan(jnp.log(jnp.maximum(a, 1e-37)), bvec,
                                 chunk=min(128, u.shape[1]),
                                 block_w=min(128, u.shape[2]))
    else:
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        _, h = lax.associative_scan(combine, (a, bvec), axis=1)
    h = h.astype(x.dtype)
    h = shard(h, "batch", "seq", "lru_width")
    out = jnp.einsum("bsw,wd->bsd", gate * h, p["wo"])
    return shard(out, "batch", "seq", "embed_act")


def rglru_init_state(cfg, batch, dtype):
    w = cfg.rglru.lru_width or cfg.d_model
    k = cfg.rglru.conv_width
    return {
        "conv": jnp.zeros((batch, k - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode(p, x, cfg, state, shard: ShardingCtx = INERT):
    """One-token decode. x: (b,1,d)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))[:, 0]
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"])[:, 0]   # (b,w)
    window = jnp.concatenate([state["conv"], u[:, None]], axis=1)
    u = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + p["conv_b"]
    a, bvec = _rglru_coeffs(p, u.astype(jnp.float32), cfg)
    h = state["h"] * a + bvec
    out = jnp.einsum("bw,wd->bd", gate * h.astype(x.dtype), p["wo"])[:, None]
    return out, {"conv": window[:, 1:], "h": h}
