"""Unified decoder LM covering all assigned architecture families.

A model is ``num_layers`` layers tiled by ``cfg.pattern`` (e.g. dense =
("attn",), recurrentgemma = ("rglru","rglru","attn_local")); the repeated
super-block is scanned (`lax.scan`) with stacked params so HLO size is
O(1) in depth, and optionally rematerialized.

Public API:
  param_schema / init_params / abstract_params / logical_axes
  forward(params, cfg, tokens, cond=None)           -> logits, aux
  loss_fn(params, cfg, batch)                       -> scalar loss
  init_cache / abstract_cache / cache_logical_axes
  decode_step(params, cfg, tokens, pos, cache)      -> logits, cache
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.common import config as C
from repro.models import layers as L
from repro.models import ssm as S
from repro.sharding.rules import ShardingCtx, INERT


# ---------------------------------------------------------------------------
# Schemas.
# ---------------------------------------------------------------------------
def _sublayer_schema(cfg, kind):
    sub = {"norm1": L.rms_norm_schema(cfg.d_model)}
    if kind in (C.ATTN, C.LOCAL_ATTN, C.CROSS_ATTN):
        sub["mix"] = L.attention_schema(cfg, cross=(kind == C.CROSS_ATTN))
    elif kind == C.MAMBA2:
        sub["mix"] = S.mamba2_schema(cfg)
    elif kind == C.RGLRU:
        sub["mix"] = S.rglru_schema(cfg)
    if _has_mlp(cfg):
        sub["norm2"] = L.rms_norm_schema(cfg.d_model)
        sub["mlp"] = L.moe_schema(cfg) if cfg.moe else L.mlp_schema(cfg)
    return sub


def _has_mlp(cfg):
    return cfg.d_ff > 0 or cfg.moe is not None


def _block_schema(cfg, pattern):
    return {f"{i:02d}_{k}": _sublayer_schema(cfg, k)
            for i, k in enumerate(pattern)}


def param_schema(cfg):
    d, v = cfg.d_model, cfg.vocab_size
    schema = {
        "embed": {"table": L.ParamSpec((v, d), ("vocab", "embed"), "embed")},
        "final_norm": L.rms_norm_schema(d),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = {
            "table": L.ParamSpec((d, v), ("embed", "vocab"))}
    if cfg.n_super > 0:
        schema["blocks"] = L.stack_specs(
            _block_schema(cfg, cfg.pattern), cfg.n_super)
    if cfg.tail_pattern:
        schema["tail"] = _block_schema(cfg, cfg.tail_pattern)
    return schema


def init_params(cfg, key):
    return L.materialize_tree(param_schema(cfg), key,
                              jnp.dtype(cfg.param_dtype))


def abstract_params(cfg):
    return L.abstract_tree(param_schema(cfg), jnp.dtype(cfg.param_dtype))


def logical_axes(cfg):
    return L.axes_tree(param_schema(cfg))


def param_count(cfg) -> int:
    import numpy as np
    return int(sum(np.prod(l.shape)
                   for l in jax.tree.leaves(abstract_params(cfg))))


# ---------------------------------------------------------------------------
# Forward (train / prefill).
# ---------------------------------------------------------------------------
def _apply_sublayer(kind, p, x, cfg, shard, cond):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    # SP boundary: gather the bf16 normed tensor (NOT the fp32 norm
    # intermediate, which GSPMD otherwise picks — 2x collective bytes).
    h = shard(h, "batch", None, "embed_act")
    aux = jnp.zeros((), jnp.float32)
    if kind in (C.ATTN, C.LOCAL_ATTN, C.CROSS_ATTN):
        h = L.attention(p["mix"], h, cfg, kind=kind, shard=shard, cond=cond)
    elif kind == C.MAMBA2:
        h = S.mamba2_mix(p["mix"], h, cfg, shard=shard)
    elif kind == C.RGLRU:
        h = S.rglru_mix(p["mix"], h, cfg, shard=shard)
    x = x + h
    if _has_mlp(cfg):
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        h = shard(h, "batch", None, "embed_act")
        if cfg.moe:
            h, aux = L.moe(p["mlp"], h, cfg, shard=shard)
        else:
            h = L.mlp(p["mlp"], h, cfg, shard=shard)
        x = x + h
    return x, aux


def _apply_block(pattern, p_blk, x, cfg, shard, cond):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(pattern):
        x, a = _apply_sublayer(kind, p_blk[f"{i:02d}_{kind}"], x, cfg,
                               shard, cond)
        aux = aux + a
    return x, aux


def forward(params, cfg, tokens, cond=None, shard: ShardingCtx = INERT):
    """tokens: (B,S) int32 (or (B,S,D) pre-embedded frames for [audio]).

    Returns (logits (B,S,V), aux_loss scalar).
    """
    if tokens.ndim == 2:
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
    else:
        x = tokens.astype(cfg.activation_dtype)
    x = x.astype(cfg.activation_dtype)
    x = shard(x, "batch", "seq", "embed_act")
    if cond is not None:
        cond = cond.astype(cfg.activation_dtype)

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.n_super > 0:
        def block(carry, p_blk):
            h, aux = carry
            h, a = _apply_block(cfg.pattern, p_blk, h, cfg, shard, cond)
            return (h, aux + a), None

        if cfg.remat:
            block = jax.checkpoint(block,
                                   policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux_total), _ = lax.scan(block, (x, aux_total), params["blocks"])
    if cfg.tail_pattern:
        x, a = _apply_block(cfg.tail_pattern, params["tail"], x, cfg,
                            shard, cond)
        aux_total = aux_total + a

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed"]["table"].T if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = jnp.einsum("bsd,dv->bsv", x, table)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux_total


def loss_fn(params, cfg, batch, shard: ShardingCtx = INERT,
            aux_weight: float = 0.01):
    """batch: dict(tokens (B,S), labels (B,S), [cond]). Mean token CE."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          cond=batch.get("cond"), shard=shard)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["labels"][..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + aux_weight * aux


# ---------------------------------------------------------------------------
# Decode path.
# ---------------------------------------------------------------------------
def _sublayer_cache_shapes(cfg, kind, batch, max_len, dtype):
    h = cfg.resolved_head_dim
    nk = cfg.num_kv_heads
    if kind == C.ATTN:
        return {
            "k": ((batch, max_len, nk, h), dtype,
                  ("batch", "cache_len", "kv_heads", "head_dim")),
            "v": ((batch, max_len, nk, h), dtype,
                  ("batch", "cache_len", "kv_heads", "head_dim")),
        }
    if kind == C.LOCAL_ATTN:
        wl = min(cfg.window_size, max_len)
        return {
            "k": ((batch, wl, nk, h), dtype,
                  ("batch", None, "kv_heads", "head_dim")),
            "v": ((batch, wl, nk, h), dtype,
                  ("batch", None, "kv_heads", "head_dim")),
        }
    if kind == C.CROSS_ATTN:
        t = cfg.n_cond_tokens
        return {
            "cond_k": ((batch, t, nk, h), dtype,
                       ("batch", "cond", "kv_heads", "head_dim")),
            "cond_v": ((batch, t, nk, h), dtype,
                       ("batch", "cond", "kv_heads", "head_dim")),
        }
    if kind == C.MAMBA2:
        s = cfg.ssm
        d_in, nh, conv_dim = S.mamba2_dims(cfg)
        return {
            "conv": ((batch, s.conv_width - 1, conv_dim), dtype,
                     ("batch", None, "ssm_inner")),
            "ssm": ((batch, nh, s.head_dim, s.d_state), jnp.float32,
                    ("batch", "ssm_heads", None, "ssm_state")),
        }
    if kind == C.RGLRU:
        w = cfg.rglru.lru_width or cfg.d_model
        k = cfg.rglru.conv_width
        return {
            "conv": ((batch, k - 1, w), dtype, ("batch", None, "lru_width")),
            "h": ((batch, w), jnp.float32, ("batch", "lru_width")),
        }
    raise ValueError(kind)


def _cache_tree(cfg, batch, max_len, dtype, mode):
    """mode: 'zeros' | 'abstract' | 'axes'."""
    def blk(pattern, stack):
        out = {}
        for i, kind in enumerate(pattern):
            sub = {}
            for name, (shape, dt, ax) in _sublayer_cache_shapes(
                    cfg, kind, batch, max_len, dtype).items():
                if stack:
                    shape = (cfg.n_super,) + shape
                    ax = ("layers",) + ax
                if mode == "zeros":
                    sub[name] = jnp.zeros(shape, dt)
                elif mode == "abstract":
                    sub[name] = jax.ShapeDtypeStruct(shape, dt)
                else:
                    sub[name] = ax
            out[f"{i:02d}_{kind}"] = sub
        return out

    cache = {}
    if cfg.n_super > 0:
        cache["blocks"] = blk(cfg.pattern, stack=True)
    if cfg.tail_pattern:
        cache["tail"] = blk(cfg.tail_pattern, stack=False)
    return cache


def init_cache(cfg, batch, max_len, dtype=None):
    return _cache_tree(cfg, batch, max_len, dtype or cfg.activation_dtype,
                       "zeros")


def abstract_cache(cfg, batch, max_len, dtype=None):
    return _cache_tree(cfg, batch, max_len, dtype or cfg.activation_dtype,
                       "abstract")


def cache_logical_axes(cfg, batch=0, max_len=0):
    return _cache_tree(cfg, 1, 2, jnp.float32, "axes")


def _apply_sublayer_decode(kind, p, x, cfg, cache, pos, shard):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in (C.ATTN, C.LOCAL_ATTN):
        h, new = L.decode_attention(p["mix"], h, cfg, kind=kind,
                                    cache=cache, pos=pos, shard=shard)
    elif kind == C.CROSS_ATTN:
        h, _ = L.decode_attention(
            p["mix"], h, cfg, kind=kind, cache=None, pos=pos, shard=shard,
            cond_kv={"k": cache["cond_k"], "v": cache["cond_v"]})
        new = cache
    elif kind == C.MAMBA2:
        h, new = S.mamba2_decode(p["mix"], h, cfg, cache, shard=shard)
    elif kind == C.RGLRU:
        h, new = S.rglru_decode(p["mix"], h, cfg, cache, shard=shard)
    x = x + h
    if _has_mlp(cfg):
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.moe:
            h, _ = L.moe(p["mlp"], h, cfg, shard=shard)
        else:
            h = L.mlp(p["mlp"], h, cfg, shard=shard)
        x = x + h
    return x, new


def _apply_block_decode(pattern, p_blk, x, cfg, cache_blk, pos, shard):
    new_cache = {}
    for i, kind in enumerate(pattern):
        key = f"{i:02d}_{kind}"
        x, new_cache[key] = _apply_sublayer_decode(
            kind, p_blk[key], x, cfg, cache_blk[key], pos, shard)
    return x, new_cache


def decode_step(params, cfg, tokens, pos, cache, shard: ShardingCtx = INERT):
    """tokens: (B,1) int32 (or (B,1,D) frames); pos: (B,) int32.

    Returns (logits (B,1,V), new_cache).
    """
    if tokens.ndim == 2:
        x = jnp.take(params["embed"]["table"], tokens, axis=0)
    else:
        x = tokens.astype(cfg.activation_dtype)
    x = x.astype(cfg.activation_dtype)
    x = shard(x, "batch", None, "embed_act")

    new_cache = {}
    if cfg.n_super > 0:
        def body(h, inp):
            p_blk, c_blk = inp
            h, nc = _apply_block_decode(cfg.pattern, p_blk, h, cfg, c_blk,
                                        pos, shard)
            return h, nc

        x, new_cache["blocks"] = lax.scan(
            body, x, (params["blocks"], cache["blocks"]))
    if cfg.tail_pattern:
        x, new_cache["tail"] = _apply_block_decode(
            cfg.tail_pattern, params["tail"], x, cfg, cache["tail"], pos,
            shard)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = (params["embed"]["table"].T if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = jnp.einsum("bsd,dv->bsv", x, table)
    return shard(logits, "batch", None, "vocab"), new_cache
