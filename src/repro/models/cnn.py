"""Vision models for the paper's own FL experiments (Table I):

  MNIST        -> two-layer CNN          (paper §IV-A)
  CIFAR-10     -> ResNet-18
  AI-READI     -> ResNet-50
  Fed-ISIC2019 -> EfficientNet-lite (depthwise-separable MBConv stack; the
                  paper uses FLamby's EfficientNet default)

Pure-JAX, param pytrees, NHWC. These are the models the FL clients
actually train end-to-end on CPU in the examples/benchmarks.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _conv_init(key, shape):
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2 / fan_in)


def _dense_init(key, shape):
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(shape[0])


def conv2d(x, w, stride=1, groups=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def batch_norm(x, p, eps=1e-5):
    # inference-style norm over batch+spatial (no running stats — FL clients
    # train short local epochs; the paper's models use standard BN, we use
    # batch statistics which is equivalent in training mode).
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mean) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _bn_params(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


# ---------------------------------------------------------------------------
# Two-layer CNN (MNIST).
# ---------------------------------------------------------------------------
def init_small_cnn(key, n_classes=10, in_ch=1):
    k = jax.random.split(key, 4)
    return {
        "c1": _conv_init(k[0], (5, 5, in_ch, 32)),
        "c2": _conv_init(k[1], (5, 5, 32, 64)),
        "fc1": _dense_init(k[2], (64 * 7 * 7, 128)),
        "fc2": _dense_init(k[3], (128, n_classes)),
    }


def small_cnn(p, x):
    x = jax.nn.relu(conv2d(x, p["c1"]))
    x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                          "VALID")
    x = jax.nn.relu(conv2d(x, p["c2"]))
    x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                          "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["fc1"])
    return x @ p["fc2"]


# ---------------------------------------------------------------------------
# ResNet (18 / 50).
# ---------------------------------------------------------------------------
def _init_basic_block(key, cin, cout, stride):
    k = jax.random.split(key, 3)
    p = {
        "c1": _conv_init(k[0], (3, 3, cin, cout)), "bn1": _bn_params(cout),
        "c2": _conv_init(k[1], (3, 3, cout, cout)), "bn2": _bn_params(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(k[2], (1, 1, cin, cout))
        p["bnp"] = _bn_params(cout)
    return p


def _basic_block(p, x, stride):
    h = jax.nn.relu(batch_norm(conv2d(x, p["c1"], stride), p["bn1"]))
    h = batch_norm(conv2d(h, p["c2"]), p["bn2"])
    if "proj" in p:
        x = batch_norm(conv2d(x, p["proj"], stride), p["bnp"])
    return jax.nn.relu(x + h)


def _init_bottleneck(key, cin, cmid, stride):
    k = jax.random.split(key, 4)
    cout = cmid * 4
    p = {
        "c1": _conv_init(k[0], (1, 1, cin, cmid)), "bn1": _bn_params(cmid),
        "c2": _conv_init(k[1], (3, 3, cmid, cmid)), "bn2": _bn_params(cmid),
        "c3": _conv_init(k[2], (1, 1, cmid, cout)), "bn3": _bn_params(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(k[3], (1, 1, cin, cout))
        p["bnp"] = _bn_params(cout)
    return p


def _bottleneck(p, x, stride):
    h = jax.nn.relu(batch_norm(conv2d(x, p["c1"]), p["bn1"]))
    h = jax.nn.relu(batch_norm(conv2d(h, p["c2"], stride), p["bn2"]))
    h = batch_norm(conv2d(h, p["c3"]), p["bn3"])
    if "proj" in p:
        x = batch_norm(conv2d(x, p["proj"], stride), p["bnp"])
    return jax.nn.relu(x + h)


_RESNET_SPECS = {
    18: ("basic", (2, 2, 2, 2)),
    50: ("bottleneck", (3, 4, 6, 3)),
}


def init_resnet(key, depth=18, n_classes=10, in_ch=3, width=64):
    kind, blocks = _RESNET_SPECS[depth]
    keys = jax.random.split(key, sum(blocks) + 2)
    ki = iter(keys)
    p = {"stem": _conv_init(next(ki), (7, 7, in_ch, width)),
         "bn_stem": _bn_params(width), "stages": []}
    cin = width
    for si, n in enumerate(blocks):
        cmid = width * (2 ** si)
        stage = []
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            if kind == "basic":
                stage.append(_init_basic_block(next(ki), cin, cmid, stride))
                cin = cmid
            else:
                stage.append(_init_bottleneck(next(ki), cin, cmid, stride))
                cin = cmid * 4
        p["stages"].append(stage)
    p["fc"] = _dense_init(next(ki), (cin, n_classes))
    return p


def resnet(p, x, depth=18):
    kind, blocks = _RESNET_SPECS[depth]
    x = jax.nn.relu(batch_norm(conv2d(x, p["stem"], 2), p["bn_stem"]))
    x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
                          "SAME")
    fn = _basic_block if kind == "basic" else _bottleneck
    for si, stage in enumerate(p["stages"]):
        for bi, bp in enumerate(stage):
            x = fn(bp, x, 2 if (bi == 0 and si > 0) else 1)
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["fc"]


# ---------------------------------------------------------------------------
# EfficientNet-lite (MBConv stack) — Fed-ISIC2019.
# ---------------------------------------------------------------------------
_EFF_STAGES = (  # (expand, cout, n, stride)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 40, 2, 2), (6, 80, 3, 2),
    (6, 112, 3, 1), (6, 192, 4, 2), (6, 320, 1, 1),
)


def _init_mbconv(key, cin, cout, expand, stride):
    k = jax.random.split(key, 3)
    cmid = cin * expand
    p = {"dw": _conv_init(k[1], (3, 3, 1, cmid)), "bn_dw": _bn_params(cmid),
         "pw": _conv_init(k[2], (1, 1, cmid, cout)), "bn_pw": _bn_params(cout)}
    if expand != 1:
        p["exp"] = _conv_init(k[0], (1, 1, cin, cmid))
        p["bn_exp"] = _bn_params(cmid)
    return p


def _mbconv(p, x, stride):
    h = x
    if "exp" in p:
        h = jax.nn.relu6(batch_norm(conv2d(h, p["exp"]), p["bn_exp"]))
    cmid = h.shape[-1]
    h = jax.nn.relu6(batch_norm(conv2d(h, p["dw"], stride, groups=cmid),
                                p["bn_dw"]))
    h = batch_norm(conv2d(h, p["pw"]), p["bn_pw"])
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = x + h
    return h


def init_efficientnet(key, n_classes=8, in_ch=3):
    keys = jax.random.split(key, sum(n for _, _, n, _ in _EFF_STAGES) + 3)
    ki = iter(keys)
    p = {"stem": _conv_init(next(ki), (3, 3, in_ch, 32)),
         "bn_stem": _bn_params(32), "blocks": []}
    cin = 32
    for expand, cout, n, stride in _EFF_STAGES:
        for bi in range(n):
            s = stride if bi == 0 else 1
            p["blocks"].append(
                (_init_mbconv(next(ki), cin, cout, expand, s), s))
            cin = cout
    p["head"] = _conv_init(next(ki), (1, 1, cin, 1280))
    p["bn_head"] = _bn_params(1280)
    p["fc"] = _dense_init(next(ki), (1280, n_classes))
    return p


def efficientnet(p, x):
    x = jax.nn.relu6(batch_norm(conv2d(x, p["stem"], 2), p["bn_stem"]))
    for bp, s in p["blocks"]:
        x = _mbconv(bp, x, s)
    x = jax.nn.relu6(batch_norm(conv2d(x, p["head"]), p["bn_head"]))
    x = jnp.mean(x, axis=(1, 2))
    return x @ p["fc"]


# ---------------------------------------------------------------------------
# Registry used by the FL layer.
# ---------------------------------------------------------------------------
def build(name: str, key, n_classes: int, in_ch: int, img: int):
    """Returns (params, apply_fn, input_shape)."""
    if name == "small_cnn":
        return (init_small_cnn(key, n_classes, in_ch), small_cnn,
                (img, img, in_ch))
    if name == "resnet18":
        p = init_resnet(key, 18, n_classes, in_ch)
        return p, lambda pp, x: resnet(pp, x, 18), (img, img, in_ch)
    if name == "resnet50":
        p = init_resnet(key, 50, n_classes, in_ch)
        return p, lambda pp, x: resnet(pp, x, 50), (img, img, in_ch)
    if name == "efficientnet":
        return (init_efficientnet(key, n_classes, in_ch), efficientnet,
                (img, img, in_ch))
    raise ValueError(name)
