"""Budget adherence (paper §III-E).

Each client declares a maximum budget; a ledger tracks real-time spend
(the paper's "background monitoring process"). Before each round the
scheduler checks `remaining >= estimated next-round cost` and excludes
clients that cannot afford the round — from that round *and all
subsequent rounds*.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Set


@dataclasses.dataclass
class BudgetEntry:
    budget: float
    spent: float = 0.0
    excluded: bool = False

    @property
    def remaining(self) -> float:
        return self.budget - self.spent


class BudgetLedger:
    def __init__(self):
        self._entries: Dict[str, BudgetEntry] = {}

    def register(self, client: str, budget: float):
        self._entries[client] = BudgetEntry(budget)

    def sync_spend(self, client: str, total_spent: float):
        """Update from the cloud's authoritative accrued cost."""
        self._entries[client].spent = total_spent

    def remaining(self, client: str) -> float:
        return self._entries[client].remaining

    def is_excluded(self, client: str) -> bool:
        return self._entries[client].excluded

    def exclude(self, client: str):
        self._entries[client].excluded = True

    def affordable(self, client: str, est_round_cost: float) -> bool:
        return self._entries[client].remaining >= est_round_cost

    def screen_round(self, clients: List[str],
                     est_round_cost: Callable[[str], float]) -> List[str]:
        """Return participants; permanently exclude the rest (§III-E)."""
        keep = []
        for c in clients:
            if self.is_excluded(c):
                continue
            if self.affordable(c, est_round_cost(c)):
                keep.append(c)
            else:
                self.exclude(c)
        return keep
