"""EMA time estimators (paper §III-B "Dynamic Estimation Updates").

Per client the scheduler tracks three quantities:
  T_epoch_cold : first-epoch time on a freshly started instance
  T_epoch_warm : epoch time on an already-running instance
  T_spinup     : instance provisioning + boot time

Each is smoothed with an exponential moving average; the spin-up estimate
is only updated when a result actually required a fresh spin-up.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


class EMA:
    def __init__(self, alpha: float, init: Optional[float] = None):
        self.alpha = alpha
        self.value: Optional[float] = init
        self.n_obs = 0

    def update(self, obs: float) -> float:
        self.n_obs += 1
        if self.value is None:
            self.value = float(obs)
        else:
            self.value = self.alpha * float(obs) + (1 - self.alpha) * self.value
        return self.value

    def get(self, default: float = 0.0) -> float:
        return self.value if self.value is not None else default


@dataclasses.dataclass
class ClientTimeModel:
    """All EMA estimates for one client."""
    epoch_cold: EMA
    epoch_warm: EMA
    spin_up: EMA

    @classmethod
    def fresh(cls, alpha: float, spin_up_prior: float = 150.0):
        return cls(EMA(alpha), EMA(alpha), EMA(alpha, init=spin_up_prior))

    # ------------------------------------------------------------------
    def predict_epoch(self, cold: bool) -> float:
        if cold:
            # before any cold observation fall back on warm (and vice versa)
            return self.epoch_cold.get(self.epoch_warm.get())
        return self.epoch_warm.get(self.epoch_cold.get())

    def predict_finish(self, start_time: float, cold: bool,
                       includes_spin_up: bool) -> float:
        t = start_time
        if includes_spin_up:
            t += self.spin_up.get()
        return t + self.predict_epoch(cold)


class TimeEstimator:
    """Registry of per-client time models + the update rules of §III-B."""

    def __init__(self, alpha: float, spin_up_prior: float = 150.0):
        self.alpha = alpha
        self.spin_up_prior = spin_up_prior
        self._models: Dict[str, ClientTimeModel] = {}

    def model(self, client: str) -> ClientTimeModel:
        if client not in self._models:
            self._models[client] = ClientTimeModel.fresh(
                self.alpha, self.spin_up_prior)
        return self._models[client]

    def observe_epoch(self, client: str, duration_s: float, cold: bool):
        m = self.model(client)
        (m.epoch_cold if cold else m.epoch_warm).update(duration_s)

    def observe_spin_up(self, client: str, duration_s: float):
        self.model(client).spin_up.update(duration_s)
