"""Typed event bus connecting the cloud / cluster / engine layers.

The FedCostAware stack is layered (PR: multi-layer refactor):

  CloudSimulator   -- publishes cloud-level events (InstanceReady,
                      InstancePreempted, InstanceTerminated, BillingTick)
  ClusterManager   -- subscribes to cloud events, owns instance
                      lifecycle, re-publishes client-level events
                      (ClientReady, ClientLost)
  RoundEngine      -- subscribes to client events, owns FL-round
                      semantics (sync barrier / async buffered)
  CostAccountant   -- subscribes to billing events, maintains per-client
                      accrued cost incrementally (O(1) queries)

Events are frozen dataclasses dispatched by exact type. Publishing is
synchronous: `publish` invokes every subscriber before returning, so the
discrete-event simulator's deterministic ordering (heap + FIFO sequence
numbers) is preserved — a handler that schedules follow-up events does so
in the same order a direct callback would have.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Type


@dataclasses.dataclass(frozen=True)
class Event:
    """Base class for all bus events; `t` is simulated time (seconds)."""
    t: float


# ---------------------------------------------------------------------------
# Cloud-layer events (published by CloudSimulator).
# `instance` fields are `repro.cloud.simulator.Instance`; typed as Any to
# keep the core layer free of cloud imports.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InstanceRequested(Event):
    instance: Any


@dataclasses.dataclass(frozen=True)
class InstanceReady(Event):
    """Instance finished spinning up; billing starts now."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class InstancePreempted(Event):
    """Spot market reclaimed a RUNNING instance (billing already closed)."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class InstanceTerminated(Event):
    """Deliberate terminate (paper's terminate-specific-node API)."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class BillingTick(Event):
    """A billing segment [t0, t1) closed, charging `amount` dollars.

    Emitted whenever the simulator finalizes billing (terminate or
    preemption); `t1 - t0` already includes the min-billing floor.
    """
    instance: Any
    client: str
    t0: float
    t1: float
    amount: float


# ---------------------------------------------------------------------------
# Cluster-layer events (published by ClusterManager). Only fired for
# instances the cluster currently tracks — stale cloud events (e.g. a
# preemption racing a deliberate replace) are filtered out below this
# layer, so engines never see them.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClientReady(Event):
    """The client's tracked instance became RUNNING.

    `resume_token` carries engine-opaque recovery state when this ready
    answers a resume-from-checkpoint request (set via
    `ClusterManager.request(..., resume_token=...)`), else None.
    """
    client: str
    instance: Any
    cold: bool
    resume_token: Optional[Any] = None


@dataclasses.dataclass(frozen=True)
class ClientLost(Event):
    """The client's tracked instance was preempted (cluster already
    dropped it; the engine decides whether/how to recover)."""
    client: str
    instance: Any


# ---------------------------------------------------------------------------
# Bus.
# ---------------------------------------------------------------------------
Handler = Callable[[Event], None]


class EventBus:
    """Minimal synchronous pub/sub keyed by exact event type."""

    def __init__(self):
        self._subs: Dict[Type[Event], List[Handler]] = defaultdict(list)

    def subscribe(self, etype: Type[Event], handler: Handler) -> Handler:
        self._subs[etype].append(handler)
        return handler

    def unsubscribe(self, etype: Type[Event], handler: Handler) -> None:
        self._subs[etype].remove(handler)

    def publish(self, event: Event) -> None:
        # snapshot: a handler may (un)subscribe while we iterate
        for h in list(self._subs[type(event)]):
            h(event)
