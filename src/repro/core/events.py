"""Typed event bus connecting the cloud / cluster / engine layers.

The FedCostAware stack is layered (PR: multi-layer refactor):

  CloudSimulator   -- publishes cloud-level events (InstanceReady,
                      InstancePreempted, InstanceTerminated, BillingTick)
  ClusterManager   -- subscribes to cloud events, owns instance
                      lifecycle, re-publishes client-level events
                      (ClientReady, ClientLost)
  RoundEngine      -- subscribes to client events, owns FL-round
                      semantics (sync barrier / async buffered), and
                      publishes engine-level telemetry (RoundStarted,
                      RoundCompleted, ClientStateChanged,
                      BudgetExhausted)
  CostAccountant   -- subscribes to billing events, maintains per-client
                      accrued cost incrementally (O(1) queries)
  EventRecorder    -- wildcard subscriber (core.eventlog): serializes
                      the full stream to JSONL for offline replay

Events are frozen dataclasses dispatched by exact type. Publishing is
synchronous: `publish` invokes every subscriber before returning, so the
discrete-event simulator's deterministic ordering (heap + FIFO sequence
numbers) is preserved — a handler that schedules follow-up events does so
in the same order a direct callback would have.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import (Any, Callable, Dict, List, Mapping, Optional, Tuple,
                    Type)


@dataclasses.dataclass(frozen=True)
class Event:
    """Base class for all bus events; `t` is simulated time (seconds)."""
    t: float


# ---------------------------------------------------------------------------
# Cloud-layer events (published by CloudSimulator).
# `instance` fields are `repro.cloud.simulator.Instance`; typed as Any to
# keep the core layer free of cloud imports.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InstanceRequested(Event):
    """Placement chosen for an instance; spin-up begins."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class InstanceReady(Event):
    """Instance finished spinning up; billing starts now."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class InstancePreemptionWarning(Event):
    """Provider reclaim notice (e.g. AWS's 2-minute warning): the
    instance will be preempted at `reclaim_at` unless terminated first.
    Only emitted when the instance's provider has a non-zero
    `preemption_notice_s`."""
    instance: Any
    reclaim_at: float


@dataclasses.dataclass(frozen=True)
class InstancePreempted(Event):
    """Spot market reclaimed a RUNNING instance (billing already closed)."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class InstanceTerminated(Event):
    """Deliberate terminate (paper's terminate-specific-node API)."""
    instance: Any


@dataclasses.dataclass(frozen=True)
class BillingTick(Event):
    """A billing segment [t0, t1) closed, charging `amount` dollars.

    Emitted whenever the simulator finalizes billing (terminate or
    preemption); `t1 - t0` already includes the min-billing floor.
    """
    instance: Any
    client: str
    t0: float
    t1: float
    amount: float


# ---------------------------------------------------------------------------
# Cluster-layer events (published by ClusterManager). Only fired for
# instances the cluster currently tracks — stale cloud events (e.g. a
# preemption racing a deliberate replace) are filtered out below this
# layer, so engines never see them.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClientReady(Event):
    """The client's tracked instance became RUNNING.

    `resume_token` carries engine-opaque recovery state when this ready
    answers a resume-from-checkpoint request (set via
    `ClusterManager.request(..., resume_token=...)`), else None.
    """
    client: str
    instance: Any
    cold: bool
    resume_token: Optional[Any] = None


@dataclasses.dataclass(frozen=True)
class ClientPreemptionWarning(Event):
    """The client's tracked instance received its provider's reclaim
    notice: it will be preempted at `reclaim_at` unless terminated
    first. The cluster-level translation of
    `InstancePreemptionWarning`, filtered the same way as
    `ClientReady`/`ClientLost` — engines never see warnings for
    instances the cluster no longer tracks."""
    client: str
    instance: Any
    reclaim_at: float


@dataclasses.dataclass(frozen=True)
class ClientLost(Event):
    """The client's tracked instance was preempted (cluster already
    dropped it; the engine decides whether/how to recover)."""
    client: str
    instance: Any


@dataclasses.dataclass(frozen=True)
class ClientCheckpointed(Event):
    """A preemption-notice-triggered checkpoint landed in cloud storage
    inside the warning window (engine `on_warning` policy "checkpoint"
    or "drain"): the client's training state through `progress_s`
    seconds of the epoch is durable, so a reclaim now only loses work
    done after the snapshot. `remaining_s` is the epoch time still owed
    if the client resumes from this snapshot. `size_mb` is the model
    state written and `provider` the cloud the writing instance runs
    on — what the provider's `StorageRates` bill (schema v4; absent
    in older logs and defaulted on decode)."""
    client: str
    round_idx: int
    progress_s: float
    remaining_s: float
    reclaim_at: float
    size_mb: float = 0.0
    provider: str = ""


@dataclasses.dataclass(frozen=True)
class ClientResumedFromCheckpoint(Event):
    """A replacement instance picked the client's training up from its
    warning-window checkpoint (rather than re-doing the round
    contribution from the last periodic checkpoint); the client owes
    only `remaining_s` seconds of epoch time."""
    client: str
    round_idx: int
    remaining_s: float


# ---------------------------------------------------------------------------
# Engine-level telemetry events (published by RoundEngines / the runner).
# These make a run fully observable on the bus: an `EventRecorder`
# (core.eventlog) that captures them plus the cloud/cluster events above
# holds everything needed to rebuild timelines and cost curves offline.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RoundStarted(Event):
    """An FL round opened with the given participant set."""
    round_idx: int
    participants: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class RoundCompleted(Event):
    """Aggregation fired for `round_idx`.

    `client_costs` is the accountant's cumulative per-client spend at
    the instant of aggregation — recorded here so replay consumers can
    rebuild the Fig-5 cost curve without re-pricing open segments.
    """
    round_idx: int
    participants: Tuple[str, ...]
    client_costs: Mapping[str, float]


@dataclasses.dataclass(frozen=True)
class ClientStateChanged(Event):
    """Fig-4 operational-state transition for one client.

    `state` is one of spinup | training | idle | savings | done; "done"
    closes the client's timeline without opening a new segment.
    """
    client: str
    state: str


@dataclasses.dataclass(frozen=True)
class BudgetExhausted(Event):
    """Budget screening (§III-E) permanently excluded `client`."""
    client: str


@dataclasses.dataclass(frozen=True)
class ClientScreenedOut(Event):
    """A `ScreenOut` directive was executed: budget screening excluded
    `client` from `round_idx` on (schema v4). Follows the
    `BudgetExhausted` event and precedes the instance teardown."""
    client: str
    round_idx: int = -1


@dataclasses.dataclass(frozen=True)
class DirectiveIssued(Event):
    """Observability trace of one executed strategy directive (schema
    v4). Only published when directive tracing is enabled
    (`FLRunConfig.trace_directives`) — default event streams carry
    none, keeping golden traces unmoved. `kind` is the directive class
    name; `detail` a short human-readable argument summary."""
    kind: str
    client: str
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class CheckpointBilled(Event):
    """Storage dollars charged for one warning-window checkpoint write
    (S3 PUT + per-MB egress, the provider's `StorageRates`; schema v4).
    Published by the live `CostAccountant` so replay consumers rebuild
    the same checkpoint spend without a price book. Only published when
    the charge is non-zero."""
    client: str
    amount: float


@dataclasses.dataclass(frozen=True)
class ClientUpdateSent(Event):
    """A client finished local training and uploaded its model update
    to the aggregation server (schema v7, the comms subsystem
    `repro.comms`). `size_mb` is the payload actually sent — the fp32
    pytree bytes, or the grad_quant int8 (blocks + scales) layout when
    `quantized` — and `transfer_s` how long the upload occupied the
    client's uplink (0 on an unmodeled/instantaneous channel).
    `provider`/`zone` locate the instance the update left from, which
    is what `TransferRates` egress pricing keys on. Only published
    when a run enables comms modeling (`FLRunConfig.update_payload_mb`
    or payload-exposing trainer hooks) — default event streams carry
    none, keeping golden traces unmoved."""
    client: str
    round_idx: int
    size_mb: float
    quantized: bool = False
    provider: str = ""
    zone: str = ""
    transfer_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class TransferBilled(Event):
    """Egress dollars charged for one client-update upload (the
    provider's `TransferRates`; schema v7). Published by the live
    `CostAccountant` in response to `ClientUpdateSent`, mirroring
    `CheckpointBilled`, so replay consumers rebuild the same transfer
    spend without a price book. Only published when the charge is
    non-zero."""
    client: str
    amount: float


@dataclasses.dataclass(frozen=True)
class FleetStepSummary(Event):
    """Aggregate fleet telemetry for one simulation step (one FL round
    of the vectorized fleet core, schema v6).

    Above `CloudConfig.fleet_threshold` the struct-of-arrays hot path
    (`repro.cloud.fleet`) batches thousands of instance lifecycles per
    step; publishing the per-instance vocabulary would cost more than
    the simulation itself, so the fleet emits one summary per step
    instead: lifecycle counts, the dollars *settled* this step
    (`cost_delta`; the sum over a complete run equals the run's total
    cost, which is what replay accounting folds), and per-"provider/
    zone" breakdowns. `open_accrued` is the informational accrued cost
    of still-open billing segments at step end — replay consumers must
    not fold it (those dollars settle in a later step's delta).

    `client_cost_delta` (schema v6) attributes the step's settled
    dollars per client — only clients that settled a nonzero amount
    this step appear, and the values sum to `cost_delta`. Replay
    accounting folds the map into per-client totals (it must NOT also
    fold it into the run total; `cost_delta` already is that sum). A
    v5 log decodes with the empty default, which replay consumers
    report as *unattributed* rather than pretending every client cost
    zero dollars."""
    step_idx: int                # round index of the fleet step
    n_clients: int               # participants (cohort) this step
    n_spinups: int               # fresh instances requested
    n_preemptions: int           # spot reclaims absorbed
    n_terminations: int          # deliberate (Listing-1 / final) stops
    cost_delta: float            # dollars settled during this step
    open_accrued: float          # accrued-but-unsettled dollars, step end
    by_zone: Mapping[str, Mapping[str, float]]  # "provider/zone" -> aggs
    # client -> dollars settled this step (v6+; empty on v5 replays)
    client_cost_delta: Mapping[str, float] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ForecastUpdated(Event):
    """One learned-forecast evaluation for a tracked training spot
    client (schema v8, the `repro.forecast` subsystem). Published by
    `LearnedForecastStrategy` once per poll: the predicted
    interruption probability over `horizon_s` (`p_interrupt`, from
    `hazard_per_hr`), the learned price band (`price_lo` / `price_mid`
    / `price_hi`; zeros when the forecaster does not model prices),
    the running calibration metrics (`brier`, `coverage`; -1.0 before
    their first resolution) and the cost-of-error `action` chosen
    ("hold" / "prewarm" / "release" / "checkpoint" /
    "prewarm+checkpoint" / "drain"). Only published when a policy
    composes the learned strategy — default event streams carry none,
    keeping golden traces unmoved."""
    client: str
    provider: str = ""
    zone: str = ""
    forecaster: str = ""
    horizon_s: float = 0.0
    p_interrupt: float = 0.0
    hazard_per_hr: float = 0.0
    price_lo: float = 0.0
    price_mid: float = 0.0
    price_hi: float = 0.0
    brier: float = -1.0
    coverage: float = -1.0
    action: str = "hold"


@dataclasses.dataclass(frozen=True)
class RunCompleted(Event):
    """Terminal event carrying the run summary.

    Published by the composition root *after* the event heap drains (the
    sync engine's makespan includes post-finish drain time, so only the
    runner knows it). `client_costs` equals the accountant's final
    per-client totals; costs are frozen once the engine finishes, so the
    snapshot is identical at finish and at drain.
    """
    makespan_s: float
    total_cost: float
    client_costs: Mapping[str, float]
    rounds_completed: int
    excluded_clients: Tuple[str, ...]
    final_round_idx: int


# Name -> type registry for (de)serialization (core.eventlog). Every
# event class that can appear on a recorded bus must be listed.
EVENT_TYPES: Dict[str, Type[Event]] = {
    cls.__name__: cls for cls in (
        InstanceRequested, InstanceReady, InstancePreemptionWarning,
        InstancePreempted, InstanceTerminated, BillingTick, ClientReady,
        ClientPreemptionWarning, ClientLost, ClientCheckpointed,
        ClientResumedFromCheckpoint, RoundStarted, RoundCompleted,
        ClientStateChanged, BudgetExhausted, ClientScreenedOut,
        DirectiveIssued, CheckpointBilled, ClientUpdateSent,
        TransferBilled, FleetStepSummary, ForecastUpdated, RunCompleted,
    )
}


# ---------------------------------------------------------------------------
# Bus.
# ---------------------------------------------------------------------------
Handler = Callable[[Event], None]


class EventBus:
    """Minimal synchronous pub/sub keyed by exact event type."""

    def __init__(self):
        self._subs: Dict[Type[Event], List[Handler]] = defaultdict(list)
        self._all: List[Handler] = []

    def subscribe(self, etype: Type[Event], handler: Handler) -> Handler:
        """Call `handler` for every future event of exactly `etype`
        (no subclass dispatch); returns `handler` for unsubscribing."""
        self._subs[etype].append(handler)
        return handler

    def subscribe_all(self, handler: Handler) -> Handler:
        """Wildcard subscription: `handler` sees every published event
        (before type-keyed subscribers). Used by the event recorder."""
        self._all.append(handler)
        return handler

    def unsubscribe(self, etype: Type[Event], handler: Handler) -> None:
        """Remove a type-keyed subscription added by `subscribe`."""
        self._subs[etype].remove(handler)

    def unsubscribe_all(self, handler: Handler) -> None:
        """Remove a wildcard subscription added by `subscribe_all`."""
        self._all.remove(handler)

    def publish(self, event: Event) -> None:
        """Synchronously invoke every subscriber (wildcards first,
        then type-keyed, each in subscription order) before returning."""
        # snapshot: a handler may (un)subscribe while we iterate
        for h in list(self._all):
            h(event)
        for h in list(self._subs[type(event)]):
            h(event)
