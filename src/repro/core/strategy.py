"""Composable scheduling strategies + typed directives.

The paper's FedCostAware scheduler (§III, Listing 1) is one of several
lifecycle disciplines the framework compares. This module makes the
discipline *composable*: a policy names a list of `SchedulingStrategy`
components, each of which observes the run (bus events plus the
engine-reported observations below) and answers with typed
**directives** —

  SpinUp(client)        ensure an instance is coming: request one when
                        the client is untracked, else pre-warm a
                        *standby* replacement next to the live instance
  Terminate(client)     stop the client's instance (Listing-1 idle
                        termination; `standby=True` cancels a standby)
  PreWarm(client, at_t) spin the client's next instance up at `at_t`
                        (the scheduler's F_s - T_spin_up - T_buffer)
  Checkpoint(client, …) persist a warning-window training snapshot
  Drain(client, …)      vacate a doomed instance and immediately
                        re-request its replacement with a resume token
  ScreenOut(client)     budget screening (§III-E) excludes the client

— which a `DirectiveExecutor` (`repro.fl.cluster`) applies against the
cluster. Engines never call `FedCostAwareScheduler` methods directly
any more: they report observations to the `StrategyStack`
(`note_dispatch` / `note_result` / …) and invoke its decision points
(`screen`, `client_result`, `recovered`, `preemption_remaining`);
everything Listing-1-shaped lives behind the strategy components:

  LifecycleStrategy       wraps Listing-1 termination + pre-warming
  BudgetScreen            wraps §III-E budget screening
  WarningReaction         the preemption-notice machinery (checkpoint /
                          drain) formerly hard-coded in the engines
  ForecastPrewarmStrategy beyond-paper: watches a reclaim hazard —
                          the true model's (`oracle=True`) or the
                          tenant-observable price-derived estimate
                          (`oracle=False`) — and pre-warms a standby
                          replacement *before* the expected
                          interruption burst, closing the spin-up gap
                          entirely (ROADMAP item) — with zero engine
                          or cloud edits. Its fully learned successor,
                          `repro.forecast.LearnedForecastStrategy`,
                          plugs into the same API from outside core.

Table-I policies are declarative compositions of these components
(`repro.core.policies`); new disciplines plug in as new strategies (or
new engines) without touching `fl/engines` internals.

Layering: this module depends on `core.*`, `common.config` and
`checkpoint.snapshots` only — never on `fl.*` or `cloud.*`. Cluster
and hazard access reach strategies as plain callables on the
`StrategyContext`, wired by the composition root (`repro.fl.runner`).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import (Any, Callable, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.checkpoint import snapshots
from repro.common.config import SchedulerConfig
from repro.core.events import ClientPreemptionWarning, EventBus
from repro.core.scheduler import FedCostAwareScheduler

# instance-state literal shared with repro.cloud.simulator.RUNNING
# (kept as a literal so the core layer stays free of cloud imports)
_RUNNING = "running"


# ---------------------------------------------------------------------------
# Directives: the typed vocabulary strategies answer with.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Directive:
    """Base class of every strategy decision; `client` is the FL client
    the decision concerns."""
    client: str


@dataclasses.dataclass(frozen=True)
class SpinUp(Directive):
    """Ensure an instance is on its way for `client`: a fresh request
    when the client is currently untracked (optionally resuming from
    `resume_token`), else a *standby* replacement pre-warmed alongside
    the live instance (promoted on the next request/reclaim)."""
    resume_token: Optional[Mapping[str, Any]] = None


@dataclasses.dataclass(frozen=True)
class Terminate(Directive):
    """Stop the client's tracked instance now (Listing-1 idle
    termination: the executor also publishes the Fig-4 "savings"
    state). `standby=True` instead cancels the client's standby
    replacement, leaving the tracked instance alone."""
    standby: bool = False


@dataclasses.dataclass(frozen=True)
class PreWarm(Directive):
    """Spin the client's next instance up at absolute time `at_t` (the
    scheduler's `F_s - T_spin_up - T_buffer` target; §III-C)."""
    at_t: float = 0.0


@dataclasses.dataclass(frozen=True)
class Checkpoint(Directive):
    """Persist a warning-window training snapshot for `client`: the
    executor writes `payload` to the run's checkpoint store and
    publishes `ClientCheckpointed`."""
    round_idx: int = -1
    progress_s: float = 0.0
    remaining_s: float = 0.0
    reclaim_at: float = 0.0
    payload: Optional[Mapping[str, Any]] = None


@dataclasses.dataclass(frozen=True)
class Drain(Directive):
    """Vacate the client's doomed instance (billing closes now, not at
    the reclaim) and immediately re-request a replacement carrying
    `resume_token`, giving its spin-up a head start."""
    resume_token: Optional[Mapping[str, Any]] = None


@dataclasses.dataclass(frozen=True)
class ScreenOut(Directive):
    """Budget screening (§III-E) permanently excluded `client` from
    `round_idx` on: the executor publishes `BudgetExhausted` +
    `ClientScreenedOut` and stops any tracked instance."""
    round_idx: int = -1


# ---------------------------------------------------------------------------
# Strategy specs: the declarative, hashable half that lives inside a
# frozen Policy. `build(policy)` turns a spec into the live component.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """Declarative description of one strategy inside a `Policy`."""

    def build(self, policy) -> "SchedulingStrategy":
        """Instantiate the live strategy this spec describes."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class LifecycleSpec(StrategySpec):
    """Listing-1 lifecycle management: terminate idle instances whose
    saving beats the respin threshold, pre-warm replacements."""

    def build(self, policy) -> "SchedulingStrategy":
        """A `LifecycleStrategy`."""
        return LifecycleStrategy()


@dataclasses.dataclass(frozen=True)
class BudgetScreenSpec(StrategySpec):
    """§III-E budget screening before each round."""

    def build(self, policy) -> "SchedulingStrategy":
        """A `BudgetScreen`."""
        return BudgetScreen()


@dataclasses.dataclass(frozen=True)
class WarningReactionSpec(StrategySpec):
    """Preemption-notice reaction; `mode` overrides the policy's
    `on_warning` knob (None inherits it). An explicit `mode` is the
    stronger statement: it also wins over a per-run
    `FLRunConfig.on_warning` override, which only reaches strategies
    through the policy knob — compositions that want the run override
    to apply should leave `mode=None`."""
    mode: Optional[str] = None

    def build(self, policy) -> "SchedulingStrategy":
        """A `WarningReaction` in `mode` (or the policy's)."""
        return WarningReaction(self.mode or policy.on_warning)


@dataclasses.dataclass(frozen=True)
class ForecastPrewarmSpec(StrategySpec):
    """Interruption-forecast pre-warming: pre-warm a standby
    replacement whenever the client's reclaim hazard (events/hour)
    crosses `hazard_threshold_per_hr`; release it once the hazard falls
    below `release_below_per_hr` (default: half the threshold).

    `oracle` names the hazard signal explicitly: True thresholds the
    *true* preemption-model hazard (`ctx.hazard_of` — a signal no real
    tenant can read; it silently degrades to the observable estimate
    when the driving model exposes no hazard, e.g. interruption
    replay), False thresholds the tenant-observable price-derived
    estimate (`ctx.observable_hazard_of`, routed through the run's
    `ObservableFeed`). Leaving it unset keeps the historical oracle
    behavior but raises a `DeprecationWarning` — compositions must now
    say which side of the oracle/observable line they stand on."""
    hazard_threshold_per_hr: float = 2.0
    poll_s: float = 30.0
    release_below_per_hr: Optional[float] = None
    oracle: Optional[bool] = None

    def build(self, policy) -> "SchedulingStrategy":
        """A `ForecastPrewarmStrategy` with this spec's thresholds."""
        oracle = self.oracle
        if oracle is None:
            warnings.warn(
                "ForecastPrewarmSpec without an explicit oracle= flag "
                "defaults to oracle=True, thresholding the true "
                "preemption-model hazard no real tenant can observe; "
                "pass oracle=True to keep that deliberately, or "
                "oracle=False for the observable price-derived signal "
                "(repro.forecast.ObservableFeed)",
                DeprecationWarning, stacklevel=2)
            oracle = True
        return ForecastPrewarmStrategy(
            self.hazard_threshold_per_hr, self.poll_s,
            self.release_below_per_hr, oracle=oracle)


# ---------------------------------------------------------------------------
# Context: everything a strategy may read or act through.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StrategyContext:
    """Wiring handed to every strategy at bind time. Cluster state and
    market/hazard lookups are plain callables so the core layer stays
    import-free of `fl.*` / `cloud.*`; the composition root
    (`repro.fl.runner`) fills them in."""
    policy: Any                          # repro.core.policies.Policy
    sched: FedCostAwareScheduler         # shared Listing-1 decision core
    sched_cfg: SchedulerConfig
    bus: EventBus
    now: Callable[[], float]
    schedule_in: Callable[[float, Callable[[], None]], None]
    clients: Tuple[str, ...]
    spin_up_default: float = 150.0       # CloudConfig.spin_up_mean_s
    instance_of: Callable[[str], Any] = lambda c: None
    standby_of: Callable[[str], Any] = lambda c: None
    spot_price_of: Callable[[str], float] = lambda c: 0.0
    spend_of: Callable[[str], float] = lambda c: 0.0
    hazard_of: Callable[[str], float] = lambda c: 0.0
    # tenant-observable hazard estimate (events/hour), routed through
    # the run's ObservableFeed — what oracle=False strategies threshold
    observable_hazard_of: Callable[[str], float] = lambda c: 0.0
    # $ one checkpoint write of size_mb costs on `provider` (the
    # provider's StorageRates, wired by the composition root)
    ckpt_cost_of: Callable[[str, float], float] = lambda p, mb: 0.0
    is_shutdown: Callable[[], bool] = lambda: False
    # the run's repro.forecast.ObservableFeed (held as Any: the core
    # layer never imports forecast); None on paths that don't wire one
    feed: Any = None
    ckpt_store: Any = None
    executor: Any = None                 # repro.fl.cluster.DirectiveExecutor
    view: Any = None                     # engine adapter (attached later)


# ---------------------------------------------------------------------------
# The strategy protocol.
# ---------------------------------------------------------------------------
class SchedulingStrategy:
    """One composable scheduling discipline.

    Strategies are bound to a `StrategyContext` once, may subscribe to
    bus events in `bind` (reactive paths apply their directives through
    `ctx.executor` themselves), and answer the stack's decision points
    with directive lists. Every hook defaults to "no opinion", so a
    strategy only implements the decisions it owns.
    """

    def bind(self, ctx: StrategyContext) -> None:
        """Attach the run wiring; subscribe to bus events here."""
        self.ctx = ctx

    def screen(self, round_idx: int, candidates: List[str]
               ) -> Tuple[List[str], List[str]]:
        """Filter the round's participant candidates; returns
        `(keep, newly_screened_out)`."""
        return list(candidates), []

    def on_client_result(self, client: str, t: float,
                         more_rounds: bool) -> List[Directive]:
        """`client` delivered its round result at `t` while the round
        is still open; return lifecycle directives."""
        return []

    def preemption_remaining(self, client: str, periodic_remaining: float
                             ) -> Optional[Tuple[float, str]]:
        """Offer a better resume point than the periodic checkpoint
        after a reclaim; `(remaining_s, source)` or None to pass."""
        return None

    def invalidate(self, client: str) -> None:
        """The client's epoch completed: drop any per-epoch state
        (e.g. a now-stale warning snapshot)."""


class LifecycleStrategy(SchedulingStrategy):
    """Listing-1 termination + pre-warming, wrapped as a strategy.

    The math stays in the shared `FedCostAwareScheduler` core
    (`ctx.sched`) so the decisions are bit-identical to the paper
    implementation; this component turns them into directives.
    """

    def on_client_result(self, client: str, t: float,
                         more_rounds: bool) -> List[Directive]:
        """Listing-1 `evaluate_termination`: terminate when the
        predicted idle time pays for a respin, pre-warm the
        replacement at `F_s - T_spin_up - T_buffer`."""
        prewarm_t = self.ctx.sched.evaluate_termination(
            client, t, more_rounds)
        if prewarm_t is None:
            return []
        out: List[Directive] = [Terminate(client)]
        if math.isfinite(prewarm_t):
            out.append(PreWarm(client, prewarm_t))
        return out


class BudgetScreen(SchedulingStrategy):
    """§III-E budget screening, wrapped as a strategy: sync real spend
    into the ledger, keep the affordable clients, emit `ScreenOut` for
    the newly excluded ones."""

    def screen(self, round_idx: int, candidates: List[str]
               ) -> Tuple[List[str], List[str]]:
        """One pre-round screening pass over `candidates`."""
        ctx = self.ctx
        sched = ctx.sched
        before = set(c for c in candidates
                     if not sched.ledger.is_excluded(c))
        for c in ctx.clients:
            sched.ledger.sync_spend(c, ctx.spend_of(c))
        keep = sched.screen_participants(list(candidates),
                                         ctx.spot_price_of)
        kept = set(keep)
        screened = [c for c in candidates
                    if c in before and c not in kept]
        if screened:
            ctx.executor.apply(
                [ScreenOut(c, round_idx) for c in screened])
        return keep, screened


class WarningReaction(SchedulingStrategy):
    """Preemption-notice reaction (`Policy.on_warning`): under
    "checkpoint"/"drain", snapshot a mid-epoch client's training state
    inside the provider's reclaim-warning window; "drain" additionally
    vacates the doomed instance right after the snapshot lands.

    This absorbs the machinery formerly hard-coded in
    `repro.fl.engines.base`; per-epoch facts (is the client mid-epoch,
    when did the epoch start, how long is it) come from the engine
    adapter at `ctx.view`.
    """

    def __init__(self, mode: str = "ignore"):
        self.mode = mode
        self._snap: Dict[str, dict] = {}   # client -> latest snapshot

    def bind(self, ctx: StrategyContext) -> None:
        """Subscribe to the cluster-filtered reclaim warnings."""
        super().bind(ctx)
        ctx.bus.subscribe(ClientPreemptionWarning, self._on_warning)

    # ------------------------------------------------------------------
    def _on_warning(self, ev: ClientPreemptionWarning) -> None:
        """Provider reclaim notice for a tracked client: start writing
        a training-state snapshot if (a) the client is actually
        mid-epoch and (b) the write can finish inside the notice
        window; otherwise the warning is informational and the reclaim
        falls back to periodic-checkpoint (lost-work) semantics."""
        ctx = self.ctx
        view = ctx.view
        if self.mode == "ignore" or view is None or view.is_done():
            return
        c = ev.client
        inst = ctx.instance_of(c)
        if inst is None or inst.iid != ev.instance.iid:
            return                          # stale: already replaced
        if not view.is_training(c):
            return                          # idle/pre-warmed: no state
        write_s = ctx.sched_cfg.warning_ckpt_write_s
        if ev.reclaim_at - ctx.now() + 1e-9 < write_s:
            return      # window too short: checkpoint cannot land
        # the snapshot captures progress at write *start*; work done
        # during the write itself is not in it (and is lost on reclaim)
        epoch_started = view.train_start(c)
        progress_s = ctx.now() - epoch_started
        ctx.schedule_in(write_s, lambda: (
            self._complete(c, ev.instance, ev.reclaim_at, progress_s,
                           epoch_started)))

    def _complete(self, c: str, inst, reclaim_at: float,
                  progress_s: float, epoch_started: float) -> None:
        """The notice-triggered snapshot finished writing: persist it
        via a `Checkpoint` directive and, under "drain", proactively
        vacate the instance. A no-op when the world moved on during
        the write (instance terminated/preempted, epoch finished — or
        a new epoch began on the same warm instance, which
        `epoch_started` detects)."""
        ctx = self.ctx
        view = ctx.view
        if view.is_done():
            return
        cur = ctx.instance_of(c)
        if cur is None or cur.iid != inst.iid or cur.state != _RUNNING:
            return          # terminated or reclaimed during the write
        if not view.is_training(c):
            return          # epoch finished inside the write window
        if view.train_start(c) != epoch_started:
            return          # a different epoch is running now
        r = view.current_round()
        remaining = max(view.train_duration(c) - progress_s, 1.0)
        payload = {"client": c, "round": r, "remaining": remaining,
                   "progress": progress_s, "t": ctx.now()}
        self._snap[c] = payload
        ctx.executor.apply([Checkpoint(
            c, round_idx=r, progress_s=progress_s, remaining_s=remaining,
            reclaim_at=reclaim_at, payload=payload)])
        if self.mode == "drain":
            self._drain(c, r, remaining)

    def _drain(self, c: str, r: int, remaining: float) -> None:
        """"drain": the snapshot is durable, so stop paying for a
        doomed instance — terminate it now (billing closes at the
        warning, not the reclaim) and immediately request the
        replacement with a resume token."""
        view = self.ctx.view
        # work done during the snapshot write is redone after resume
        view.note_lost_work(c, remaining)
        self._snap.pop(c, None)         # consumed by this resume
        self.ctx.executor.apply([Drain(c, resume_token={
            "round": r, "remaining": remaining, "source": "warning"})])
        view.after_drain(c, remaining)

    # ------------------------------------------------------------------
    def preemption_remaining(self, client: str, periodic_remaining: float
                             ) -> Optional[Tuple[float, str]]:
        """Offer the warning-window snapshot when it preserves more
        than the last periodic checkpoint (coarse `checkpoint_every_s`
        cadences are where the notice pays off)."""
        snap = self._snap.pop(client, None)
        if snap is None:
            return None
        stored = snapshots.load_snapshot(
            self.ctx.ckpt_store, client) or snap
        warn_remaining = float(stored["remaining"])
        if warn_remaining < periodic_remaining:
            return warn_remaining, "warning"
        return None

    def invalidate(self, client: str) -> None:
        """Epoch done: the warning snapshot (if any) is stale."""
        self._snap.pop(client, None)


class ForecastPrewarmStrategy(SchedulingStrategy):
    """Interruption-forecast pre-warming (ROADMAP): watch a reclaim
    hazard and pre-warm a *standby* replacement before the expected
    interruption burst. When the reclaim lands, the standby is
    promoted instead of a cold re-request — the spin-up gap collapses
    to ~0. Once the hazard falls back below the release threshold, an
    unused standby is cancelled so quiet market stretches cost nothing
    extra.

    `oracle=True` thresholds the true-model hazard (`ctx.hazard_of`,
    wired to `PriceCoupledModel.hazard` when that model drives the
    run); `oracle=False` thresholds the tenant-observable
    price-derived estimate (`ctx.observable_hazard_of`, the
    `repro.forecast.ObservableFeed` signal). The fully *learned*
    alternative — no hazard formula at all — is
    `repro.forecast.LearnedForecastStrategy`.

    Lives entirely outside `fl/engines/` and `cloud/`: it only reads
    context callables and answers with `SpinUp` / `Terminate`
    directives.
    """

    def __init__(self, hazard_threshold_per_hr: float = 2.0,
                 poll_s: float = 30.0,
                 release_below_per_hr: Optional[float] = None,
                 oracle: bool = True):
        self.threshold = hazard_threshold_per_hr
        self.poll_s = poll_s
        self.release = (release_below_per_hr
                        if release_below_per_hr is not None
                        else hazard_threshold_per_hr / 2.0)
        self.oracle = oracle

    def bind(self, ctx: StrategyContext) -> None:
        """Start the hazard polling loop on the simulator clock."""
        super().bind(ctx)
        ctx.schedule_in(self.poll_s, self._tick)

    def _tick(self) -> None:
        """One hazard sweep over every client; re-arms itself until
        the cluster shuts down."""
        ctx = self.ctx
        if ctx.is_shutdown():
            return
        hazard_of = ctx.hazard_of if self.oracle \
            else ctx.observable_hazard_of
        directives: List[Directive] = []
        for c in ctx.clients:
            inst = ctx.instance_of(c)
            standby = ctx.standby_of(c)
            tracked_spot = (inst is not None and not inst.on_demand
                            and inst.state == _RUNNING)
            # only clients that are actually mid-epoch stall anyone on
            # a reclaim: an idle instance lost at the barrier is simply
            # re-requested at the next dispatch, so a standby for it
            # would be pure waste
            training = (ctx.view is not None
                        and ctx.view.is_training(c))
            if tracked_spot and training and standby is None:
                if hazard_of(c) >= self.threshold:
                    directives.append(SpinUp(c))
            elif standby is not None:
                if (not tracked_spot or not training
                        or hazard_of(c) < self.release):
                    directives.append(Terminate(c, standby=True))
        if directives:
            ctx.executor.apply(directives)
        ctx.schedule_in(self.poll_s, self._tick)


# ---------------------------------------------------------------------------
# The stack: what engines talk to.
# ---------------------------------------------------------------------------
class StrategyStack:
    """The run's composed scheduling discipline.

    Owns the shared `FedCostAwareScheduler` decision core and the
    policy's strategy components. Engines report observations here
    (`note_*`) and invoke the decision points; strategies answer with
    directives that the stack (or the strategy itself, on reactive
    paths) applies through the `DirectiveExecutor`.

    A `WarningReaction` is appended automatically when the policy's
    strategy list does not name one, so the `on_warning` knob keeps
    working for every policy — exactly the pre-redesign behavior where
    the machinery lived in the shared engine base.
    """

    def __init__(self, strategies: Sequence[SchedulingStrategy],
                 ctx: StrategyContext):
        self.ctx = ctx
        self.sched = ctx.sched
        self.strategies: List[SchedulingStrategy] = list(strategies)
        if not any(isinstance(s, WarningReaction)
                   for s in self.strategies):
            self.strategies.append(WarningReaction(ctx.policy.on_warning))
        for s in self.strategies:
            s.bind(ctx)

    @classmethod
    def from_policy(cls, policy, ctx: StrategyContext) -> "StrategyStack":
        """Build the stack a policy's declarative spec list describes."""
        return cls([spec.build(policy) for spec in policy.strategies],
                   ctx)

    def attach_engine(self, view) -> None:
        """Register the engine adapter strategies read per-epoch facts
        from (`is_training` / `train_start` / …)."""
        self.ctx.view = view

    # ------------------------------------------------------------------
    # Observation reporting (engines feed the shared decision core).
    # ------------------------------------------------------------------
    def begin_round(self, round_idx: int) -> None:
        """A new FL round opened: reset per-round decision state."""
        self.sched.begin_round(round_idx)

    def note_dispatch(self, client: str, t: float, cold: bool,
                      includes_spin_up: bool) -> None:
        """A training task was dispatched to `client` at `t`."""
        self.sched.register_dispatch(client, t, cold, includes_spin_up)

    def note_result(self, client: str, t: float, epoch_s: float,
                    cold: bool, spin_up_s: Optional[float]) -> None:
        """`client` finished a full epoch: update finish state and the
        §III-B EMA estimates."""
        self.sched.on_result(client, t, epoch_s, cold, spin_up_s)

    def note_resume_result(self, client: str, t: float,
                           spin_up_s: Optional[float]) -> None:
        """`client` finished a *partial* (checkpoint-resumed) epoch:
        partial durations would corrupt the epoch-time EMAs, so only
        the finish state and the spin-up observation are recorded."""
        s = self.sched.states[client]
        s.finished = True
        s.finish_time = t
        if spin_up_s is not None:
            self.sched.est.observe_spin_up(client, spin_up_s)

    def note_observation(self, client: str,
                         epoch_s: Optional[float] = None,
                         cold: bool = False,
                         spin_up_s: Optional[float] = None) -> None:
        """Round-free estimator update (async engines keep the EMAs
        fresh without the sync barrier's round bookkeeping)."""
        if epoch_s is not None:
            self.sched.est.observe_epoch(client, epoch_s, cold)
        if spin_up_s is not None:
            self.sched.est.observe_spin_up(client, spin_up_s)

    # ------------------------------------------------------------------
    # Decision points.
    # ------------------------------------------------------------------
    def screen(self, round_idx: int, candidates: List[str]
               ) -> Tuple[List[str], List[str]]:
        """Chain every component's screening pass; returns the
        surviving participants and the newly screened-out clients
        (whose `ScreenOut` directives were already applied)."""
        keep, screened_all = list(candidates), []
        for s in self.strategies:
            keep, screened = s.screen(round_idx, keep)
            screened_all.extend(screened)
        return keep, screened_all

    def client_result(self, client: str, t: float,
                      more_rounds: bool) -> None:
        """`client` delivered its round result while the round is
        still open: apply every component's lifecycle directives."""
        for s in self.strategies:
            d = s.on_client_result(client, t, more_rounds)
            if d:
                self.ctx.executor.apply(d)

    def recovered(self, client: str, remaining_s: float) -> None:
        """§III-D dynamic schedule adjustment: `client` restarts after
        a reclaim (or drain) owing `remaining_s` seconds; push back the
        pre-warm targets of already-terminated clients so they stay
        off while it recovers."""
        spin_est = self.sched.est.model(client).spin_up.get(
            self.ctx.spin_up_default)
        recovery_finish = self.ctx.now() + spin_est + remaining_s
        moved = self.sched.on_preemption_recovery(client, recovery_finish)
        if moved:
            self.ctx.executor.apply(
                [PreWarm(c, t) for c, t in moved.items()])

    def preemption_remaining(self, client: str, periodic_remaining: float
                             ) -> Tuple[float, str]:
        """Epoch time still owed after a reclaim, from the best
        surviving checkpoint any component can offer; falls back to
        the periodic checkpoint. Returns `(remaining_s, source)`."""
        for s in self.strategies:
            better = s.preemption_remaining(client, periodic_remaining)
            if better is not None:
                return better
        return periodic_remaining, "periodic"

    def invalidate_ckpt(self, client: str) -> None:
        """The client's epoch completed: per-epoch strategy state
        (warning snapshots) is stale."""
        for s in self.strategies:
            s.invalidate(client)

    def prewarm_target(self, client: str) -> Optional[float]:
        """The client's currently queued pre-warm fire time, if any
        (consulted by the cluster's staleness check at fire time)."""
        return self.sched.prewarm_queue.get(client)
