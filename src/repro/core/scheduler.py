"""FedCostAware scheduler — the paper's core contribution (§III, Listing 1).

Implements, against the simulated cloud:
  * calibration phase (round 1 cold / round 2 warm, §III-B),
  * EMA estimate updates on every client result,
  * instance termination when predicted idle time pays for a respin
    (`idle - T_spin_up > T_threshold`),
  * proactive pre-warming at `F_s - T_spin_up - T_buffer`,
  * dynamic schedule adjustment when a preempted client pushes the round's
    critical path out (§III-D),
  * budget screening before each round (§III-E).

Since the composable-strategy redesign this class is the pure
*decision core*: round engines never call it directly. The strategy
components in `repro.core.strategy` (LifecycleStrategy wrapping the
Listing-1 calls, BudgetScreen wrapping §III-E) read and update it
through the `StrategyStack`, and the OnDemand / PlainSpot baselines
simply compose no strategies — which is exactly the paper's Table I
comparison.

"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.common.config import SchedulerConfig
from repro.core.budget import BudgetLedger
from repro.core.estimator import TimeEstimator


@dataclasses.dataclass
class RoundClientState:
    """Scheduler-visible state of one client within the current round."""
    start_time: float = 0.0         # when its training task was dispatched
    is_cold_start: bool = True      # first epoch on a fresh instance?
    includes_spin_up: bool = False  # instance still spinning at dispatch?
    finished: bool = False
    finish_time: Optional[float] = None
    # recovery override (§III-D): expected finish after preemption restart
    recovery_finish: Optional[float] = None


class FedCostAwareScheduler:
    """Pure decision logic with no side effects: the strategy
    components (`repro.core.strategy`) consume the decisions and the
    `DirectiveExecutor` (`repro.fl.cluster`) executes them (terminate /
    pre-warm spin-ups), so the scheduler stays independently testable
    and engine-agnostic — the async buffered engine's stack reuses the
    estimator EMAs and §III-E budget screening while skipping the
    barrier-specific Listing-1 calls.
    """

    def __init__(self, cfg: SchedulerConfig, estimator: TimeEstimator,
                 ledger: BudgetLedger):
        self.cfg = cfg
        self.est = estimator
        self.ledger = ledger
        self.round_idx = 0
        self.states: Dict[str, RoundClientState] = {}
        self.prewarm_queue: Dict[str, float] = {}   # client -> spin_up time
        self.terminated: set = set()

    # ------------------------------------------------------------------
    # Round bookkeeping.
    # ------------------------------------------------------------------
    @property
    def in_calibration(self) -> bool:
        return self.round_idx < self.cfg.calibration_rounds

    def begin_round(self, round_idx: int):
        self.round_idx = round_idx
        self.states = {}
        self.prewarm_queue = {}

    def register_dispatch(self, client: str, t: float, cold: bool,
                          includes_spin_up: bool):
        self.states[client] = RoundClientState(
            start_time=t, is_cold_start=cold,
            includes_spin_up=includes_spin_up)

    # ------------------------------------------------------------------
    # Listing 1: estimate_slowest_finish_time.
    # ------------------------------------------------------------------
    def estimate_finish(self, client: str) -> float:
        s = self.states[client]
        if s.finished:
            return s.finish_time
        if s.recovery_finish is not None:
            return s.recovery_finish
        m = self.est.model(client)
        return m.predict_finish(s.start_time, s.is_cold_start,
                                s.includes_spin_up)

    def estimate_slowest_finish_time(self) -> float:
        return max(self.estimate_finish(c) for c in self.states)

    # ------------------------------------------------------------------
    # Listing 1: evaluate_termination.
    # ------------------------------------------------------------------
    def evaluate_termination(self, client: str, f_i: float,
                             more_rounds: bool) -> Optional[float]:
        """Called when `client` delivers its result at time `f_i`.

        Returns the pre-warm spin-up start time if the instance should be
        terminated (caller terminates + queues the spin-up), else None.
        """
        if self.in_calibration:
            return None
        f_s = self.estimate_slowest_finish_time()
        idle = f_s - f_i
        t_spin = self.est.model(client).spin_up.get(self.cfg.t_threshold_s)
        if idle - t_spin <= self.cfg.t_threshold_s:
            return None
        self.terminated.add(client)
        if not more_rounds:
            return math.inf            # terminate; nothing to pre-warm
        prewarm_t = f_s - t_spin - self.cfg.t_buffer_s
        self.prewarm_queue[client] = prewarm_t
        return prewarm_t

    # ------------------------------------------------------------------
    # Result / preemption hooks (§III-B, §III-D).
    # ------------------------------------------------------------------
    def on_result(self, client: str, t: float, epoch_duration: float,
                  cold: bool, spin_up_observed: Optional[float]):
        s = self.states[client]
        s.finished = True
        s.finish_time = t
        self.est.observe_epoch(client, epoch_duration, cold)
        if spin_up_observed is not None:
            self.est.observe_spin_up(client, spin_up_observed)

    def on_preemption_recovery(self, client: str, recovery_finish: float
                               ) -> Dict[str, float]:
        """§III-D: a preempted client restarts and will now finish at
        `recovery_finish`; recompute pre-warm targets for every already-
        terminated client. Returns the updated {client: spin_up_time} map
        (callers must reschedule their pending spin-up events).
        """
        s = self.states.get(client)
        if s is not None:
            s.recovery_finish = recovery_finish
        f_s = self.estimate_slowest_finish_time()
        updates = {}
        for c, old_t in list(self.prewarm_queue.items()):
            t_spin = self.est.model(c).spin_up.get(self.cfg.t_threshold_s)
            new_t = max(f_s, recovery_finish) - t_spin - self.cfg.t_buffer_s
            if new_t > old_t + 1e-9:
                self.prewarm_queue[c] = new_t
                updates[c] = new_t
        return updates

    # ------------------------------------------------------------------
    # Budget screening (§III-E).
    # ------------------------------------------------------------------
    def screen_participants(self, clients: List[str],
                            spot_price_of) -> List[str]:
        def est_cost(c):
            m = self.est.model(c)
            dur = m.predict_epoch(cold=False) + m.spin_up.get()
            return spot_price_of(c) * dur / 3600.0

        return self.ledger.screen_round(clients, est_cost)
