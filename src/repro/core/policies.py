"""Scheduling policies compared in the paper's Table I — now declarative
compositions of `SchedulingStrategy` components (`repro.core.strategy`).

  on_demand          — on-demand instances, kept running for the whole
                       job; no strategies.
  spot               — spot instances, kept running for the whole job
                       (fault-tolerant but no lifecycle management); no
                       strategies.
  fedcostaware       — spot instances + the FedCostAware discipline
                       (§III) as `LifecycleSpec() + BudgetScreenSpec()`
                       under the paper's synchronous round barrier.
  fedcostaware_async — beyond-paper fourth column: the same strategy
                       composition, but rounds run on the FedBuff-style
                       async buffered engine (aggregate after K results;
                       stragglers roll into the next round).

Each policy names the `RoundEngine` implementation that owns its round
semantics (see `repro.fl.engines`) and the strategy components that own
its scheduling decisions; both plug in without touching engine or cloud
internals. `register_policy` adds beyond-paper compositions under new
names — e.g. the oracle/observable forecast-pre-warming variants
(`benchmarks/forecast_prewarm.py`) or the learned-forecast composition
(`repro.forecast.register_learned_policy`, whose strategy lives
entirely outside this package yet plugs into `Policy.strategies` like
any core spec).

Legacy boolean construction — `Policy(name, on_demand,
manage_lifecycle, enforce_budgets, pick_cheapest_zone)` — still works:
the flags map onto the equivalent strategy list with a
`DeprecationWarning`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

from repro.common.config import SchedulerConfig
from repro.core.budget import BudgetLedger
from repro.core.estimator import TimeEstimator
from repro.core.scheduler import FedCostAwareScheduler
from repro.core.strategy import (BudgetScreenSpec, LifecycleSpec,
                                 StrategySpec)


# valid engine reactions to a provider's preemption-notice warning
ON_WARNING_MODES = ("ignore", "checkpoint", "drain")


def _known_engines() -> Optional[Tuple[str, ...]]:
    """The `RoundEngine` registry keys, or None while the registry is
    still importing (the one circular-bootstrap window: building the
    module-level `POLICIES` below triggers `repro.fl.engines`, whose
    import chain re-enters this module)."""
    try:
        from repro.fl.engines import ENGINES
    except ImportError:
        return None
    return tuple(sorted(ENGINES))


@dataclasses.dataclass(frozen=True, init=False)
class Policy:
    """One scheduling policy: which market, strategy composition, round
    engine, placement scope and warning reaction a run uses."""
    name: str
    on_demand: bool              # instance market
    pick_cheapest_zone: bool     # cheapest-zone placement vs pinned
    engine: str                  # RoundEngine registry key
    # whether cheapest-zone placement arbitrates across *every* provider
    # in the SpotMarket (Multi-FedLS-style) or stays on the market's
    # default provider. Moot on single-provider markets, so the default
    # preserves all existing behavior; `FLRunConfig.cross_provider`
    # overrides it per run.
    cross_provider: bool
    # how engines react to a provider's preemption-notice warning
    # (`ClientPreemptionWarning`): "ignore" (historical behavior — work
    # since the last periodic checkpoint is lost on reclaim),
    # "checkpoint" (snapshot training state inside the notice window,
    # resume the replacement from it), or "drain" (snapshot, then
    # proactively terminate and re-request before the reclaim lands).
    # `FLRunConfig.on_warning` overrides it per run.
    on_warning: str
    # the declarative strategy composition (repro.core.strategy specs);
    # the composition root builds a StrategyStack from it per run
    strategies: Tuple[StrategySpec, ...]

    def __init__(self, name: str, on_demand: bool = False,
                 manage_lifecycle: Optional[bool] = None,
                 enforce_budgets: Optional[bool] = None,
                 pick_cheapest_zone: bool = False, engine: str = "sync",
                 cross_provider: bool = True, on_warning: str = "ignore",
                 strategies: Optional[Tuple[StrategySpec, ...]] = None):
        """Construct a policy; `manage_lifecycle`/`enforce_budgets` are
        the deprecated boolean spelling of the strategy list (kept so
        pre-redesign `Policy(name, od, lifecycle, budgets, cheapest)`
        call sites keep working)."""
        if manage_lifecycle is not None or enforce_budgets is not None:
            if strategies is not None:
                raise ValueError(
                    f"policy {name!r}: pass either the deprecated "
                    f"boolean flags or `strategies=`, not both")
            warnings.warn(
                f"policy {name!r}: boolean Policy flags "
                f"(manage_lifecycle/enforce_budgets) are deprecated; "
                f"compose strategies instead, e.g. "
                f"Policy({name!r}, strategies=(LifecycleSpec(), "
                f"BudgetScreenSpec()))",
                DeprecationWarning, stacklevel=2)
            mapped = []
            if manage_lifecycle:
                mapped.append(LifecycleSpec())
            if enforce_budgets:
                mapped.append(BudgetScreenSpec())
            strategies = tuple(mapped)
        strategies = tuple(strategies or ())
        for s in strategies:
            if not isinstance(s, StrategySpec):
                raise ValueError(
                    f"policy {name!r}: strategies must be StrategySpec "
                    f"instances, got {type(s).__name__}")
        if on_warning not in ON_WARNING_MODES:
            raise ValueError(
                f"policy {name!r}: unknown on_warning mode "
                f"{on_warning!r}; known: {ON_WARNING_MODES}")
        known = _known_engines()
        if known is not None and engine not in known:
            raise ValueError(
                f"policy {name!r}: unknown round engine {engine!r}; "
                f"known: {list(known)}")
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "on_demand", on_demand)
        object.__setattr__(self, "pick_cheapest_zone", pick_cheapest_zone)
        object.__setattr__(self, "engine", engine)
        object.__setattr__(self, "cross_provider", cross_provider)
        object.__setattr__(self, "on_warning", on_warning)
        object.__setattr__(self, "strategies", strategies)

    # ------------------------------------------------------------------
    # Backwards-compatible boolean views of the composition.
    # ------------------------------------------------------------------
    @property
    def manage_lifecycle(self) -> bool:
        """Does the composition include Listing-1 lifecycle management
        (the old `manage_lifecycle` flag)?"""
        return any(isinstance(s, LifecycleSpec) for s in self.strategies)

    @property
    def enforce_budgets(self) -> bool:
        """Does the composition include §III-E budget screening (the
        old `enforce_budgets` flag)?"""
        return any(isinstance(s, BudgetScreenSpec)
                   for s in self.strategies)


POLICIES = {
    "on_demand": Policy("on_demand", on_demand=True),
    "spot": Policy("spot", pick_cheapest_zone=True),
    "fedcostaware": Policy(
        "fedcostaware", pick_cheapest_zone=True,
        strategies=(LifecycleSpec(), BudgetScreenSpec())),
    "fedcostaware_async": Policy(
        "fedcostaware_async", pick_cheapest_zone=True,
        strategies=(LifecycleSpec(), BudgetScreenSpec()),
        engine="async_buffered"),
}


def get_policy(name: str) -> Policy:
    """Look up a registered policy by name."""
    return POLICIES[name]


def register_policy(policy: Policy, overwrite: bool = False) -> Policy:
    """Register a beyond-Table-I policy composition under its name so
    string-keyed plumbing (`FLRunConfig.policy`, benchmarks) can reach
    it. Re-registering an existing name raises unless `overwrite`."""
    if policy.name in POLICIES and not overwrite:
        raise ValueError(f"policy {policy.name!r} already registered")
    POLICIES[policy.name] = policy
    return policy


def make_scheduler(policy: Policy, sched_cfg: SchedulerConfig,
                   spin_up_prior: float = 150.0) -> FedCostAwareScheduler:
    """Fresh FedCostAware decision core (estimator + budget ledger) for
    a run under `policy` — the shared state every strategy component
    reads (`StrategyContext.sched`)."""
    est = TimeEstimator(sched_cfg.ema_alpha, spin_up_prior)
    ledger = BudgetLedger()
    return FedCostAwareScheduler(sched_cfg, est, ledger)
