"""Scheduling policies compared in the paper's Table I.

  on_demand          — on-demand instances, kept running for the whole
                       job.
  spot               — spot instances, kept running for the whole job
                       (fault-tolerant but no lifecycle management).
  fedcostaware       — spot instances + the FedCostAware scheduler
                       (terminate idle, pre-warm, budgets, §III) under
                       the paper's synchronous round barrier.
  fedcostaware_async — beyond-paper fourth column: same spot market and
                       budget screening, but rounds run on the
                       FedBuff-style async buffered engine (aggregate
                       after K results; stragglers roll into the next
                       round), which eliminates the idle time the sync
                       scheduler could only terminate around.

Each policy names the `RoundEngine` implementation that owns its round
semantics (see `repro.fl.engines`); the runner resolves `engine` through
the engine registry, so new round disciplines plug in without touching
the policies of the existing Table-I columns.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.common.config import SchedulerConfig
from repro.core.budget import BudgetLedger
from repro.core.estimator import TimeEstimator
from repro.core.scheduler import FedCostAwareScheduler


# valid engine reactions to a provider's preemption-notice warning
ON_WARNING_MODES = ("ignore", "checkpoint", "drain")


@dataclasses.dataclass(frozen=True)
class Policy:
    """One Table-I column: which market, lifecycle management, round
    engine, placement scope and warning reaction a run uses."""
    name: str
    on_demand: bool              # instance market
    manage_lifecycle: bool       # terminate-idle + pre-warm
    enforce_budgets: bool
    pick_cheapest_zone: bool
    engine: str = "sync"         # RoundEngine registry key
    # whether cheapest-zone placement arbitrates across *every* provider
    # in the SpotMarket (Multi-FedLS-style) or stays on the market's
    # default provider. Moot on single-provider markets, so the default
    # preserves all existing behavior; `FLRunConfig.cross_provider`
    # overrides it per run.
    cross_provider: bool = True
    # how engines react to a provider's preemption-notice warning
    # (`ClientPreemptionWarning`): "ignore" (historical behavior — work
    # since the last periodic checkpoint is lost on reclaim),
    # "checkpoint" (snapshot training state inside the notice window,
    # resume the replacement from it), or "drain" (snapshot, then
    # proactively terminate and re-request before the reclaim lands).
    # `FLRunConfig.on_warning` overrides it per run.
    on_warning: str = "ignore"

    def __post_init__(self):
        """Reject unknown warning reactions: anything other than the
        exact "ignore" would otherwise silently take the checkpoint
        path in the engines."""
        if self.on_warning not in ON_WARNING_MODES:
            raise ValueError(
                f"unknown on_warning mode {self.on_warning!r}; "
                f"known: {ON_WARNING_MODES}")


POLICIES = {
    "on_demand": Policy("on_demand", True, False, False, False),
    "spot": Policy("spot", False, False, False, True),
    "fedcostaware": Policy("fedcostaware", False, True, True, True),
    "fedcostaware_async": Policy("fedcostaware_async", False, True, True,
                                 True, engine="async_buffered"),
}


def get_policy(name: str) -> Policy:
    """Look up a registered policy by its Table-I name."""
    return POLICIES[name]


def make_scheduler(policy: Policy, sched_cfg: SchedulerConfig,
                   spin_up_prior: float = 150.0) -> FedCostAwareScheduler:
    """Fresh FedCostAware scheduler (estimator + budget ledger) for a
    run under `policy`."""
    est = TimeEstimator(sched_cfg.ema_alpha, spin_up_prior)
    ledger = BudgetLedger()
    return FedCostAwareScheduler(sched_cfg, est, ledger)
