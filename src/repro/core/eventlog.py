"""Event-log record/replay: persist a run's typed event stream, rebuild
it offline.

`EventRecorder` subscribes (wildcard) to an `EventBus` and serializes
every frozen event dataclass to JSONL: one header line carrying the
schema version + run metadata, then one line per event in publish order.
Object references (`repro.cloud.simulator.Instance`) are replaced by a
stable snapshot keyed on the instance id, taken at publish time — the
log is plain data, diffable across runs, and two runs of the same
seeded config produce byte-comparable streams (the determinism CI job
relies on this).

`EventReplayer` parses a recorded stream back into typed events
(instances become frozen `InstanceRef` stand-ins) and re-publishes them
onto a fresh bus in recorded order. Pure consumers — `CostAccountant`
with no price book, `TimelineRecorder`, `CostCurveRecorder`
(fl.telemetry) — then rebuild per-client costs, Fig-4 timelines and
Fig-5 cost curves without ever touching `CloudSimulator`. That is the
record-then-audit discipline of Multi-FedLS-style post-hoc cost
accounting, and it turns recorded traces into golden regression
fixtures (tests/golden/).

Layering: this module depends only on `core.events` and the stdlib.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Union

from repro.core.events import EVENT_TYPES, Event, EventBus

# Schema history (full vocabulary per version in docs/events.md):
#   v1 — engine-split vocabulary; instance snapshots without provider
#   v2 — instance snapshots carry the provider of the zone they run in
#        (multi-cloud SpotMarket); v1 logs decode with the
#        single-provider default on InstanceRef
#   v3 — preemption-notice checkpointing vocabulary:
#        ClientPreemptionWarning / ClientCheckpointed /
#        ClientResumedFromCheckpoint. Purely additive — v1/v2 logs
#        (golden copies under tests/golden/v1, tests/golden/v2) replay
#        unchanged.
#   v4 — strategy-API vocabulary: DirectiveIssued (opt-in directive
#        tracing), ClientScreenedOut (§III-E exclusions),
#        CheckpointBilled (storage dollars per warning checkpoint);
#        ClientCheckpointed gains `size_mb`. Purely additive — v1–v3
#        logs (golden copies under tests/golden/v1..v3) replay
#        unchanged; fields absent from older logs take their
#        dataclass defaults on decode.
#   v5 — fleet-core vocabulary: FleetStepSummary (aggregate per-step
#        lifecycle counts + settled cost deltas per provider/zone,
#        emitted by the struct-of-arrays fleet path above
#        `CloudConfig.fleet_threshold` in place of per-instance
#        events). Purely additive — v1–v4 logs (golden copies under
#        tests/golden/v1..v4) replay unchanged, and sub-threshold runs
#        still record the exact per-instance vocabulary.
#   v6 — FleetStepSummary gains `client_cost_delta` (client -> dollars
#        settled that step, summing to `cost_delta`), fixing the v5
#        replay gap where fleet traces rebuilt the correct run total
#        but reported every per-client cost as zero. Purely additive —
#        v1–v5 logs (golden copies under tests/golden/v1..v5) replay
#        unchanged; a v5 summary decodes with an empty map, which
#        replay accounting surfaces as "per-client attribution absent"
#        (`RunResult.has_client_costs=False`) instead of zeros.
#   v7 — communication-cost vocabulary (the `repro.comms` subsystem):
#        ClientUpdateSent (one per client-update upload: payload MB,
#        quantized flag, provider/zone, transfer seconds) and
#        TransferBilled (egress dollars the live accountant priced for
#        that upload, mirroring CheckpointBilled). Purely additive —
#        v1–v6 logs (golden copies under tests/golden/v1..v6) replay
#        unchanged, and runs without comms modeling (the default:
#        `FLRunConfig.update_payload_mb=None`, zero egress rates)
#        record streams identical to v6 apart from the header.
#   v8 — learned-forecast vocabulary (the `repro.forecast` subsystem):
#        ForecastUpdated (one per forecast poll per tracked training
#        spot client: predicted interruption probability + hazard,
#        learned price band, running Brier/coverage calibration, and
#        the cost-of-error action chosen). Headers may additionally
#        carry `hazard_source` ("oracle" | "observable" | "mixed")
#        naming which hazard signal the run's strategies actually
#        consulted — absent when none did. Purely additive — v1–v7
#        logs (golden copies under tests/golden/v1..v7) replay
#        unchanged, and runs without a learned-forecast strategy (the
#        default policies) record streams identical to v7 apart from
#        the header.
SCHEMA_VERSION = 8
SUPPORTED_SCHEMAS = (1, 2, 3, 4, 5, 6, 7, 8)

_SCALARS = (bool, int, float, str)


@dataclasses.dataclass(frozen=True)
class InstanceRef:
    """Replay-side stand-in for a live `Instance`: the recorded snapshot
    of its scalar fields at event time. Replayed billing segments are
    always already closed, hence the class-level `_billing_from` — the
    accountant's open-segment pricing sees `None` and charges nothing.

    `provider` defaults to the single provider every v1 log implicitly
    ran on, so v1 snapshots (no provider key) decode losslessly.
    """
    iid: int
    client: str
    zone: str
    on_demand: bool
    t_request: float
    t_ready: Optional[float] = None
    t_end: Optional[float] = None
    state: str = "spinning_up"
    provider: str = "aws"

    _billing_from = None        # class attr on purpose: never a field


_INSTANCE_FIELDS = tuple(f.name for f in dataclasses.fields(InstanceRef))


# ---------------------------------------------------------------------------
# Encoding (live objects -> JSON-ready dicts).
# ---------------------------------------------------------------------------
def _encode_value(v: Any) -> Any:
    if v is None or isinstance(v, _SCALARS):
        return v
    if hasattr(v, "iid") and hasattr(v, "client"):     # Instance(-Ref)
        return {"$instance": {f: getattr(v, f, None)
                              for f in _INSTANCE_FIELDS}}
    if isinstance(v, dict):
        return {str(k): _encode_value(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_encode_value(x) for x in v]
    raise TypeError(f"event field of type {type(v).__name__} is not "
                    f"serializable: {v!r}")


def encode_event(ev: Event) -> Dict[str, Any]:
    """Event dataclass -> JSON-ready dict (`type` key + every field,
    instances snapshotted)."""
    rec: Dict[str, Any] = {"type": type(ev).__name__}
    for f in dataclasses.fields(ev):
        rec[f.name] = _encode_value(getattr(ev, f.name))
    return rec


def _decode_value(v: Any) -> Any:
    if isinstance(v, dict):
        if "$instance" in v:
            return InstanceRef(**v["$instance"])
        return {k: _decode_value(x) for k, x in v.items()}
    if isinstance(v, list):
        return tuple(_decode_value(x) for x in v)
    return v


def decode_event(rec: Dict[str, Any]) -> Event:
    """Inverse of `encode_event`; instance snapshots decode to
    `InstanceRef`. Raises on event types absent from `EVENT_TYPES`.
    Fields an older-schema log does not carry (e.g. v3's
    `ClientCheckpointed` without `size_mb`) take their dataclass
    defaults, so additive field growth never breaks replay."""
    name = rec["type"]
    if name not in EVENT_TYPES:
        raise ValueError(f"unknown event type in log: {name!r}")
    cls = EVENT_TYPES[name]
    kwargs = {f.name: _decode_value(rec[f.name])
              for f in dataclasses.fields(cls) if f.name in rec}
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Recorder.
# ---------------------------------------------------------------------------
class EventRecorder:
    """Captures every event published on `bus` as an encoded record.

    Events are encoded at publish time, so the log reflects instance
    state at the instant of each event even though `Instance` objects
    mutate afterwards.
    """

    def __init__(self, bus: EventBus, meta: Optional[Dict[str, Any]] = None):
        self.header: Dict[str, Any] = {"schema": SCHEMA_VERSION,
                                       **(meta or {})}
        self.records: List[Dict[str, Any]] = []
        bus.subscribe_all(self._on_event)

    def _on_event(self, ev: Event) -> None:
        self.records.append(encode_event(ev))

    # ------------------------------------------------------------------
    def dumps(self) -> str:
        """The full log as JSONL text: header line, then one event per
        line in publish order."""
        # no sort_keys: dataclass field order and profile insertion
        # order are deterministic, and preserving them keeps replayed
        # dict iteration (e.g. cost-curve client order) identical to
        # the live run's.
        lines = [json.dumps(self.header)]
        lines.extend(json.dumps(r) for r in self.records)
        return "\n".join(lines) + "\n"

    def dump(self, path: Union[str, Path]) -> Path:
        """Write `dumps()` to `path`, creating parent directories."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.dumps())
        return path


# ---------------------------------------------------------------------------
# Read-only stream access (shared by the replayer and the reporting
# CLI, repro.cloud.report). Errors are one-line ValueErrors naming the
# source and line number — replay-consuming entry points print them
# verbatim instead of a raw traceback on truncated/corrupt logs.
# ---------------------------------------------------------------------------
def _parse_header(line: str, source: str) -> Dict[str, Any]:
    try:
        header = json.loads(line)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"{source}: header line is not valid JSON ({e.msg}) — "
            f"corrupt file or not a recorded event log") from None
    if not isinstance(header, dict) or "schema" not in header:
        raise ValueError(
            f"{source}: header carries no schema field — not a "
            f"recorded event log")
    if header["schema"] not in SUPPORTED_SCHEMAS:
        raise ValueError(
            f"{source}: event log schema {header['schema']!r} not in "
            f"supported {SUPPORTED_SCHEMAS}")
    return header


def read_header(path: Union[str, Path]) -> Dict[str, Any]:
    """Parse only a recorded trace's header line (schema + run
    metadata) without decoding any events — the cheap identity lookup
    `repro.cloud.report trends` scans whole directories with."""
    path = Path(path)
    with path.open() as fh:
        for line in fh:
            if line.strip():
                return _parse_header(line, str(path))
    raise ValueError(f"{path}: empty event log")


def iter_events(path: Union[str, Path]):
    """Lazily decode a recorded trace's events in publish order (header
    validated first). Corrupt or truncated lines raise a one-line
    `ValueError` naming the source and line number instead of leaking
    a raw `json` traceback."""
    path = Path(path)
    saw_header = False
    with path.open() as fh:
        for lineno, line in enumerate(fh, start=1):
            if not line.strip():
                continue
            if not saw_header:
                _parse_header(line, str(path))
                saw_header = True
                continue
            yield _decode_line(line, lineno, str(path))
    if not saw_header:
        raise ValueError(f"{path}: empty event log")


def _decode_line(line: str, lineno: int, source: str) -> Event:
    try:
        rec = json.loads(line)
    except json.JSONDecodeError:
        raise ValueError(
            f"{source}: line {lineno} is not valid JSON — truncated "
            f"or corrupt event log") from None
    try:
        return decode_event(rec)
    except (TypeError, KeyError, ValueError) as e:
        raise ValueError(f"{source}: line {lineno}: {e}") from None


# ---------------------------------------------------------------------------
# Replayer.
# ---------------------------------------------------------------------------
class EventReplayer:
    """Re-publishes a recorded stream onto a bus, in recorded order."""

    def __init__(self, header: Dict[str, Any], events: List[Event]):
        self.header = header
        self.events = events

    @classmethod
    def loads(cls, text: str,
              source: str = "event log") -> "EventReplayer":
        """Parse JSONL log text; rejects unsupported schema versions
        and raises one-line, line-numbered `ValueError`s on corrupt
        or truncated input."""
        numbered = [(i, ln) for i, ln in enumerate(text.splitlines(),
                                                  start=1) if ln.strip()]
        if not numbered:
            raise ValueError(f"{source}: empty event log")
        header = _parse_header(numbered[0][1], source)
        events = [_decode_line(ln, i, source) for i, ln in numbered[1:]]
        return cls(header, events)

    @classmethod
    def load(cls, path: Union[str, Path]) -> "EventReplayer":
        """`loads` over a file on disk (errors name the path)."""
        return cls.loads(Path(path).read_text(), source=str(path))

    def replay(self, bus: EventBus) -> None:
        """Publish every recorded event onto `bus`, in recorded order."""
        for ev in self.events:
            bus.publish(ev)
