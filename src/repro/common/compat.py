"""JAX version-compatibility shims.

The mesh / shard_map APIs moved between JAX releases:

  * ``jax.set_mesh``       — new; older releases have
    ``jax.sharding.use_mesh``, and before that ``Mesh`` itself is the
    context manager.
  * ``jax.shard_map``      — new (with ``check_vma``); older releases
    ship ``jax.experimental.shard_map.shard_map`` (with ``check_rep``).

All repro code (and tests/examples) route through these wrappers so the
code base runs unmodified across the JAX versions we encounter.
"""
from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager making `mesh` the ambient mesh for jit/shard_map.

    Prefers ``jax.set_mesh``, falls back to ``jax.sharding.use_mesh``,
    and finally to entering the ``Mesh`` object itself (the pre-0.5 API).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh          # Mesh is a context manager in older JAX


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` if present, else the experimental spelling with
    ``check_vma`` translated to the old ``check_rep`` keyword."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
