"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's
own FL experiments additionally use ``FLRunConfig`` + ``CloudConfig``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Layer kinds used in block patterns.
# ---------------------------------------------------------------------------
ATTN = "attn"            # global self attention (GQA / MHA)
LOCAL_ATTN = "local_attn"  # sliding-window self attention
CROSS_ATTN = "cross_attn"  # cross attention to (stub) image embeddings
MAMBA2 = "mamba2"        # SSD state-space layer
RGLRU = "rglru"          # Griffin recurrent block (RG-LRU)

SUPPORTED_KINDS = (ATTN, LOCAL_ATTN, CROSS_ATTN, MAMBA2, RGLRU)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    capacity_factor: float = 1.25
    group_size: int = 512          # tokens per dispatch group (GShard style)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD hyper-parameters."""
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """Griffin / RecurrentGemma recurrent-block hyper-parameters."""
    lru_width: Optional[int] = None   # defaults to d_model
    conv_width: int = 4
    c_constant: float = 8.0           # the fixed `c` exponent scale


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|ssm|hybrid|moe|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // num_heads
    # Block pattern. A model is `num_layers` layers tiled by `pattern`;
    # remainder layers (num_layers % len(pattern)) form an explicit tail
    # taking the pattern prefix.
    pattern: Tuple[str, ...] = (ATTN,)
    # attention
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    window_size: int = 2048            # for local_attn layers
    logit_softcap: Optional[float] = None
    # mlp
    mlp_kind: str = "swiglu"           # swiglu|gelu
    # optional sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # vlm / audio frontends (stub): number of conditioning tokens fed to
    # cross-attention layers (vlm) or raw frame-embedding inputs (audio).
    n_cond_tokens: int = 0
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    grad_accum: int = 1                # microbatches per train step
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False           # TPU path; dry-run/CPU uses refs
    attn_chunk: int = 1024             # query-chunk for online-softmax attn
    # per-arch logical->mesh rule overrides (e.g. granite's 40 experts do
    # not divide a 16-way axis: shard the expert FFN dim instead)
    sharding_overrides: Optional[Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]] = None

    # ------------------------------------------------------------------
    def __post_init__(self):
        for k in self.pattern:
            if k not in SUPPORTED_KINDS:
                raise ValueError(f"unknown layer kind {k!r}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("moe family requires MoEConfig")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def n_super(self) -> int:
        """Number of full pattern repetitions (scanned)."""
        return self.num_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        """Remainder layers appended after the scanned super-blocks."""
        return self.pattern[: self.num_layers % len(self.pattern)]

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_subquadratic(self) -> bool:
        """True when no layer performs global attention (long_500k eligible)."""
        full = set(self.pattern + self.tail_pattern)
        return ATTN not in full and CROSS_ATTN not in full

    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6ND)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        counts = {}
        counts[ATTN] = counts[LOCAL_ATTN] = (
            d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
            + (2 * d)  # 2 rmsnorm scales (pre-attn + pre-mlp share layer)
        )
        counts[CROSS_ATTN] = counts[ATTN]
        if self.qkv_bias:
            counts[ATTN] += nq * hd + 2 * nkv * hd
            counts[LOCAL_ATTN] = counts[CROSS_ATTN] = counts[ATTN]
        if self.moe is not None:
            e, eff = self.moe.num_experts, self.moe.d_ff
            mlp = d * e + e * (3 * d * eff if self.mlp_kind == "swiglu" else 2 * d * eff)
        else:
            mlp = 3 * d * dff if self.mlp_kind == "swiglu" else 2 * d * dff
        # attention-kind layers carry the mlp too (parallel structure:
        # every non-ssm/rglru layer = attn + mlp).
        for k in (ATTN, LOCAL_ATTN, CROSS_ATTN):
            counts[k] += mlp
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            counts[MAMBA2] = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                + conv_dim * s.conv_width + conv_dim                    # conv1d + bias
                + 3 * nheads                                            # A_log, dt_bias, D
                + d_in                                                  # gated norm
                + d_in * d + d                                          # out_proj + norm
            )
        if self.rglru is not None:
            w = self.rglru.lru_width or d
            counts[RGLRU] = (
                2 * d * w            # two input branches
                + w * self.rglru.conv_width + w   # temporal conv + bias
                + 2 * w * w // 1     # RG-LRU input/recurrence gates (diag-block)
                + 2 * w              # gate biases
                + w                  # Lambda
                + w * d              # out proj
                + d                  # pre-norm
            )
        total = v * d + d            # embed + final norm
        if not self.tie_embeddings:
            total += d * v
        layers = list(self.pattern) * self.n_super + list(self.tail_pattern)
        for k in layers:
            total += counts[k]
        return total


# ---------------------------------------------------------------------------
# Input shapes (the assigned 4-shape set).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# FL / cloud configuration (the paper's experiments).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """Per-client heterogeneity profile used by the simulator."""
    name: str
    mean_epoch_s: float            # warm per-epoch wall time
    cold_multiplier: float = 1.15  # first-epoch-on-fresh-instance slowdown
    jitter: float = 0.03           # lognormal sigma on epoch time
    budget: float = float("inf")   # USD
    n_samples: int = 1             # FedAvg weight
    zone: Optional[str] = None     # pinned zone, else cheapest
    provider: Optional[str] = None  # provider of the pinned zone
    join_round: int = 0            # elastic scaling: round the client joins


@dataclasses.dataclass(frozen=True)
class ProviderConfig:
    """One provider's market + billing parameters inside a
    `MarketConfig`. `price_trace` switches the provider's zones from the
    synthetic OU process to real recorded spot history (a CSV/JSONL file
    in AWS spot-price-history format, see `repro.cloud.traces`)."""
    name: str = "aws"
    on_demand_rate: float = 1.008
    spot_rate_mean: float = 0.3951
    spot_rate_sigma: float = 0.004
    n_zones: int = 4
    regions: Tuple[str, ...] = ("us-east-1", "us-east-2", "us-west-2",
                                "eu-west-1")
    billing_granularity_s: float = 1.0
    min_billing_s: float = 60.0
    preemption_notice_s: float = 0.0
    price_trace: Optional[str] = None
    # price-coupled preemption (cloud.preemption.PriceCoupledModel):
    # hazard multiplier slope vs the zone's mean price. 0 decouples the
    # provider's reclaim rate from its price level entirely.
    preemption_price_sensitivity: float = 1.0
    # recorded real interruption timestamps for this provider's zones
    # (cloud.preemption.ReplayInterruptionModel); a CSV/JSONL file in
    # the spot-history format minus the price column, sharing the
    # market epoch with `price_trace` (see `repro.cloud.traces`)
    interruption_trace: Optional[str] = None
    # object-storage rates (`repro.cloud.pricing.StorageRates`) billed
    # per warning-window checkpoint write: a flat PUT-request charge
    # plus per-MB egress of the model state
    # (`SchedulerConfig.warning_ckpt_size_mb`). Zero by default, so
    # checkpoint writes stay free unless a market opts in.
    storage_put_usd: float = 0.0
    storage_egress_usd_per_mb: float = 0.0
    # client-update egress rate (`repro.cloud.pricing.TransferRates`,
    # the comms subsystem): dollars per MB a client's model update
    # costs to leave this provider on its way to the aggregation
    # server. Zero by default — per-round transfer dollars only appear
    # when a market opts in, keeping every pre-comms total unchanged.
    update_egress_usd_per_mb: float = 0.0
    # uplink bandwidth (megabits/s) of this provider's instances toward
    # the aggregation server; client-update transfers occupy the client
    # for payload_bits / uplink for this long, extending the round
    # makespan inside both engines. <= 0 models an instantaneous
    # uplink (no makespan extension — the pre-comms behavior).
    uplink_mbps: float = 0.0
    # per-zone uplink overrides as ("zone-name", mbps) pairs; zones
    # absent here fall back to `uplink_mbps`
    zone_uplink_mbps: Tuple[Tuple[str, float], ...] = ()


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    """One adversarial market scenario applied on top of a
    `MarketConfig`'s base price processes (`repro.cloud.scenarios`).

    `name` selects the generator from the scenario registry
    ("flash_crash" | "capacity_crunch" | "diurnal" |
    "price_inversion"); every generator is fully seeded, so the same
    (market, scenario) pair always produces byte-identical traces and
    reclaim schedules. `strength` scales the stress (1.0 = the
    generator's documented default severity), `horizon_s`/`step_s` the
    shaped trace's extent and resolution, and `provider` flags which
    provider the scenario squeezes (capacity_crunch / price_inversion;
    None = the market's first provider)."""
    name: str
    seed: int = 0
    horizon_s: float = 48 * 3600.0
    step_s: float = 300.0
    strength: float = 1.0
    provider: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class MarketConfig:
    """The spot market a run executes against: one or more providers,
    each synthetic or trace-driven. Provider order is placement
    tie-break order (see `SpotMarket.cheapest_zone`). `scenario`
    optionally reshapes the built market through a seeded adversarial
    generator (`repro.cloud.scenarios`) — flash crashes, correlated
    capacity-crunch reclaims, diurnal cycles, cross-provider price
    inversions — registered by name so every benchmark can request a
    stress market by configuration alone."""
    providers: Tuple[ProviderConfig, ...] = (ProviderConfig(),)
    scenario: Optional[ScenarioConfig] = None


@dataclasses.dataclass(frozen=True)
class PopulationConfig:
    """A large client population described by distribution parameters
    instead of per-client `ClientProfile` objects (the cross-silo ->
    cross-device jump).

    The fleet core (`repro.cloud.fleet.ClientArrays`) expands this into
    contiguous numpy arrays in O(arrays) — constructing a 100k-client
    run never materializes 100k Python objects. Per-client warm epoch
    times are lognormal around `mean_epoch_s` with cross-client sigma
    `epoch_sigma` (0 makes the population homogeneous), drawn from
    `seed` so a population is reproducible independent of the run
    seed."""
    n_clients: int
    mean_epoch_s: float = 900.0
    epoch_sigma: float = 0.25      # cross-client lognormal spread
    cold_multiplier: float = 1.15
    jitter: float = 0.03           # per-epoch lognormal sigma (per run)
    budget: float = float("inf")   # USD, uniform across the population
    name_prefix: str = "c"         # client i is f"{name_prefix}{i}"
    seed: int = 0                  # population draw seed

    def __post_init__(self):
        if self.n_clients <= 0:
            raise ValueError("population needs n_clients >= 1")


@dataclasses.dataclass(frozen=True)
class CloudConfig:
    on_demand_rate: float = 1.008        # $/hr g5.xlarge (paper Table I)
    spot_rate_mean: float = 0.3951       # $/hr
    spot_rate_sigma: float = 0.004       # zone-to-zone / temporal wiggle
    n_zones: int = 4
    spin_up_mean_s: float = 150.0        # instance provisioning + boot
    spin_up_sigma: float = 0.10
    preemption_rate_per_hr: float = 0.0  # paper observed none; configurable
    # which `repro.cloud.preemption.PreemptionModel` reclaims spot
    # instances: "constant" (flat Poisson at `preemption_rate_per_hr`,
    # bit-identical to the pre-model behavior), "price_coupled" (hazard
    # scales with the zone's current spot price level), "replay"
    # (recorded interruption timestamps from the providers'
    # `interruption_trace` files), or "correlated" (constant-rate
    # background churn plus the market's scheduled reclaims — e.g. the
    # `capacity_crunch` scenario's provider-wide correlated hits)
    preemption_model: str = "constant"
    # sensitivity of the legacy single-provider synthetic market under
    # the price-coupled model (multi-provider markets carry it per
    # provider in `ProviderConfig.preemption_price_sensitivity`)
    preemption_price_sensitivity: float = 1.0
    billing_granularity_s: float = 1.0   # per-second billing
    min_billing_s: float = 60.0          # AWS bills min 60s for spot
    # explicit multi-provider / trace-driven market; None keeps the
    # legacy single-provider synthetic market built from the scalar
    # fields above (bit-identical to the pre-SpotMarket behavior)
    market: Optional[MarketConfig] = None
    # fleets at or above this many clients switch from the per-object
    # simulator hot path (one heap callback per instance, per-instance
    # events — bit-identical to every pre-fleet release) to the
    # struct-of-arrays fleet core (`repro.cloud.fleet`), which batches
    # spin-ups, billing and preemption draws per round and publishes
    # aggregate `FleetStepSummary` events instead of the per-instance
    # vocabulary. `FLRunConfig.fleet` overrides the switch per run.
    fleet_threshold: int = 512


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """FedCostAware knobs (paper §III)."""
    ema_alpha: float = 0.3          # EMA weight on the newest observation
    t_threshold_s: float = 120.0    # min net idle saving to justify a stop
    t_buffer_s: float = 45.0        # pre-warm safety buffer
    calibration_rounds: int = 2     # round1=cold, round2=warm
    checkpoint_every_s: float = 60.0
    # wall time a preemption-notice-triggered checkpoint takes to write
    # to cloud storage; the snapshot only lands if the provider's
    # warning window (`Provider.preemption_notice_s`) is at least this
    # long, else the engine falls back to periodic-checkpoint (lost
    # work) semantics
    warning_ckpt_write_s: float = 10.0
    # model-state megabytes one warning-window checkpoint writes — what
    # the provider's `StorageRates` (S3 PUT + per-MB egress) bill; the
    # default rates are zero, so this only costs dollars once a
    # provider sets non-zero storage rates
    warning_ckpt_size_mb: float = 64.0


@dataclasses.dataclass(frozen=True)
class FLRunConfig:
    dataset: str
    clients: Tuple[ClientProfile, ...]
    n_epochs: int                   # global FL rounds (1 local epoch each)
    # on_demand | spot | fedcostaware | fedcostaware_async
    policy: str = "fedcostaware"
    algorithm: str = "fedavg"       # fedavg | fedprox | fedavgm
    fedprox_mu: float = 0.01
    server_momentum: float = 0.9
    local_steps: Optional[int] = None  # mesh-FL: steps per round
    # async (FedBuff-style) engines: aggregate once `buffer_k` client
    # results arrive; None -> n_clients - 1 (wait for all but the
    # slowest). Ignored by the synchronous engine.
    buffer_k: Optional[int] = None
    # None -> the policy's own cross_provider default; True/False
    # overrides whether cheapest-zone placement may arbitrate across
    # every provider in the market or stays on the default provider
    cross_provider: Optional[bool] = None
    # None -> the policy's own round engine ("sync" unless the policy
    # says otherwise, e.g. fedcostaware_async); "sync" |
    # "async_buffered" overrides it. Resolved before the fleet-path
    # decision, so forcing async on a fleet-capable policy falls back
    # to the per-object engines.
    engine: Optional[str] = None
    # None -> the policy's own on_warning default; "ignore" | "drain" |
    # "checkpoint" overrides how the run reacts to a provider's
    # preemption-notice warning (see `repro.core.strategy`). The
    # override flows through the policy knob, so a composition whose
    # `WarningReactionSpec` pins an explicit mode keeps that mode.
    on_warning: Optional[str] = None
    # publish a `DirectiveIssued` event for every strategy directive
    # the DirectiveExecutor applies (observability; off by default so
    # recorded streams and golden traces stay unchanged)
    trace_directives: bool = False
    # cross-device cohort mode (fleet core): a large client population
    # described by distribution parameters instead of `clients`
    # profiles; each round samples `cohort_size` participants from it.
    # Setting `population` requires `clients == ()` and engages the
    # vectorized fleet path regardless of `fleet_threshold`.
    population: Optional[PopulationConfig] = None
    # participants sampled (without replacement, seeded) per round from
    # the population — None means every active client trains each round
    cohort_size: Optional[int] = None
    # fleet-path switch: None auto-selects (population set, or at least
    # `CloudConfig.fleet_threshold` clients on a sync-engine policy);
    # True forces the vectorized core even for tiny runs (equivalence
    # tests); False forces the per-object path at any scale
    fleet: Optional[bool] = None
    # communication-cost modeling (`repro.comms`): the per-update
    # payload each client uploads after local training, in MB of fp32
    # state. None disables the comms subsystem entirely (no
    # ClientUpdateSent events, no transfer billing, no makespan
    # extension — byte-identical to pre-comms streams). When trainer
    # hooks expose a real param pytree (`TrainerHooks.update_payload`),
    # that measured payload wins over this modeled value.
    update_payload_mb: Optional[float] = None
    # quantize client updates through the `grad_quant` int8 codec:
    # payload bytes follow the kernel's exact (block + scale) layout
    # (~4x smaller egress), and hooks that train for real
    # (`repro.fl.training.MeshTrainerHooks`) round-trip every update
    # through quantize/dequantize before aggregation
    quantize_updates: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.population is not None and self.clients:
            raise ValueError(
                "FLRunConfig: pass either explicit `clients` profiles "
                "or a `population`, not both")
        if self.cohort_size is not None:
            n = (self.population.n_clients if self.population is not None
                 else len(self.clients))
            if not 0 < self.cohort_size <= n:
                raise ValueError(
                    f"cohort_size must be in [1, {n}], "
                    f"got {self.cohort_size}")
