"""Optimizers (pure JAX, pytree-based): AdamW, SGD(+momentum), schedules,
global-norm clipping. Optimizer state is kept in fp32 regardless of param
dtype (bf16 params update through an fp32 math path and cast back).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: object          # first moment  (or momentum buffer for sgd)
    nu: object          # second moment (None-like zeros for sgd)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable    # (grads, state, params) -> (new_params, new_state)


def _f32_like(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(base_lr, warmup, total):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_schedule(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
          clip_norm: Optional[float] = 1.0, schedule=None) -> Optimizer:
    sched = schedule or constant_schedule(lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _f32_like(params),
                        _f32_like(params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = sched(step)
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr_t * delta
            return new_p.astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step, new_m, new_v)

    return Optimizer(init, update)


def sgd(lr=0.01, momentum=0.9, clip_norm: Optional[float] = None,
        schedule=None) -> Optimizer:
    sched = schedule or constant_schedule(lr)

    def init(params):
        return OptState(jnp.zeros((), jnp.int32), _f32_like(params),
                        jnp.zeros((), jnp.float32))

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        lr_t = sched(step)

        def upd(p, g, m):
            g = g.astype(jnp.float32)
            m = momentum * m + g
            new_p = p.astype(jnp.float32) - lr_t * m
            return new_p.astype(p.dtype), m

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (treedef.unflatten([o[0] for o in out]),
                OptState(step, treedef.unflatten([o[1] for o in out]),
                         state.nu))

    return Optimizer(init, update)


def get(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "sgd":
        return sgd(**kw)
    raise ValueError(name)
