"""dbrx-132b [moe] — 16 experts top-4, fine-grained.
[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H kv=8 d_ff=10752(per-expert) vocab=100352.
"""
from repro.common.config import ModelConfig, MoEConfig, ATTN

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=0, vocab_size=100352,
    pattern=(ATTN,), mlp_kind="swiglu",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff=10752,
                  capacity_factor=1.25, group_size=512),
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=0, vocab_size=128,
    pattern=(ATTN,), mlp_kind="swiglu",
    # capacity_factor = E/top_k -> capacity == group tokens: no
    # drops, so cached decode reproduces teacher-forced forward
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, group_size=32,
                  capacity_factor=2.0),
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
