"""command-r-35b [dense] — GQA kv=8, no biases.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
40L d_model=8192 64H kv=8 d_ff=22528 vocab=256000.
"""
from repro.common.config import ModelConfig, ATTN

FULL = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    pattern=(ATTN,), mlp_kind="swiglu", qkv_bias=False,
    grad_accum=2,
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=128, vocab_size=128,
    pattern=(ATTN,), mlp_kind="swiglu",
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
