"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 ratio.
[arXiv:2402.19427; hf]  26L d_model=2560 10H kv=1(MQA) d_ff=7680 vocab=256000.
Pattern (R,R,A)x8 + (R,R) tail = 26 layers; sliding window 2048.
"""
from repro.common.config import ModelConfig, RGLRUConfig, RGLRU, LOCAL_ATTN

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    pattern=(RGLRU, RGLRU, LOCAL_ATTN), window_size=2048,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    mlp_kind="gelu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=128, head_dim=16,
    pattern=(RGLRU, RGLRU, LOCAL_ATTN), window_size=8,
    rglru=RGLRUConfig(lru_width=64, conv_width=4),
    mlp_kind="gelu", tie_embeddings=True,
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
