"""glm4-9b [dense] — RoPE, aggressive GQA (kv=2).
[hf:THUDM/glm-4-9b; hf]  40L d_model=4096 32H kv=2 d_ff=13696 vocab=151552.
"""
from repro.common.config import ModelConfig, ATTN

FULL = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552,
    pattern=(ATTN,), mlp_kind="swiglu", rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="glm4-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=128, vocab_size=128,
    pattern=(ATTN,), mlp_kind="swiglu",
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
