"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]  48L d_model=1536 24H kv=24(MHA) d_ff=6144 vocab=2048.
The EnCodec frontend is a stub: input_specs() provides precomputed frame
embeddings (B,S,d_model); the LM head predicts the 2048-way codebook.
"""
from repro.common.config import ModelConfig, ATTN

FULL = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048,
    pattern=(ATTN,), mlp_kind="gelu",
)

SMOKE = ModelConfig(
    name="musicgen-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=64,
    pattern=(ATTN,), mlp_kind="gelu",
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
