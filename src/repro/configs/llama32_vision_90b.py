"""llama-3.2-vision-90b [vlm] — cross-attention image layers every 5th.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
100L d_model=8192 64H kv=8 d_ff=28672 vocab=128256.
Vision frontend is a stub: input_specs() supplies precomputed patch
embeddings (n_cond_tokens x d_model) consumed by the cross-attn layers.
"""
from repro.common.config import ModelConfig, ATTN, CROSS_ATTN

FULL = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    pattern=(ATTN, ATTN, ATTN, ATTN, CROSS_ATTN),
    n_cond_tokens=6400,   # 4 tiles x 1600 patches
    mlp_kind="swiglu",
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    num_layers=5, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=128, vocab_size=128,
    pattern=(ATTN, ATTN, ATTN, ATTN, CROSS_ATTN), n_cond_tokens=8,
    mlp_kind="swiglu",
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
