"""granite-moe-3b-a800m [moe] — fine-grained MoE, 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H kv=8 d_ff=512(per-expert) vocab=49155.
Small experts => GShard dispatch overhead matters; group_size=128 keeps
the dispatch einsum <10% of expert FLOPs (see DESIGN.md).
"""
from repro.common.config import ModelConfig, MoEConfig, ATTN

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=0, vocab_size=49155,
    pattern=(ATTN,), mlp_kind="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8, d_ff=512, capacity_factor=1.25,
                  group_size=128),
    # 40 experts do not divide the 16-way model axis; the shape-aware rule
    # resolver drops the expert mapping automatically, and `mlp` stays on
    # `model` -> intra-expert TP (noted in DESIGN.md §Arch-applicability).
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=0, vocab_size=128,
    pattern=(ATTN,), mlp_kind="swiglu",
    # capacity_factor = E/top_k -> capacity == group tokens: no
    # drops, so cached decode reproduces teacher-forced forward
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, group_size=32,
                  capacity_factor=2.0),
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
