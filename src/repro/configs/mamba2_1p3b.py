"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128.
"""
from repro.common.config import ModelConfig, SSMConfig, MAMBA2

FULL = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    pattern=(MAMBA2,),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk_size=256),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=128,
    pattern=(MAMBA2,),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                  n_groups=1, chunk_size=8),
    tie_embeddings=True, dtype="float32", param_dtype="float32", remat=False,
    attn_chunk=8,
)
