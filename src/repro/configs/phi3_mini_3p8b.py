"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA(kv=32 -> MHA).
[arXiv:2404.14219; unverified]  32L d_model=3072 32H d_ff=8192 vocab=32064.
"""
from repro.common.config import ModelConfig, ATTN

FULL = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    pattern=(ATTN,), mlp_kind="swiglu", rope_theta=10_000.0,
    # §Perf hillclimb #1: a 3.8B model on 256 chips is collective-bound
    # under TP16+SP (peak fraction 0.096); pure ZeRO-3/FSDP (batch over
    # all 256 devices, weights gathered per layer) is 8.4x cheaper on
    # collectives -> peak fraction 0.75. remat stays ON (refuted attempt:
    # remat=False -> 203GB temp, attention internals unsharded under FSDP).
    sharding_overrides=(
        ("batch", ("pod", "data", "model")),
        ("embed", ("data", "model")),
        ("heads", None), ("kv_heads", None), ("mlp", None),
        ("vocab", None), ("seq", None),
    ),
)

SMOKE = ModelConfig(
    name="phi3-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=128,
    pattern=(ATTN,), mlp_kind="swiglu",
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
