"""qwen1.5-110b [dense] — GQA kv=8 with QKV bias.
[hf:Qwen/Qwen1.5-0.5B; hf]  80L d_model=8192 64H kv=8 d_ff=49152 vocab=152064.
"""
from repro.common.config import ModelConfig, ATTN

FULL = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064,
    pattern=(ATTN,), mlp_kind="swiglu", qkv_bias=True,
    grad_accum=4,
)

SMOKE = ModelConfig(
    name="qwen-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
    d_ff=128, vocab_size=128,
    pattern=(ATTN,), mlp_kind="swiglu", qkv_bias=True,
    dtype="float32", param_dtype="float32", remat=False, attn_chunk=8,
)
