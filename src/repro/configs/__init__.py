"""Architecture registry: ``--arch <id>`` resolves here.

Each module exposes FULL (the published config, exercised only via the
AOT dry-run) and SMOKE (a reduced same-family config that trains a real
step on CPU in the tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ModelConfig, SHAPES, ShapeConfig

_MODULES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "glm4-9b": "glm4_9b",
    "command-r-35b": "command_r_35b",
    "qwen1.5-110b": "qwen1p5_110b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "dbrx-132b": "dbrx_132b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.FULL


def applicable_shapes(arch: str) -> List[str]:
    """The assigned shape set, minus rule-based skips (DESIGN.md par.4):
    long_500k only for sub-quadratic (SSM / hybrid) architectures."""
    cfg = get_config(arch)
    out = []
    for name, sh in SHAPES.items():
        if name == "long_500k" and not cfg.is_subquadratic:
            continue
        out.append(name)
    return out


def all_cells():
    """Every (arch, shape) dry-run cell after rule-based skips."""
    return [(a, s) for a in ARCH_IDS for s in applicable_shapes(a)]


def skipped_cells():
    return [(a, s) for a in ARCH_IDS for s in SHAPES
            if s not in applicable_shapes(a)]
