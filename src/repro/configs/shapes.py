"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device memory is allocated: the dry-run lowers and compiles against
these abstract values only.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, SHAPES, ShapeConfig
from repro.models import lm


def _tok_struct(cfg: ModelConfig, batch: int, seq: int):
    if cfg.family == "audio":
        # EnCodec frontend stub: precomputed frame embeddings
        return jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                    cfg.activation_dtype)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Returns the kwargs pytree for the step function of `shape.kind`."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _tok_struct(cfg, B, S),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["cond"] = jax.ShapeDtypeStruct(
                (B, cfg.n_cond_tokens, cfg.d_model), cfg.activation_dtype)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": _tok_struct(cfg, B, S)}
        if cfg.family == "vlm":
            out["cond"] = jax.ShapeDtypeStruct(
                (B, cfg.n_cond_tokens, cfg.d_model), cfg.activation_dtype)
        return out
    if shape.kind == "decode":
        return {
            "tokens": _tok_struct(cfg, B, 1),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
            "cache": lm.abstract_cache(cfg, B, S),
        }
    raise ValueError(shape.kind)
