"""The explicit cost-of-error decision rule: probabilities -> dollars
-> directives.

A forecast only earns its keep through the asymmetric costs of acting
on it. For a predicted interruption probability `p` over the decision
horizon, priced at the client's live spot rate:

  pre-warm a standby   costs the standby's expected *wasted* runtime,
                       `(1 - p) * horizon * rate` (when the reclaim
                       does land, the standby is promoted and its
                       seconds are not wasted). Skipping it risks
                       `p * (spin_up * stall_weight + lost_work) *
                       rate`: the replacement's cold boot stalls not
                       just the victim but every peer idling at the
                       sync barrier (`stall_weight` ~ the number of
                       stalled clients), plus the lost work since the
                       last durable snapshot.
  checkpoint now       costs `ckpt_usd`: the storage write (the
                       provider's `StorageRates`) plus the write
                       window's paid instance seconds, priced by the
                       caller. Skipping it risks `p * unsnapshotted *
                       rate` of redone work, so snapshots naturally
                       densify as the hazard rises — an adaptive
                       checkpoint cadence.
  drain                only when doom is near-certain
                       (`p >= drain_threshold`) *and* a fresh snapshot
                       makes the vacate lossless — draining on a false
                       alarm throws away a healthy instance, so the
                       rule is deliberately conservative.

`decide` is a pure function of its arguments (no hidden state, no
market access) so the rule itself is unit-testable in isolation and
every threshold is explicit in one place. Hysteresis: an active
standby is only released once the expected loss falls below
`prewarm_hysteresis` times the standby cost, preventing flapping at
the decision boundary.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DecisionConfig:
    """Knobs of the cost-of-error rule (module docstring)."""
    horizon_s: float = 600.0          # decision/forecast horizon
    stall_weight: float = 3.0         # peers stalled per cold respin
    prewarm_hysteresis: float = 0.5   # release below this x standby cost
    drain_threshold: float = 0.95     # p floor for vacating an instance


@dataclasses.dataclass(frozen=True)
class Decision:
    """One evaluated decision: the chosen actions plus the dollar
    quantities that chose them (recorded for auditability)."""
    prewarm: bool
    release: bool
    checkpoint: bool
    drain: bool
    expected_loss_usd: float          # cost of *not* acting
    standby_usd: float                # expected wasted standby dollars

    @property
    def action(self) -> str:
        """Compressed label for telemetry: the strongest action."""
        if self.drain:
            return "drain"
        if self.checkpoint and self.prewarm:
            return "prewarm+checkpoint"
        if self.checkpoint:
            return "checkpoint"
        if self.prewarm:
            return "prewarm"
        if self.release:
            return "release"
        return "hold"


def decide(p: float, spot_rate_hr: float, spin_up_s: float,
           lost_work_s: float, unsnapshotted_s: float,
           ckpt_usd: float, standby_active: bool,
           have_fresh_snapshot: bool,
           cfg: DecisionConfig = DecisionConfig()) -> Decision:
    """Evaluate the cost-of-error rule for one client.

    `p` is the forecast interruption probability within
    `cfg.horizon_s`; `spot_rate_hr` the client's live spot price;
    `spin_up_s` the expected replacement boot time; `lost_work_s` the
    training seconds a reclaim would force the client to redo;
    `unsnapshotted_s` the portion of that not yet covered by any
    durable snapshot; `ckpt_usd` the all-in cost of writing a snapshot
    now (storage dollars + the write window's instance seconds).
    `have_fresh_snapshot` gates the drain arm only — checkpointing
    re-fires as `unsnapshotted_s` grows back after each write.
    """
    p = min(max(p, 0.0), 1.0)
    rate_s = spot_rate_hr / 3600.0
    expected_loss = p * (spin_up_s * cfg.stall_weight
                         + lost_work_s) * rate_s
    standby = (1.0 - p) * cfg.horizon_s * rate_s
    prewarm = not standby_active and expected_loss > standby
    release = (standby_active
               and expected_loss < cfg.prewarm_hysteresis * standby)
    checkpoint = (unsnapshotted_s > 0.0
                  and p * unsnapshotted_s * rate_s > ckpt_usd)
    drain = p >= cfg.drain_threshold and have_fresh_snapshot
    return Decision(prewarm=prewarm, release=release,
                    checkpoint=checkpoint, drain=drain,
                    expected_loss_usd=expected_loss,
                    standby_usd=standby)
