"""The tenant-observable market surface forecasters learn from.

A real spot tenant sees exactly two things: the published price
history of the zones it runs in, and the reclaims (plus provider
notices) that hit its own instances. `ObservableFeed` packages those
two signals — and nothing else — behind one object:

  * it subscribes to `InstancePreempted` / `InstancePreemptionWarning`
    on the run's bus and forwards spot reclaim observations to every
    attached observer (forecasters, calibration trackers);
  * `sample_price` reads a zone's current spot price through the
    market callables and forwards the sample, deduplicated per
    (provider, zone, time) so co-located clients polling in the same
    tick don't double-count market exposure;
  * `price_derived_hazard` reproduces the price-coupled hazard
    formula (`repro.cloud.preemption.PriceCoupledModel`) from the
    observable quantities alone — the estimate the runner's replay
    fallback (`fl.runner._observable_hazard_of`) now routes through,
    making "oracle" vs "observable" an explicit property of every
    recorded trace instead of a silent substitution.

Layering: depends on `core.events` and the stdlib only. Market access
arrives as plain callables (`for_market` builds them over any
duck-typed market object without importing `cloud.*`).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.events import (EventBus, InstancePreempted,
                               InstancePreemptionWarning)


class ObservableFeed:
    """Subscription hub for tenant-observable market signals.

    `spot_price_of(provider, zone, t)` and
    `mean_price_of(provider, zone)` read the published price surface;
    `sensitivity_of(provider)` is the provider's advertised
    hazard-vs-price slope (`preemption_price_sensitivity`) and
    `base_rate_per_hr` the tenant's prior reclaim rate — the same two
    knobs a real scheduler calibrates its interruption estimate with.
    """

    def __init__(self,
                 spot_price_of: Callable[[str, str, float], float],
                 mean_price_of: Callable[[str, str], float],
                 sensitivity_of: Callable[[str], float],
                 base_rate_per_hr: float = 0.0,
                 bus: Optional[EventBus] = None):
        self.spot_price_of = spot_price_of
        self.mean_price_of = mean_price_of
        self.sensitivity_of = sensitivity_of
        self.base_rate_per_hr = base_rate_per_hr
        self._observers: List[Any] = []
        self._ref_price: Dict[Tuple[str, str], float] = {}
        self._last_sample_t: Dict[Tuple[str, str], float] = {}
        self.n_reclaims_seen = 0
        self.n_warnings_seen = 0
        if bus is not None:
            bus.subscribe(InstancePreempted, self._on_preempted)
            bus.subscribe(InstancePreemptionWarning, self._on_warning)

    @classmethod
    def for_market(cls, market: Any, base_rate_per_hr: float,
                   bus: Optional[EventBus] = None) -> "ObservableFeed":
        """Build a feed over a duck-typed `SpotMarket`-shaped object
        (the composition root passes the live market; tests may pass
        any object with `spot_price` / `mean_spot_price` /
        `provider_of`)."""
        return cls(
            spot_price_of=lambda p, z, t: market.spot_price(z, t, p),
            mean_price_of=lambda p, z: market.mean_spot_price(z, p),
            sensitivity_of=lambda p: (
                market.provider_of(p).preemption_price_sensitivity),
            base_rate_per_hr=base_rate_per_hr, bus=bus)

    # ------------------------------------------------------------------
    # Observer fan-out.
    # ------------------------------------------------------------------
    def attach(self, observer: Any) -> Any:
        """Register an observer; anything with `observe_price(provider,
        zone, t, price)` and/or `observe_reclaim(provider, zone, t)`
        (forecasters, calibration trackers) qualifies."""
        self._observers.append(observer)
        return observer

    def _on_preempted(self, ev: InstancePreempted) -> None:
        """A spot reclaim landed on one of the tenant's instances:
        forward the observation. On-demand terminations never reach
        this handler (the simulator only reclaims spot)."""
        inst = ev.instance
        if getattr(inst, "on_demand", False):
            return
        self.n_reclaims_seen += 1
        for obs in self._observers:
            hook = getattr(obs, "observe_reclaim", None)
            if hook is not None:
                hook(inst.provider, inst.zone, ev.t)

    def _on_warning(self, ev: InstancePreemptionWarning) -> None:
        """A provider reclaim notice arrived; counted for telemetry
        but *not* forwarded as a reclaim — the reclaim itself follows
        and forwarding both would double-count the event."""
        self.n_warnings_seen += 1

    def sample_price(self, provider: str, zone: str, t: float) -> float:
        """Read the zone's spot price at `t` and forward the sample to
        every observer. Repeat samples of the same (provider, zone) at
        a non-advancing time are read but not re-forwarded, so several
        co-located clients polling in one tick count the market
        exposure once."""
        price = self.spot_price_of(provider, zone, t)
        key = (provider, zone)
        last = self._last_sample_t.get(key)
        if last is not None and t <= last:
            return price
        self._last_sample_t[key] = t
        for obs in self._observers:
            hook = getattr(obs, "observe_price", None)
            if hook is not None:
                hook(provider, zone, t, price)
        return price

    # ------------------------------------------------------------------
    # The price-derived hazard estimate (replay-fallback signal).
    # ------------------------------------------------------------------
    def _ref(self, provider: str, zone: str) -> float:
        """Cached per-zone reference (historical mean) price."""
        key = (provider, zone)
        if key not in self._ref_price:
            self._ref_price[key] = self.mean_price_of(provider, zone)
        return self._ref_price[key]

    def price_derived_hazard(self, provider: str, zone: str,
                             t: float) -> float:
        """Instantaneous reclaim-hazard estimate (events/second) from
        the observable price level alone: the price-coupled formula
        `base * max(0, 1 + s * (p/p_ref - 1))` evaluated on published
        prices — numerically identical to
        `PriceCoupledModel.hazard`, but computed without touching the
        model (which, under recorded-interruption replay, does not
        even exist)."""
        base = self.base_rate_per_hr / 3600.0
        if base <= 0.0:
            return 0.0
        s = self.sensitivity_of(provider)
        level = self.spot_price_of(provider, zone, t) / self._ref(
            provider, zone)
        return base * max(1.0 + s * (level - 1.0), 0.0)
