"""Online calibration metrics for interruption/price forecasts.

A forecast is only worth dollars if its probabilities mean what they
say. `CalibrationTracker` scores two things, both computed online as
the run unfolds (no post-hoc pass):

  Brier score      every `note_prediction(zone, t, p)` opens a pending
                   "will a reclaim hit this zone within `horizon_s`?"
                   question; an observed reclaim before the deadline
                   resolves it with outcome 1, deadline expiry (driven
                   by `advance(t)`) resolves it with outcome 0. The
                   score is the running mean of `(p - outcome)^2` —
                   0 is clairvoyant, 0.25 is the uninformative p=0.5.
  band coverage    every `note_band(zone, t, lo, hi)` records the
                   forecaster's current price band; the *next* price
                   sample for the zone checks whether the realized
                   price fell inside it. Empirical coverage should
                   match the nominal band mass (e.g. a (0.1, 0.9)
                   band should cover ~80% of samples).

Both metrics answer -1.0 before their first resolution, which
`ForecastUpdated` telemetry records as "not yet measurable". The
pending-prediction set is bounded by construction: one deadline per
note, expired entries drop at every `advance`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class _Pending:
    """One open interruption-within-horizon question."""
    zone: Tuple[str, str]
    deadline: float
    p: float


class CalibrationTracker:
    """Online Brier score + quantile-band coverage (module docstring)."""

    def __init__(self, horizon_s: float = 600.0):
        self.horizon_s = horizon_s
        self._pending: List[_Pending] = []
        self._brier_sum = 0.0
        self._brier_n = 0
        self._band: Dict[Tuple[str, str], Tuple[float, float]] = {}
        self._band_hits = 0
        self._band_n = 0

    # ------------------------------------------------------------------
    # Interruption-probability scoring (Brier).
    # ------------------------------------------------------------------
    def note_prediction(self, provider: str, zone: str, t: float,
                        p: float) -> None:
        """Open a question: P(reclaim in this zone before
        `t + horizon_s`) was forecast as `p`."""
        self._pending.append(_Pending((provider, zone),
                                      t + self.horizon_s, p))

    def observe_reclaim(self, provider: str, zone: str,
                        t: float) -> None:
        """A reclaim landed: every open question for the zone whose
        deadline has not passed resolves with outcome 1."""
        key = (provider, zone)
        still_open: List[_Pending] = []
        for q in self._pending:
            if q.zone == key and q.deadline >= t:
                self._brier_sum += (q.p - 1.0) ** 2
                self._brier_n += 1
            else:
                still_open.append(q)
        self._pending = still_open

    def advance(self, t: float) -> None:
        """Time moved to `t`: questions whose deadline passed without
        a reclaim resolve with outcome 0."""
        still_open: List[_Pending] = []
        for q in self._pending:
            if q.deadline < t:
                self._brier_sum += q.p ** 2
                self._brier_n += 1
            else:
                still_open.append(q)
        self._pending = still_open

    def brier(self) -> float:
        """Running mean Brier score; -1.0 before any resolution."""
        if self._brier_n == 0:
            return -1.0
        return self._brier_sum / self._brier_n

    # ------------------------------------------------------------------
    # Quantile-band coverage.
    # ------------------------------------------------------------------
    def note_band(self, provider: str, zone: str,
                  lo: float, hi: float) -> None:
        """Record the forecaster's current price band for the zone;
        the next observed price sample scores it."""
        self._band[(provider, zone)] = (lo, hi)

    def observe_price(self, provider: str, zone: str, t: float,
                      price: float) -> None:
        """Score the previously noted band (if any) against the
        realized price, then retire it."""
        band = self._band.pop((provider, zone), None)
        if band is None:
            return
        lo, hi = band
        self._band_hits += 1 if lo <= price <= hi else 0
        self._band_n += 1

    def coverage(self) -> float:
        """Empirical band coverage in [0, 1]; -1.0 before any scored
        band."""
        if self._band_n == 0:
            return -1.0
        return self._band_hits / self._band_n

    # ------------------------------------------------------------------
    def n_resolved(self) -> int:
        """How many interruption questions have resolved so far."""
        return self._brier_n
