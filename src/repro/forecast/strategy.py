"""`LearnedForecastStrategy`: learned predictions -> directives.

The composition the whole package exists for: a `SchedulingStrategy`
(`repro.core.strategy` API — zero engine or cloud edits) that

  1. samples the tenant-observable market surface through the run's
     `ObservableFeed` (`ctx.feed`, wired by the composition root),
  2. keeps an online `Forecaster` and a `CalibrationTracker` fed from
     those observations,
  3. converts the predicted interruption probability into PreWarm /
     Checkpoint / Drain directives via the explicit cost-of-error rule
     (`repro.forecast.decision`), priced from the live spot rate and
     the provider's storage rates, and
  4. publishes one `ForecastUpdated` telemetry event per poll per
     tracked training spot client (eventlog schema v8) carrying the
     prediction, the learned price band, and the running calibration
     metrics — the raw material `benchmarks/forecast_quality.py` maps
     from calibration to dollars.

Unlike `ForecastPrewarmStrategy(oracle=True)` this strategy never
touches the preemption model: every input is something a real tenant
could read off its own bus. The checkpoint/drain arms mirror the
guard discipline of `core.strategy.WarningReaction` (stale-instance /
stale-epoch checks around the asynchronous snapshot write), but fire
on *predicted* doom rather than a provider notice.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.checkpoint import snapshots
from repro.core.events import ForecastUpdated
from repro.core.policies import Policy, register_policy
from repro.core.strategy import (Checkpoint, Directive, Drain,
                                 SchedulingStrategy, SpinUp,
                                 StrategyContext, StrategySpec,
                                 Terminate)
from repro.forecast.calibration import CalibrationTracker
from repro.forecast.decision import Decision, DecisionConfig, decide
from repro.forecast.predictors import Forecaster, make_forecaster

# instance-state literal shared with repro.cloud.simulator.RUNNING
_RUNNING = "running"


@dataclasses.dataclass(frozen=True)
class LearnedForecastSpec(StrategySpec):
    """Declarative spec of a `LearnedForecastStrategy`.

    `forecaster` picks the predictor ("quantile" or "ewma");
    `prior_rate_per_hr` seeds both predictors' hazard prior (a real
    tenant's base interruption-rate assumption). The decision knobs
    mirror `DecisionConfig`; the learning knobs (`lr`,
    `spike_margin`, `prior_weight`, `ewma_alpha`) reach the chosen
    predictor. `miscalibrate=True` builds the deliberately wrong
    quantile forecaster (regime hazards swapped at query time) used to
    demonstrate that bad calibration loses money."""
    forecaster: str = "quantile"
    horizon_s: float = 600.0
    poll_s: float = 30.0
    prior_rate_per_hr: float = 1.0
    stall_weight: float = 3.0
    prewarm_hysteresis: float = 0.5
    drain_threshold: float = 0.95
    lr: float = 0.05
    spike_margin: float = 0.15
    prior_weight: float = 1.0
    ewma_alpha: float = 0.3
    miscalibrate: bool = False
    seed: int = 0

    def build(self, policy) -> "SchedulingStrategy":
        """A `LearnedForecastStrategy` configured by this spec."""
        return LearnedForecastStrategy(self)

    def make_forecaster(self) -> Forecaster:
        """The configured online predictor instance."""
        if self.forecaster == "ewma":
            return make_forecaster(
                "ewma", base_rate_per_hr=self.prior_rate_per_hr,
                alpha=self.ewma_alpha, seed=self.seed)
        return make_forecaster(
            "quantile", lr=self.lr, spike_margin=self.spike_margin,
            base_rate_per_hr=self.prior_rate_per_hr,
            prior_weight=self.prior_weight,
            miscalibrate=self.miscalibrate, seed=self.seed)


class LearnedForecastStrategy(SchedulingStrategy):
    """Forecast-driven scheduling from observable signals only
    (module docstring)."""

    def __init__(self, spec: LearnedForecastSpec):
        self.spec = spec
        self.predictor = spec.make_forecaster()
        self.calibration = CalibrationTracker(spec.horizon_s)
        self.decision_cfg = DecisionConfig(
            horizon_s=spec.horizon_s, stall_weight=spec.stall_weight,
            prewarm_hysteresis=spec.prewarm_hysteresis,
            drain_threshold=spec.drain_threshold)
        self._snap: Dict[str, dict] = {}   # client -> durable snapshot
        self._writing: set = set()         # clients mid snapshot-write

    def bind(self, ctx: StrategyContext) -> None:
        """Attach the predictor + calibration to the run's observable
        feed and start the poll loop. Requires `ctx.feed` (the
        composition root's `ObservableFeed`)."""
        super().bind(ctx)
        if ctx.feed is None:
            raise ValueError(
                "LearnedForecastStrategy needs StrategyContext.feed "
                "(an ObservableFeed); the per-object FLCloudRunner "
                "wires one — the fleet path does not support learned "
                "forecasting")
        ctx.feed.attach(self.predictor)
        ctx.feed.attach(self.calibration)
        ctx.schedule_in(self.spec.poll_s, self._tick)

    # ------------------------------------------------------------------
    # The poll loop.
    # ------------------------------------------------------------------
    def _tick(self) -> None:
        """One forecast sweep: sample every tracked zone's price,
        resolve expired calibration questions, then decide and act per
        client. Re-arms itself until the cluster shuts down."""
        ctx = self.ctx
        if ctx.is_shutdown():
            return
        now = ctx.now()
        # pass 1: feed the predictors every tracked zone's price before
        # any prediction is read, so co-located clients see one
        # consistent market snapshot per tick
        for c in ctx.clients:
            inst = ctx.instance_of(c)
            if (inst is not None and not inst.on_demand
                    and inst.state == _RUNNING):
                ctx.feed.sample_price(inst.provider, inst.zone, now)
        self.calibration.advance(now)
        # pass 2: decide per client
        directives: List[Directive] = []
        for c in ctx.clients:
            directives.extend(self._decide_client(c, now))
        if directives:
            ctx.executor.apply(directives)
        ctx.schedule_in(self.spec.poll_s, self._tick)

    def _decide_client(self, c: str, now: float) -> List[Directive]:
        """Evaluate the cost-of-error rule for one client and emit the
        resulting directives + `ForecastUpdated` telemetry."""
        ctx = self.ctx
        spec = self.spec
        inst = ctx.instance_of(c)
        standby = ctx.standby_of(c)
        tracked_spot = (inst is not None and not inst.on_demand
                        and inst.state == _RUNNING)
        training = ctx.view is not None and ctx.view.is_training(c)
        if not (tracked_spot and training):
            # nobody stalls on an idle/untracked client's reclaim: an
            # active standby for it is pure waste
            if standby is not None:
                return [Terminate(c, standby=True)]
            return []
        provider, zone = inst.provider, inst.zone
        p = self.predictor.interruption_probability(
            provider, zone, now, spec.horizon_s)
        hazard = self.predictor.hazard_per_hr(provider, zone, now)
        self.calibration.note_prediction(provider, zone, now, p)
        quants = self.predictor.price_quantiles(provider, zone)
        lo = mid = hi = 0.0
        if quants:
            lo, hi = quants[min(quants)], quants[max(quants)]
            mid = quants.get(0.5, (lo + hi) / 2.0)
            self.calibration.note_band(provider, zone, lo, hi)

        epoch_started = ctx.view.train_start(c)
        progress_s = now - epoch_started
        snap = self._snap.get(c)
        fresh_snap = (snap is not None
                      and snap.get("epoch_started") == epoch_started)
        snapped_s = snap["progress"] if fresh_snap else 0.0
        # durable floor: the periodic checkpoint cadence covers
        # progress up to the last multiple of checkpoint_every_s
        every = ctx.sched_cfg.checkpoint_every_s
        if every > 0.0:
            snapped_s = max(snapped_s, (progress_s // every) * every)
        unsnapshotted = max(progress_s - snapped_s, 0.0)

        rate_hr = ctx.spot_price_of(c)
        # all-in snapshot cost: storage dollars + the paid instance
        # seconds the write itself occupies
        ckpt_usd = (ctx.ckpt_cost_of(
            provider, ctx.sched_cfg.warning_ckpt_size_mb)
            + ctx.sched_cfg.warning_ckpt_write_s * rate_hr / 3600.0)
        d = decide(
            p=p, spot_rate_hr=rate_hr,
            spin_up_s=ctx.spin_up_default,
            lost_work_s=unsnapshotted, unsnapshotted_s=unsnapshotted,
            ckpt_usd=ckpt_usd,
            standby_active=standby is not None,
            have_fresh_snapshot=fresh_snap, cfg=self.decision_cfg)

        out: List[Directive] = []
        if d.prewarm:
            out.append(SpinUp(c))
        elif d.release and standby is not None:
            out.append(Terminate(c, standby=True))
        if d.checkpoint and c not in self._writing:
            self._start_snapshot(c, inst, now, epoch_started)
        if d.drain and fresh_snap:
            self._drain(c, snap)
        ctx.bus.publish(ForecastUpdated(
            now, client=c, provider=provider, zone=zone,
            forecaster=self.predictor.name, horizon_s=spec.horizon_s,
            p_interrupt=p, hazard_per_hr=hazard,
            price_lo=lo, price_mid=mid, price_hi=hi,
            brier=self.calibration.brier(),
            coverage=self.calibration.coverage(), action=d.action))
        return out

    # ------------------------------------------------------------------
    # Forecast-triggered checkpoint/drain (WarningReaction's guard
    # discipline, driven by prediction instead of a provider notice).
    # ------------------------------------------------------------------
    def _start_snapshot(self, c: str, inst, now: float,
                        epoch_started: float) -> None:
        """Kick off an asynchronous snapshot write for the client's
        current epoch; completion re-checks that the world did not
        move on during the write."""
        write_s = self.ctx.sched_cfg.warning_ckpt_write_s
        progress_s = now - epoch_started
        self._writing.add(c)
        self.ctx.schedule_in(write_s, lambda: self._complete(
            c, inst, progress_s, epoch_started))

    def _complete(self, c: str, inst, progress_s: float,
                  epoch_started: float) -> None:
        """The forecast-triggered snapshot finished writing: persist
        it via a `Checkpoint` directive. A no-op when the instance was
        replaced, the epoch finished, or a new epoch began during the
        write."""
        ctx = self.ctx
        self._writing.discard(c)
        view = ctx.view
        if view.is_done():
            return
        cur = ctx.instance_of(c)
        if cur is None or cur.iid != inst.iid or cur.state != _RUNNING:
            return
        if not view.is_training(c):
            return
        if view.train_start(c) != epoch_started:
            return
        r = view.current_round()
        remaining = max(view.train_duration(c) - progress_s, 1.0)
        payload = {"client": c, "round": r, "remaining": remaining,
                   "progress": progress_s, "t": ctx.now()}
        self._snap[c] = dict(payload, epoch_started=epoch_started)
        ctx.executor.apply([Checkpoint(
            c, round_idx=r, progress_s=progress_s,
            remaining_s=remaining,
            reclaim_at=ctx.now() + self.spec.horizon_s,
            payload=payload)])

    def _drain(self, c: str, snap: dict) -> None:
        """Predicted doom + durable snapshot: vacate the instance now
        and re-request the replacement with a resume token."""
        view = self.ctx.view
        remaining = float(snap["remaining"])
        r = int(snap["round"])
        view.note_lost_work(c, remaining)
        self._snap.pop(c, None)
        self.ctx.executor.apply([Drain(c, resume_token={
            "round": r, "remaining": remaining, "source": "forecast"})])
        view.after_drain(c, remaining)

    # ------------------------------------------------------------------
    def preemption_remaining(self, client: str, periodic_remaining: float
                             ) -> Optional[Tuple[float, str]]:
        """Offer the forecast-triggered snapshot when it preserves
        more than the periodic checkpoint."""
        snap = self._snap.pop(client, None)
        if snap is None:
            return None
        stored = snapshots.load_snapshot(
            self.ctx.ckpt_store, client) or snap
        remaining = float(stored["remaining"])
        if remaining < periodic_remaining:
            return remaining, "forecast"
        return None

    def invalidate(self, client: str) -> None:
        """Epoch done: any forecast snapshot for it is stale."""
        self._snap.pop(client, None)


def register_learned_policy(name: str = "learned_forecast",
                            on_warning: str = "checkpoint",
                            overwrite: bool = True,
                            **spec_kwargs) -> Policy:
    """Register (and return) a policy composing the learned forecast
    strategy over cheapest-zone spot placement; `spec_kwargs` reach
    `LearnedForecastSpec`. The default `on_warning="checkpoint"` keeps
    provider-notice handling active alongside the forecaster, matching
    the reactive baseline it is benchmarked against."""
    return register_policy(Policy(
        name, pick_cheapest_zone=True, on_warning=on_warning,
        strategies=(LearnedForecastSpec(**spec_kwargs),)),
        overwrite=overwrite)
