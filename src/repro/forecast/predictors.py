"""Online interruption/price predictors behind one `Forecaster`
protocol.

Both predictors learn incrementally — one O(1) update per observed
event, no batch refits — and are fully deterministic given their
constructor arguments (the `seed` is stored for provenance; no
randomness is consumed, so identical event streams always reproduce
identical predictions, which `tests/test_properties.py` pins).

  HazardEwmaForecaster  an exponentially weighted moving average over
                        the gaps between observed reclaims, per
                        (provider, zone). The hazard estimate is the
                        reciprocal mean gap; before the first reclaim
                        it falls back to the prior `base_rate_per_hr`.
  QuantileForecaster    per-zone online quantile regression: each
                        price sample takes one pinball-loss
                        subgradient step per tracked quantile, and
                        the learned median splits the market into a
                        calm and a spike *regime*. Reclaim counts and
                        market exposure are attributed to the regime
                        in force, giving two smoothed per-regime
                        hazard rates — high in spikes, low in calm —
                        which is exactly the structure of the
                        price-coupled reclaim process it observes.
                        `miscalibrate=True` swaps the two regimes'
                        rates at query time: the deliberately wrong
                        forecaster `benchmarks/forecast_quality.py`
                        uses to show that bad calibration loses real
                        dollars.

Interruption probability within a horizon follows from the hazard via
the exponential survival function `p = 1 - exp(-lambda * h)`.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

_SPIKE = "spike"
_CALM = "calm"


class Forecaster:
    """Protocol every online predictor implements.

    Observations arrive through `observe_price` / `observe_reclaim`
    (forwarded by an `ObservableFeed`); queries never mutate state, so
    prediction at time `t` reflects only events observed strictly
    before the query.
    """

    #: short identifier recorded in `ForecastUpdated` telemetry
    name: str = "forecaster"

    def observe_price(self, provider: str, zone: str, t: float,
                      price: float) -> None:
        """One spot-price sample for a zone."""

    def observe_reclaim(self, provider: str, zone: str,
                        t: float) -> None:
        """One observed reclaim in a zone."""

    def hazard_per_hr(self, provider: str, zone: str,
                      t: float) -> float:
        """Current reclaim-hazard estimate (events/hour)."""
        raise NotImplementedError

    def interruption_probability(self, provider: str, zone: str,
                                 t: float, horizon_s: float) -> float:
        """P(at least one reclaim within `horizon_s`), exponential
        survival on the current hazard estimate."""
        lam = self.hazard_per_hr(provider, zone, t)
        if lam <= 0.0 or horizon_s <= 0.0:
            return 0.0
        return 1.0 - math.exp(-lam * horizon_s / 3600.0)

    def price_quantiles(self, provider: str, zone: str
                        ) -> Optional[Dict[float, float]]:
        """Learned price quantiles (tau -> $/hr) when the predictor
        models them; None otherwise."""
        return None


class HazardEwmaForecaster(Forecaster):
    """EWMA over observed inter-reclaim gaps, per (provider, zone).

    The first gap is measured from the zone's first price sample (the
    earliest moment the tenant was watching); subsequent gaps are
    reclaim-to-reclaim. The hazard estimate is `3600 / ewma_gap`
    events/hour, falling back to the prior `base_rate_per_hr` before
    any reclaim is seen.
    """

    name = "ewma"

    def __init__(self, base_rate_per_hr: float = 0.2,
                 alpha: float = 0.3, seed: int = 0):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.base_rate_per_hr = base_rate_per_hr
        self.alpha = alpha
        self.seed = seed                     # provenance only
        self._first_seen: Dict[Tuple[str, str], float] = {}
        self._last_reclaim: Dict[Tuple[str, str], float] = {}
        self._ewma_gap: Dict[Tuple[str, str], float] = {}

    def observe_price(self, provider: str, zone: str, t: float,
                      price: float) -> None:
        """Prices only anchor the first observation time here."""
        self._first_seen.setdefault((provider, zone), t)

    def observe_reclaim(self, provider: str, zone: str,
                        t: float) -> None:
        """Fold one reclaim gap into the zone's EWMA."""
        key = (provider, zone)
        prev = self._last_reclaim.get(key,
                                      self._first_seen.get(key, t))
        gap = max(t - prev, 1.0)     # degenerate same-tick reclaims
        cur = self._ewma_gap.get(key)
        self._ewma_gap[key] = (gap if cur is None
                               else (1.0 - self.alpha) * cur
                               + self.alpha * gap)
        self._last_reclaim[key] = t

    def hazard_per_hr(self, provider: str, zone: str,
                      t: float) -> float:
        """Reciprocal EWMA gap; the prior before any reclaim."""
        gap = self._ewma_gap.get((provider, zone))
        if gap is None:
            return self.base_rate_per_hr
        return 3600.0 / gap


class _ZoneQuantiles:
    """Per-zone online quantile-regression + regime-hazard state."""

    def __init__(self, taus: Tuple[float, ...]):
        self.q: Dict[float, float] = {}       # tau -> estimate
        self.taus = taus
        self.last_t: Optional[float] = None
        self.regime: str = _CALM
        self.exposure_h = {_CALM: 0.0, _SPIKE: 0.0}
        self.reclaims = {_CALM: 0, _SPIKE: 0}
        self.n_samples = 0


class QuantileForecaster(Forecaster):
    """Online quantile regression over spot prices + regime-conditioned
    hazard rates, per zone.

    Each price sample takes one pinball-loss subgradient step per
    tracked quantile: `q += lr_t * (tau - 1{price <= q})` with a step
    size proportional to the price scale. The learned median defines
    the market *regime* — spike when the price exceeds the median by
    `spike_margin` relative — and reclaims/exposure are attributed to
    the regime in force when they were observed. The per-regime hazard
    is the smoothed occurrence rate

        lambda_r = (reclaims_r + w * base) / (exposure_hours_r + w)

    with `w = prior_weight` pseudo-hours of the prior
    `base_rate_per_hr`, so the estimate starts at the prior and
    converges to the empirical rate as evidence accumulates.
    """

    name = "quantile"

    def __init__(self, taus: Tuple[float, ...] = (0.1, 0.5, 0.9),
                 lr: float = 0.05, spike_margin: float = 0.15,
                 base_rate_per_hr: float = 0.2,
                 prior_weight: float = 1.0,
                 miscalibrate: bool = False, seed: int = 0):
        if 0.5 not in taus:
            raise ValueError("taus must include the 0.5 median "
                             "(regime split point)")
        self.taus = tuple(taus)
        self.lr = lr
        self.spike_margin = spike_margin
        self.base_rate_per_hr = base_rate_per_hr
        self.prior_weight = prior_weight
        self.miscalibrate = miscalibrate
        self.seed = seed                     # provenance only
        self._zones: Dict[Tuple[str, str], _ZoneQuantiles] = {}

    def _zone(self, provider: str, zone: str) -> _ZoneQuantiles:
        key = (provider, zone)
        if key not in self._zones:
            self._zones[key] = _ZoneQuantiles(self.taus)
        return self._zones[key]

    def _classify(self, z: _ZoneQuantiles, price: float) -> str:
        mid = z.q.get(0.5)
        if mid is None or mid <= 0.0:
            return _CALM
        return _SPIKE if price > mid * (1.0 + self.spike_margin) \
            else _CALM

    def observe_price(self, provider: str, zone: str, t: float,
                      price: float) -> None:
        """Accrue regime exposure for the elapsed interval, then take
        one pinball step per quantile and reclassify the regime."""
        z = self._zone(provider, zone)
        if z.last_t is not None and t > z.last_t:
            # the price was piecewise-constant at its previous level
            # over (last_t, t], so the elapsed exposure belongs to the
            # regime that level implied
            z.exposure_h[z.regime] += (t - z.last_t) / 3600.0
        if not z.q:
            z.q = {tau: price for tau in self.taus}
        else:
            step = self.lr * max(abs(price), 1e-3)
            for tau in self.taus:
                grad = tau - (1.0 if price <= z.q[tau] else 0.0)
                z.q[tau] += step * grad
        z.regime = self._classify(z, price)
        z.last_t = t
        z.n_samples += 1

    def observe_reclaim(self, provider: str, zone: str,
                        t: float) -> None:
        """Attribute the reclaim to the regime currently in force."""
        z = self._zone(provider, zone)
        z.reclaims[z.regime] += 1

    def _regime_hazard(self, z: _ZoneQuantiles, regime: str) -> float:
        w = self.prior_weight
        return ((z.reclaims[regime] + w * self.base_rate_per_hr)
                / (z.exposure_h[regime] + w))

    def hazard_per_hr(self, provider: str, zone: str,
                      t: float) -> float:
        """The hazard of the zone's current regime (events/hour);
        `miscalibrate=True` answers with the *other* regime's rate —
        confidently wrong in both directions."""
        z = self._zone(provider, zone)
        regime = z.regime
        if self.miscalibrate:
            regime = _CALM if regime == _SPIKE else _SPIKE
        return self._regime_hazard(z, regime)

    def price_quantiles(self, provider: str, zone: str
                        ) -> Optional[Dict[float, float]]:
        """The zone's learned quantiles, or None before any sample."""
        z = self._zone(provider, zone)
        return dict(z.q) if z.q else None


def make_forecaster(kind: str, **kwargs) -> Forecaster:
    """Factory keyed on the spec-level `forecaster` name."""
    if kind == "ewma":
        return HazardEwmaForecaster(**kwargs)
    if kind == "quantile":
        return QuantileForecaster(**kwargs)
    raise ValueError(f"unknown forecaster kind {kind!r} "
                     f"(expected 'ewma' or 'quantile')")
