"""Online interruption/price forecasting learned from the event bus.

The `ForecastPrewarmStrategy` shipped in the strategy-API redesign
thresholds the *true* preemption-model hazard — a signal no real
tenant can read, and one that does not even exist when a run replays
recorded interruptions. This package replaces the oracle with
forecasters that learn online from exactly what a tenant observes:

  ObservableFeed       (`feed`) subscribes to the bus's reclaim events
                       and samples zone spot prices on demand — the
                       tenant-visible market surface, no model
                       internals. It also hosts the price-derived
                       hazard estimate the runner's replay fallback
                       uses, so "oracle" vs "observable" is an explicit
                       property of every recorded trace.
  Forecaster protocol  (`predictors`) with two online implementations:
                       `HazardEwmaForecaster` (EWMA over observed
                       inter-reclaim gaps) and `QuantileForecaster`
                       (per-zone online quantile regression via pinball
                       updates + regime-conditioned hazard rates).
                       Both are deterministic given a seed and update
                       incrementally per event.
  CalibrationTracker   (`calibration`) scores the forecasts online:
                       Brier score for interruption-within-horizon
                       predictions, empirical coverage of the quantile
                       price bands.
  decide               (`decision`) the explicit cost-of-error rule:
                       expected lost-work dollars vs standby /
                       checkpoint dollars, priced from the live market
                       rates the strategy context exposes.
  LearnedForecastStrategy
                       (`strategy`) the composition: a
                       `SchedulingStrategy` (zero engine edits) that
                       turns predicted interruption probability into
                       PreWarm / Checkpoint / Drain directives and
                       publishes `ForecastUpdated` telemetry
                       (eventlog schema v8).

Layering: this package depends on `core.*`, `common.config` and
`checkpoint.snapshots` only — never on `fl.*` or `cloud.*`. Market
access reaches the feed as plain callables, wired by the composition
root (`repro.fl.runner`) or by `ObservableFeed.for_market` over any
duck-typed market object.
"""
from repro.forecast.calibration import CalibrationTracker
from repro.forecast.decision import Decision, DecisionConfig, decide
from repro.forecast.feed import ObservableFeed
from repro.forecast.predictors import (Forecaster, HazardEwmaForecaster,
                                       QuantileForecaster, make_forecaster)
from repro.forecast.strategy import (LearnedForecastSpec,
                                     LearnedForecastStrategy,
                                     register_learned_policy)

__all__ = [
    "CalibrationTracker", "Decision", "DecisionConfig", "decide",
    "ObservableFeed", "Forecaster", "HazardEwmaForecaster",
    "QuantileForecaster", "make_forecaster", "LearnedForecastSpec",
    "LearnedForecastStrategy", "register_learned_policy",
]
