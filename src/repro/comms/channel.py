"""Uplink bandwidth model: how long a client-update upload takes.

Multi-FedLS measures client→server transfer time as a first-order
term of cross-silo round makespan; FedCostAware's simulator treated
uploads as instantaneous. `UplinkChannel` answers "how many seconds
does `payload_bytes` occupy the uplink of a client in (provider,
zone)?" from per-provider base bandwidth with per-zone overrides —
both configured on `cloud.pricing.Provider` (lifted from
`ProviderConfig.uplink_mbps` / `zone_uplink_mbps`) and both
zero-defaulted, so providers that never opted in keep instantaneous
uploads and every pre-comms round makespan is unchanged.

`CommsModel` bundles one run's payload with its channel: the single
object the engines consult when a client finishes local training.

Layering: duck-types the market (`provider_of`) instead of importing
`cloud.pricing`, so comms stays importable below the cloud layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from repro.comms.payload import UpdatePayload


class UplinkChannel:
    """Per-(provider, zone) uplink bandwidth lookups.

    `providers` maps provider name -> (base_mbps, {zone: mbps})
    with an empty-string key for the market's default provider.
    A non-positive resolved bandwidth means "not modeled": the
    transfer is instantaneous, matching pre-comms behavior.
    """

    def __init__(self, providers: Dict[str, Tuple[float, Dict[str, float]]]):
        self._providers = dict(providers)

    @classmethod
    def from_market(cls, market: Any) -> "UplinkChannel":
        """Lift every provider's uplink fields off a `SpotMarket`
        (duck-typed: anything with `.providers` name->descriptor)."""
        table: Dict[str, Tuple[float, Dict[str, float]]] = {}
        for name, prov in getattr(market, "providers", {}).items():
            base = float(getattr(prov, "uplink_mbps", 0.0))
            zones = {z: float(mbps)
                     for z, mbps in getattr(prov, "zone_uplink_mbps", ())}
            table[name] = (base, zones)
        if table:
            table.setdefault("", next(iter(table.values())))
        return cls(table)

    def uplink_mbps(self, provider: str = "",
                    zone: str = "") -> float:
        """Resolved uplink bandwidth (Mbit/s): the zone override when
        present, else the provider base; 0.0 when unmodeled."""
        base, zones = self._providers.get(provider or "",
                                          self._providers.get("", (0.0, {})))
        return zones.get(zone, base)

    def transfer_s(self, payload_bytes: int, provider: str = "",
                   zone: str = "") -> float:
        """Seconds `payload_bytes` occupies the client's uplink; 0.0
        when bandwidth is unmodeled (instantaneous upload)."""
        mbps = self.uplink_mbps(provider, zone)
        if mbps <= 0.0 or payload_bytes <= 0:
            return 0.0
        return payload_bytes * 8.0 / (mbps * 1e6)


class CommsModel:
    """One run's communication model: payload size + uplink channel.

    Engines call `transfer_s(provider, zone)` when a client finishes
    local training and stretch round completion by the result; the
    matching `ClientUpdateSent` event carries `size_mb`/`quantized` so
    the accountant can price egress.
    """

    def __init__(self, payload: UpdatePayload, channel: UplinkChannel):
        self.payload = payload
        self.channel = channel

    @property
    def size_mb(self) -> float:
        """Wire size (MB) of one client update."""
        return self.payload.size_mb

    @property
    def quantized(self) -> bool:
        """Whether updates travel in the grad_quant int8 layout."""
        return self.payload.quantized

    def transfer_s(self, provider: str = "", zone: str = "") -> float:
        """Upload duration for one update from (provider, zone)."""
        return self.channel.transfer_s(self.payload.num_bytes,
                                       provider, zone)
