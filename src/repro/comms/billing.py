"""Egress pricing for client-update transfers.

`TransferRates` is the transfer sibling of `cloud.pricing.StorageRates`:
a tiny frozen rate card hung off `Provider` (as `Provider.transfer`)
that turns an upload size into dollars. The live `CostAccountant`
prices every `ClientUpdateSent` through the sending provider's card and
publishes a `TransferBilled` event for any non-zero charge, so replayed
logs rebuild transfer dollars from the recorded `TransferBilled` stream
without needing a price book — the same live/replay split
`CheckpointBilled` uses for storage.

Rates default to zero: providers configured before the comms subsystem
existed bill no egress, which keeps every pre-v7 golden total unmoved.

Layering: pure stdlib — `cloud.pricing` imports *from* here, never the
reverse.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TransferRates:
    """Per-provider egress rate card for client-update uploads.

    `egress_usd_per_mb` prices the bytes a client sends back to the
    server (cloud egress is billed at the sender). The zero default
    makes transfer billing strictly opt-in.
    """
    egress_usd_per_mb: float = 0.0

    def transfer_cost(self, size_mb: float) -> float:
        """Dollars to egress one `size_mb` client update."""
        if size_mb <= 0.0:
            return 0.0
        return size_mb * self.egress_usd_per_mb
