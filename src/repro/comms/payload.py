"""Client-update payload sizing.

One FL round uploads one model-sized update per participating client,
and the dollars/seconds that upload costs scale with its byte size —
the knob practitioners actually control (FeatureCloud, Multi-FedLS).
This module turns a param pytree (or a `ModelConfig`, via the same
abstract shapes `configs/shapes.py` dry-runs against) into an
`UpdatePayload` with an exact byte count for the two wire formats the
bridge supports:

* fp32 — each leaf uploads as raw float32, 4 bytes per element.
* quantized — each leaf uploads in the `kernels.grad_quant` block
  layout: int8 values padded to full `BLOCK`-wide rows plus one fp32
  scale per row. The byte math here mirrors `grad_quant.ops.quantize`
  exactly (`tests/test_properties.py` pins the equality against real
  quantized arrays), so billed egress is the true wire size including
  padding overhead — quantization only pays off once a leaf amortizes
  its scale rows, which is precisely the trade the accountant should
  see.

Import-light on purpose: jax and the kernel package load lazily, so
`cloud.pricing` (which imports the sibling `billing` module through the
package) stays cheap to import.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

_FP32_BYTES = 4


def _quant_block() -> int:
    # Lazy: pulling BLOCK from the kernel package imports jax.
    from repro.kernels.grad_quant.ops import BLOCK
    return BLOCK


def fp32_leaf_bytes(n: int) -> int:
    """Wire bytes for one n-element leaf uploaded as raw float32."""
    return int(n) * _FP32_BYTES


def quantized_leaf_bytes(n: int) -> int:
    """Wire bytes for one n-element leaf in the grad_quant block layout.

    `quantize` flattens the leaf and pads it to `nb = ceil(n/BLOCK)`
    full rows (minimum one), returning an int8 `(nb, BLOCK)` value
    array plus an fp32 `(nb, 1)` scale column — so the wire carries
    `nb*BLOCK` int8 bytes plus `nb*4` scale bytes, padding included.
    """
    block = _quant_block()
    nb = max((int(n) + block - 1) // block, 1)
    return nb * block + nb * _FP32_BYTES


def _leaf_elements(tree: Any) -> list:
    """Element counts per leaf; accepts arrays or ShapeDtypeStructs."""
    import jax

    counts = []
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", ())
        n = 1
        for d in shape:
            n *= int(d)
        counts.append(n)
    return counts


@dataclasses.dataclass(frozen=True)
class UpdatePayload:
    """Byte-exact size of one client's update upload.

    `n_params`/`n_leaves` describe the pytree the bytes were derived
    from; `num_bytes` is the wire size in the chosen format. Frozen so
    engines and the accountant can share one instance per run.
    """
    n_params: int
    n_leaves: int
    num_bytes: int
    quantized: bool = False

    @property
    def size_mb(self) -> float:
        """Wire size in MB (2**20 bytes), the unit provider rates use."""
        return self.num_bytes / float(1 << 20)

    # ------------------------------------------------------------------
    @classmethod
    def from_tree(cls, tree: Any, quantized: bool = False) -> "UpdatePayload":
        """Size an update from the actual param pytree (arrays or
        `ShapeDtypeStruct`s), leaf by leaf — each leaf is quantized
        independently, so padding overhead is summed per leaf."""
        counts = _leaf_elements(tree)
        per_leaf = quantized_leaf_bytes if quantized else fp32_leaf_bytes
        return cls(n_params=sum(counts), n_leaves=len(counts),
                   num_bytes=sum(per_leaf(n) for n in counts),
                   quantized=quantized)

    @classmethod
    def from_model_config(cls, cfg: Any,
                          quantized: bool = False) -> "UpdatePayload":
        """Size an update for a `ModelConfig` without materializing
        params, via the same abstract pytree the dry-run harness uses
        (`models.lm.abstract_params`)."""
        from repro.models import lm
        return cls.from_tree(lm.abstract_params(cfg), quantized=quantized)

    @classmethod
    def from_mb(cls, size_mb: float,
                quantized: bool = False) -> "UpdatePayload":
        """Back a modeled size (`FLRunConfig.update_payload_mb`) into a
        payload, treating it as one flat fp32 leaf of the equivalent
        element count so the quantized variant prices consistently."""
        n = max(int(round(size_mb * (1 << 20))) // _FP32_BYTES, 0)
        per_leaf = quantized_leaf_bytes if quantized else fp32_leaf_bytes
        return cls(n_params=n, n_leaves=1, num_bytes=per_leaf(n),
                   quantized=quantized)
