"""Communication-cost subsystem: what a client update *costs* to send.

FedCostAware priced compute seconds but never the client→server
transfer, which Multi-FedLS (Brum et al., 2023) shows is a first-order
cost term in real cross-silo multi-cloud FL. This package models it in
three separable pieces:

  payload.py — how many bytes one client update is, sized from the
               actual param pytree (fp32 baseline, or the grad_quant
               int8 block layout when updates are quantized)
  channel.py — how long the upload occupies the client's uplink
               (per-provider / per-zone bandwidth), which is what
               extends round makespan inside both engines
  billing.py — what the egress costs in dollars (`TransferRates`,
               extending the `StorageRates` pattern), priced by the
               live `CostAccountant` into `TransferBilled` events

Everything is opt-in and zero-defaulted: a run without
`FLRunConfig.update_payload_mb` (or payload-exposing trainer hooks)
publishes no comms events and bills no transfer dollars, so every
pre-comms event stream and golden total is unchanged.
"""
from repro.comms.billing import TransferRates
from repro.comms.channel import CommsModel, UplinkChannel
from repro.comms.payload import UpdatePayload, fp32_leaf_bytes, \
    quantized_leaf_bytes

__all__ = ["CommsModel", "TransferRates", "UpdatePayload", "UplinkChannel",
           "fp32_leaf_bytes", "quantized_leaf_bytes"]
