import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: AOT-lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective analyses.

MUST be run as its own process (the XLA flag above is locked in at jax
init): ``PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape
all --mesh both``.

Also lowers the FL-in-the-mesh round step (the paper-representative
program) when ``--fl-round`` is passed.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.common import compat
from repro.common.config import SHAPES
from repro.configs.shapes import input_specs
from repro.launch import mesh as M
from repro.launch import roofline as RF
from repro.launch import steps as ST
from repro.models import lm
from repro.optim import optimizers
from repro.sharding import rules as R


def abstract_opt_state(cfg):
    p = lm.abstract_params(cfg)
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    return optimizers.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32, nu=f32)


def lower_cell(arch: str, shape_name: str, mesh, rule_overrides=None):
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    jitted, _ = ST.jit_step_for(cfg, shape, mesh,
                                rule_overrides=rule_overrides)
    specs = input_specs(cfg, shape)
    with compat.set_mesh(mesh):
        if shape.kind == "train":
            lowered = jitted.lower(lm.abstract_params(cfg),
                                   abstract_opt_state(cfg), specs["batch"])
        elif shape.kind == "prefill":
            args = [lm.abstract_params(cfg), specs["tokens"]]
            if cfg.family == "vlm":
                args.append(specs["cond"])
            lowered = jitted.lower(*args)
        else:
            lowered = jitted.lower(lm.abstract_params(cfg),
                                   specs["tokens"], specs["pos"],
                                   specs["cache"])
    return cfg, shape, lowered


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             rule_overrides=None, verbose: bool = True):
    t0 = time.time()
    cfg, shape, lowered = lower_cell(arch, shape_name, mesh, rule_overrides)
    compiled = lowered.compile()
    t1 = time.time()

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:   # backend-dependent
        mem["error"] = str(e)

    n_chips = M.mesh_chips(mesh)
    trip = max(cfg.n_super, 1)
    rl = RF.analyze(compiled, n_chips=n_chips, scan_trip_count=trip,
                    model_flops_global=RF.model_flops(cfg, shape))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips, "compile_s": round(t1 - t0, 1),
        "memory": mem, "roofline": rl.as_dict(),
        "params": lm.param_count(cfg),
    }
    if verbose:
        dom = rl.dominant
        print(f"[OK] {arch:24s} {shape_name:12s} {mesh_name:6s} "
              f"compile={t1-t0:6.1f}s flops/dev={rl.flops:.3e} "
              f"bytes/dev={rl.bytes_accessed:.3e} "
              f"coll/dev={rl.collective_bytes:.3e} dom={dom} "
              f"useful={rl.useful_ratio:.2f}")
        if mem and "error" not in mem:
            print(f"     memory_analysis: {mem}")
    return rec


def run_fl_round(mesh, mesh_name: str, arch: str = "phi3-mini-3.8b",
                 local_steps: int = 4, compressed: bool = False,
                 verbose: bool = True):
    """Lower the FL-in-the-mesh round step (paper-representative cell)."""
    from repro.fl import mesh_fl
    cfg = configs.get_config(arch)
    n_pods = mesh.shape.get("pod", 1)
    n_clients = max(n_pods, 1)
    rules = R.make_rules("train")
    shard = R.ShardingCtx(mesh, rules)
    step = mesh_fl.make_fl_round_step(
        cfg, opt=3e-4, shard=shard, local_steps=local_steps,
        compressed=compressed, mesh=mesh, n_pods=n_clients)

    p_abs = lm.abstract_params(cfg)
    stack = lambda s, extra=(): jax.ShapeDtypeStruct(
        (n_clients,) + tuple(extra) + s.shape, s.dtype)
    params_stk = jax.tree.map(lambda s: stack(s), p_abs)
    mu_stk = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_clients,) + s.shape, jnp.float32),
        p_abs)
    B_local, S = 16, 4096
    batches = {
        "tokens": jax.ShapeDtypeStruct(
            (n_clients, local_steps, B_local, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
            (n_clients, local_steps, B_local, S), jnp.int32),
    }
    weights = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    def shard_stacked(axes_tree):
        return jax.tree.map(
            lambda axes: R.resolve_sharding(("fl_clients",) + axes, rules,
                                            mesh),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))

    pshard = shard_stacked(lm.logical_axes(cfg))
    mushard = pshard
    bshard = {
        "tokens": R.resolve_sharding(("fl_clients", None, "fl_batch", None),
                                     rules, mesh),
        "labels": R.resolve_sharding(("fl_clients", None, "fl_batch", None),
                                     rules, mesh),
    }
    wshard = R.resolve_sharding(("fl_clients",), rules, mesh)
    jitted = jax.jit(step, in_shardings=(pshard, mushard, bshard, wshard),
                     out_shardings=(pshard, mushard, wshard))
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jitted.lower(params_stk, mu_stk, batches, weights)
        compiled = lowered.compile()
    t1 = time.time()
    trip = max(configs.get_config(arch).n_super, 1) * local_steps
    rl = RF.analyze(compiled, n_chips=M.mesh_chips(mesh),
                    scan_trip_count=trip,
                    model_flops_global=6.0 * lm.param_count(cfg)
                    * n_clients * local_steps * B_local * S)
    rec = {"arch": arch, "shape": f"fl_round(ls={local_steps},"
           f"compressed={compressed})", "mesh": mesh_name,
           "chips": M.mesh_chips(mesh), "compile_s": round(t1 - t0, 1),
           "roofline": rl.as_dict()}
    if verbose:
        print(f"[OK] FL-round {arch} {mesh_name} compressed={compressed} "
              f"compile={t1-t0:.1f}s coll/dev={rl.collective_bytes:.3e}")
    return rec


def run_fl_agg(mesh, mesh_name: str, arch: str = "phi3-mini-3.8b",
               compressed: bool = False, verbose: bool = True):
    """Lower ONLY the synchronous FedAvg aggregation (the paper's round
    barrier) to isolate its collective cost: plain bf16 weighted average
    vs int8-ring compressed (beyond-paper)."""
    from repro.fl import mesh_fl
    cfg = configs.get_config(arch)
    n_pods = mesh.shape.get("pod", 1)
    n_clients = max(n_pods, 1)
    rules = R.make_rules("train")
    p_abs = lm.abstract_params(cfg)
    params_stk = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n_clients,) + s.shape, s.dtype),
        p_abs)
    weights = jax.ShapeDtypeStruct((n_clients,), jnp.float32)

    def shard_stacked(axes_tree):
        return jax.tree.map(
            lambda axes: R.resolve_sharding(("fl_clients",) + axes, rules,
                                            mesh),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))

    pshard = shard_stacked(lm.logical_axes(cfg))
    if compressed:
        gshard = ST.param_shardings(cfg, rules, mesh)
        g_abs = p_abs
        specs = jax.tree.map(lambda s: s.spec, pshard)

        def agg(p_stk, g, w):
            return mesh_fl.fedavg_sync_compressed(p_stk, g, w, mesh,
                                                  n_clients,
                                                  stacked_specs=specs)

        jitted = jax.jit(agg, in_shardings=(pshard, gshard, None),
                         out_shardings=pshard)
        args_ = (params_stk, g_abs, weights)
    else:
        jitted = jax.jit(lambda p, w: mesh_fl.fedavg_sync(p, w),
                         in_shardings=(pshard, None),
                         out_shardings=pshard)
        args_ = (params_stk, weights)
    t0 = time.time()
    with compat.set_mesh(mesh):
        lowered = jitted.lower(*args_)
        compiled = lowered.compile()
    t1 = time.time()
    rl = RF.analyze(compiled, n_chips=M.mesh_chips(mesh), scan_trip_count=1,
                    model_flops_global=0.0)
    rec = {"arch": arch,
           "shape": f"fl_agg(compressed={compressed})", "mesh": mesh_name,
           "chips": M.mesh_chips(mesh), "compile_s": round(t1 - t0, 1),
           "roofline": rl.as_dict()}
    if verbose:
        print(f"[OK] FL-agg {arch} {mesh_name} compressed={compressed} "
              f"coll/dev={rl.collective_bytes:.3e} "
              f"by_kind={rl.collective_by_kind}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--fl-round", action="store_true")
    ap.add_argument("--fl-agg", action="store_true")
    ap.add_argument("--compressed", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args(argv)

    assert jax.device_count() >= 512, (
        "dry-run needs the 512 fake CPU devices; run as its own process")

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", M.make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", M.make_production_mesh(multi_pod=True)))

    records, failures = [], []
    if args.fl_agg:
        for name, mesh in meshes:
            records.append(run_fl_agg(mesh, name,
                                      compressed=args.compressed))
    elif args.fl_round:
        for name, mesh in meshes:
            records.append(run_fl_round(mesh, name,
                                        compressed=args.compressed))
    else:
        archs = configs.ARCH_IDS if args.arch == "all" else [args.arch]
        for arch in archs:
            shapes = (configs.applicable_shapes(arch)
                      if args.shape == "all" else [args.shape])
            for shape_name in shapes:
                for mesh_name, mesh in meshes:
                    try:
                        records.append(
                            run_cell(arch, shape_name, mesh, mesh_name))
                    except Exception as e:
                        failures.append((arch, shape_name, mesh_name,
                                         repr(e)))
                        print(f"[FAIL] {arch} {shape_name} {mesh_name}: "
                              f"{e}", file=sys.stderr)
                        traceback.print_exc()
                        if args.fail_fast:
                            raise

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    keyed = {(r["arch"], r["shape"], r["mesh"]): r for r in existing}
    for r in records:
        keyed[(r["arch"], r["shape"], r["mesh"])] = r
    with open(args.out, "w") as f:
        json.dump(list(keyed.values()), f, indent=1)
    print(f"\n{len(records)} cells OK, {len(failures)} failed "
          f"-> {args.out}")
    if failures:
        for f_ in failures:
            print("  FAILED:", *f_)
        sys.exit(1)


if __name__ == "__main__":
    main()
