"""Call-graph-weighted HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each computation once — a `while`
body produced by ``lax.scan`` over 80 layers contributes 1/80th of its
real FLOPs. This module parses the optimized HLO text, builds the call
graph (fusion `calls=`, reduce `to_apply=`, `while` condition/body), reads
loop trip counts out of loop-condition constants, and weights every
computation by its execution multiplicity. It reports:

  flops             — 2*M*N*K for every dot, weighted
  hbm_bytes         — Σ (operand + result bytes) of top-level ops, with a
                      fusion counted as ONE op (its body excluded) — the
                      standard post-fusion HBM-traffic proxy
  collective_bytes  — per collective kind, weighted (all-gather /
                      all-reduce / all-to-all / collective-permute count
                      result bytes; reduce-scatter counts operand bytes)

Validated against analytic 6ND expectations in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\([^\n]*\{\s*$", re.M)
_OP_HEAD = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLS = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_WHILE = re.compile(r"condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_CONST_INT = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def _type_elems(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _type_elems(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    text: str
    ops: List[dict]
    is_entry: bool


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    loop_trips: Dict[str, int]
    n_computations: int

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


# ---------------------------------------------------------------------------
def _split_computations(text: str) -> List[Computation]:
    comps = []
    headers = list(_COMP_HDR.finditer(text))
    for i, h in enumerate(headers):
        start = h.start()
        end = headers[i + 1].start() if i + 1 < len(headers) else len(text)
        comps.append(Computation(
            name=h.group(2), text=text[start:end], ops=[],
            is_entry=bool(h.group(1))))
    return comps


_SKIP_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota"}

# HBM-traffic model: the CPU backend wraps each elementwise op in its own
# single-op `fusion` (wrapped_add, ...), so syntactic op counting reflects
# CPU fusion, not TPU fusion, and inflates the memory term ~100x. Instead
# we charge HBM traffic semantically, the way a fused TPU kernel sees it:
#   * dot/convolution: operands + result (weight + activation streams —
#     surrounding elementwise/norm/softmax ops fuse into these kernels),
#   * gather/scatter & (dynamic-)slice/update-slice: embedding lookups,
#     scan xs/carry slicing, KV-cache writes — real HBM round trips,
#   * concatenate/pad/rng: unfusable data movement,
#   * ENTRY I/O (params in/out, optimizer state): charged once in
#     analyze_hlo_text (the fused optimizer reads+writes whole-param state).
_TRAFFIC_OPS = {
    "dot", "convolution", "gather", "scatter",
    "dynamic-update-slice", "dynamic-slice", "slice",
    "concatenate", "pad", "rng", "rng-bit-generator",
    "cholesky", "triangular-solve", "fft",
}


def _parse_op_line(line: str):
    """One op per line: `%name = TYPE opcode(operands), attrs...`.

    TYPE may be a tuple `(f32[..], /*index=5*/ s32[], ...)` containing
    comments with `=`, so we bracket-match rather than regex the type.
    """
    m = _OP_HEAD.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, tail = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\((.*)$", tail)
    if not om:
        return None
    return name, type_str, om.group(1), om.group(2)


def _parse_ops(comp: Computation, shape_of: Dict[str, str]):
    for line in comp.text.splitlines():
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, type_str, opcode, rest = parsed
        shape_of[name] = type_str
        comp.ops.append({"name": name, "type": type_str, "op": opcode,
                         "rest": rest})


def _dot_flops(op: dict, shape_of: Dict[str, str]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dim sizes)."""
    res = _type_elems(op["type"])
    if not res:
        return 0.0
    res_elems = 1
    for d in res[0][1]:
        res_elems *= d
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op["rest"])
    operands = _OPERAND.findall(op["rest"].split(")", 1)[0] + ")")
    contracted = 1
    if cm and operands:
        lhs_type = shape_of.get(operands[0], "")
        lhs = _type_elems(lhs_type)
        if lhs:
            dims = lhs[0][1]
            for idx in (int(i) for i in cm.group(1).split(",") if i):
                if idx < len(dims):
                    contracted *= dims[idx]
    return 2.0 * res_elems * contracted


def _conv_flops(op: dict, shape_of: Dict[str, str]) -> float:
    """2 * out_elems * (kernel spatial * in_channels)."""
    res = _type_elems(op["type"])
    operands = _OPERAND.findall(op["rest"].split(")", 1)[0] + ")")
    if not res or len(operands) < 2:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    ker = _type_elems(shape_of.get(operands[1], ""))
    if not ker:
        return 0.0
    k_elems = 1
    for d in ker[0][1][:-1]:    # all but output-feature dim
        k_elems *= d
    return 2.0 * out_elems * k_elems


def _loop_trip(cond_comp: Optional[Computation]) -> int:
    if cond_comp is None:
        return 1
    consts = [int(c) for c in _CONST_INT.findall(cond_comp.text)]
    consts = [c for c in consts if 0 < c < 10_000_000]
    return max(consts) if consts else 1


def analyze_hlo_text(text: str) -> HloCost:
    comps = _split_computations(text)
    by_name = {c.name: c for c in comps}
    shape_of: Dict[str, str] = {}
    for c in comps:
        _parse_ops(c, shape_of)

    # --- call graph edges: (caller, callee, factor, via_fusion)
    edges: List[Tuple[str, str, float, bool]] = []
    fusion_bodies = set()
    loop_trips: Dict[str, int] = {}
    for c in comps:
        for op in c.ops:
            if op["op"] == "while":
                wm = _WHILE.search(op["rest"])
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trip = _loop_trip(by_name.get(cond))
                    loop_trips[body] = trip
                    edges.append((c.name, body, float(trip), False))
                    edges.append((c.name, cond, float(trip + 1), False))
            else:
                for cm in _CALLS.finditer(op["rest"]):
                    callee = cm.group(1)
                    is_fusion = op["op"] == "fusion" or op["op"].startswith(
                        "wrapped")
                    if is_fusion or op["op"] in ("reduce", "map", "scatter",
                                                 "sort", "reduce-window",
                                                 "select-and-scatter",
                                                 "all-reduce",
                                                 "reduce-scatter"):
                        fusion_bodies.add(callee)
                    edges.append((c.name, callee, 1.0, True))

    # --- multiplicities via propagation (graph is a DAG)
    mult: Dict[str, float] = {c.name: 0.0 for c in comps}
    for c in comps:
        if c.is_entry:
            mult[c.name] = 1.0
    changed = True
    it = 0
    while changed and it < 200:
        changed = False
        it += 1
        new = {c.name: (1.0 if c.is_entry else 0.0) for c in comps}
        for caller, callee, factor, _ in edges:
            new[callee] = new.get(callee, 0.0) + mult.get(caller, 0.0) * factor
        for k, v in new.items():
            if abs(v - mult.get(k, 0.0)) > 1e-9:
                changed = True
        if changed:
            mult = new

    # --- cost accumulation
    flops = 0.0
    hbm = 0.0
    coll_b = {k: 0.0 for k in COLLECTIVE_KINDS}
    coll_n = {k: 0.0 for k in COLLECTIVE_KINDS}
    for c in comps:
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        count_traffic = c.name not in fusion_bodies
        for op in c.ops:
            oc = op["op"]
            if oc == "dot":
                flops += m * _dot_flops(op, shape_of)
            elif oc == "convolution":
                flops += m * _conv_flops(op, shape_of)
            kind = oc.replace("-start", "")
            if kind in coll_b:
                if kind == "reduce-scatter":
                    operands = _OPERAND.findall(
                        op["rest"].split(")", 1)[0] + ")")
                    b = sum(_type_bytes(shape_of.get(o, ""))
                            for o in operands)
                else:
                    b = _type_bytes(op["type"])
                coll_b[kind] += m * b
                coll_n[kind] += m
            if count_traffic and oc in _TRAFFIC_OPS:
                b = _type_bytes(op["type"])
                operands = _OPERAND.findall(
                    op["rest"].split(")", 1)[0] + ")")
                b += sum(_type_bytes(shape_of.get(o, "")) for o in operands)
                hbm += m * b

    # ENTRY I/O once: optimizer state + params are read and written by the
    # (TPU-fused) update kernels. Outputs alias donated inputs, so charge
    # 2x the entry parameter bytes (read + write).
    for c in comps:
        if not c.is_entry:
            continue
        for op in c.ops:
            if op["op"] == "parameter":
                hbm += 2 * _type_bytes(op["type"])
    return HloCost(flops=flops, hbm_bytes=hbm, collective_bytes=coll_b,
                   collective_counts=coll_n, loop_trips=loop_trips,
                   n_computations=len(comps))
