"""Training driver: runs real steps of any ``--arch`` (smoke scale on CPU,
full scale on a TPU mesh) with checkpoint/restart fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \\
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Restart the same command after killing it mid-run: training resumes from
the latest checkpoint (the FedCostAware fault-tolerance path, §III-D).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.ckpt import Checkpointer
from repro.checkpoint.store import FileStore
from repro.data.synthetic import token_stream
from repro.launch import steps as ST
from repro.models import lm
from repro.optim import optimizers


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    train_step, opt = ST.make_train_step(cfg, lr=args.lr)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))

    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt_state = opt.init(params)
    start_step = 0

    ck = None
    if args.ckpt_dir:
        ck = Checkpointer(FileStore(args.ckpt_dir))
        latest = ck.latest_step(f"{args.arch}")
        if latest is not None:
            tpl = {"params": params, "opt": opt_state}
            saved = ck.restore(f"{args.arch}/step={latest}", template=tpl)
            params, opt_state = saved["params"], saved["opt"]
            start_step = latest
            print(f"resumed from checkpoint step {latest}")

    stream = token_stream(cfg.vocab_size, args.batch, args.seq, seed=1)
    for _ in range(start_step):      # keep the data stream deterministic
        next(stream)

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        if cfg.family == "audio":
            rng = np.random.RandomState(step)
            batch["tokens"] = jnp.asarray(
                rng.randn(args.batch, args.seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["cond"] = jnp.zeros(
                (args.batch, cfg.n_cond_tokens, cfg.d_model),
                cfg.activation_dtype)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if (step + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"({dt*1e3:.0f} ms/step)")
            t0 = time.time()
        if ck is not None and (step + 1) % args.ckpt_every == 0:
            ck.save(f"{args.arch}/step={step+1}",
                    {"params": params, "opt": opt_state})
    print("done.")


if __name__ == "__main__":
    main()
