"""Roofline-term extraction from AOT-compiled artifacts.

Per (arch x shape x mesh) cell we derive, WITHOUT hardware:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI_link_bw

`cost_analysis()` supplies FLOPs/bytes of the *partitioned per-device*
module. Collective bytes are not in cost_analysis: we parse the optimized
HLO, sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, and multiply ops inside
`while` bodies (scan-over-layers) by their trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch import mesh as M

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, incl. tuple types."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str,
                      while_trip_count: int = 1) -> CollectiveStats:
    """Sum collective result bytes; ops inside while bodies scale by
    `while_trip_count` (the scan-over-layers length)."""
    # map computation name -> its text block
    comp_starts: List[Tuple[str, int]] = []
    for m in re.finditer(
            r"^(?:ENTRY )?%?([\w\.\-]+)[^\n]*\{", hlo_text, re.M):
        comp_starts.append((m.group(1), m.start()))
    comp_starts.sort(key=lambda x: x[1])

    # which computations are while bodies/conditions?
    loop_comps = set()
    for m in re.finditer(r"(?:body|condition)=%?([\w\.\-]+)", hlo_text):
        loop_comps.add(m.group(1))

    def comp_of(pos: int) -> str:
        name = ""
        for n, s in comp_starts:
            if s <= pos:
                name = n
            else:
                break
        return name

    bytes_by: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    count_by: Dict[str, int] = {k: 0 for k in COLLECTIVE_KINDS}
    for m in re.finditer(
            r"^\s*(?:ROOT )?%?[\w\.\-]+\s*=\s*([^=\n]*?)\s*"
            r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute-start|"
            r"collective-permute)\(", hlo_text, re.M):
        type_str, kind_raw = m.group(1), m.group(2)
        kind = kind_raw.replace("-start", "")
        if kind not in bytes_by:
            continue
        b = _shape_bytes(type_str)
        comp = comp_of(m.start())
        mult = while_trip_count if comp in loop_comps else 1
        bytes_by[kind] += b * mult
        count_by[kind] += mult
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device (HBM traffic proxy)
    collective_bytes: float      # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # 6ND / 2ND useful work (whole step, global)
    useful_ratio: float          # model_flops / (flops * chips)
    peak_fraction: float         # compute_s / max(all terms)
    collective_by_kind: Optional[Dict[str, float]] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, n_chips: int, scan_trip_count: int,
            model_flops_global: float,
            hlo_text: Optional[str] = None) -> Roofline:
    """Derive the three roofline terms from the compiled per-device module.

    FLOPs / HBM bytes / collective bytes come from the call-graph-weighted
    HLO analysis (repro.launch.hlo_analysis), which — unlike XLA's
    cost_analysis() — multiplies `while` (scan) bodies by their trip
    counts. `scan_trip_count` is kept as a cross-check fallback only.
    """
    from repro.launch import hlo_analysis as HA
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = HA.analyze_hlo_text(text)
    flops = hc.flops
    nbytes = hc.hbm_bytes

    compute_s = flops / M.PEAK_FLOPS_BF16
    memory_s = nbytes / M.HBM_BW
    collective_s = hc.total_collective_bytes / M.ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_device_flops = flops * n_chips
    useful = (model_flops_global / total_device_flops
              if total_device_flops else 0.0)
    bound = max(terms.values())
    return Roofline(
        flops=flops, bytes_accessed=nbytes,
        collective_bytes=float(hc.total_collective_bytes),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops_global,
        useful_ratio=useful,
        peak_fraction=(compute_s / bound) if bound > 0 else 0.0,
        collective_by_kind={k: v for k, v in hc.collective_bytes.items()
                            if v})


def estimate_step_time(flops: float, bytes_accessed: float, *,
                       peak_flops: Optional[float] = None,
                       hbm_bw: Optional[float] = None,
                       combine: str = "max") -> float:
    """Roofline wall-clock estimate for one step from its FLOP and byte
    counts, against overridable hardware peaks.

    Defaults use the TPU constants in `launch.mesh` (the dry-run
    analysis above); the training calibrator
    (`repro.fl.training.calibrate`) passes *measured* host peaks
    instead, so the same formula cross-checks a CPU-measured step time.
    `combine="max"` is the classic roofline bound (terms overlap);
    `"sum"` models a serial host where compute and memory traffic share
    one pipe — the right shape for the CPU host-device trick.
    """
    peak_flops = M.PEAK_FLOPS_BF16 if peak_flops is None else peak_flops
    hbm_bw = M.HBM_BW if hbm_bw is None else hbm_bw
    compute_s = flops / peak_flops
    memory_s = bytes_accessed / hbm_bw
    if combine == "sum":
        return compute_s + memory_s
    return max(compute_s, memory_s)


# ---------------------------------------------------------------------------
# Model-FLOPs (the "useful work" yardstick).
# ---------------------------------------------------------------------------
def active_param_count(cfg) -> float:
    """Params touched per token: MoE expert weights scale by top_k/E."""
    from repro.models import lm as _lm
    import numpy as np
    import jax
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(
        _lm.abstract_params(cfg))[0]
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        n = float(np.prod(leaf.shape))
        if cfg.moe is not None and any(
                k in keys for k in ("wi_gate", "wi_up", "wi", "wo")) \
                and "mlp" in keys:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return total


def model_flops(cfg, shape) -> float:
    """6·N·D train / 2·N·D forward; D = tokens processed by the step."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
