"""Jittable step functions (train / prefill / decode) with their sharding
trees — the programs the dry-run lowers and the drivers execute.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig, ShapeConfig
from repro.models import lm
from repro.optim import optimizers
from repro.sharding import rules as R


# ---------------------------------------------------------------------------
# Sharding trees.
# ---------------------------------------------------------------------------
def param_shardings(cfg, rules, mesh):
    return R.tree_shardings(lm.logical_axes(cfg), rules, mesh,
                            lm.abstract_params(cfg))


def opt_shardings(cfg, rules, mesh):
    pshard = param_shardings(cfg, rules, mesh)
    scalar = NamedSharding(mesh, P())
    return optimizers.OptState(step=scalar, mu=pshard, nu=pshard)


def batch_shardings(cfg, rules, mesh, kind: str, shape=None):
    tok_axes = (("batch", "seq", "embed_act") if cfg.family == "audio"
                else ("batch", "seq"))
    B = shape.global_batch if shape is not None else None
    S = shape.seq_len if shape is not None else None
    d = cfg.d_model

    def rs(axes, shp):
        return R.resolve_sharding(axes, rules, mesh,
                                  shp if shape is not None else None)

    tok_shape = (B, S, d) if cfg.family == "audio" else (B, S)
    if kind == "train":
        b = {"tokens": rs(tok_axes, tok_shape),
             "labels": rs(("batch", "seq"), (B, S))}
        if cfg.family == "vlm":
            b["cond"] = rs(("batch", "cond", "embed_act"),
                           (B, cfg.n_cond_tokens, d))
        return {"batch": b}
    if kind == "prefill":
        out = {"tokens": rs(tok_axes, tok_shape)}
        if cfg.family == "vlm":
            out["cond"] = rs(("batch", "cond", "embed_act"),
                             (B, cfg.n_cond_tokens, d))
        return out
    if kind == "decode":
        tok_dec = (("batch", None, "embed_act") if cfg.family == "audio"
                   else ("batch", None))
        tok_dec_shape = (B, 1, d) if cfg.family == "audio" else (B, 1)
        cache_abs = (lm.abstract_cache(cfg, B, S)
                     if shape is not None else None)
        return {
            "tokens": rs(tok_dec, tok_dec_shape),
            "pos": rs(("batch",), (B,)),
            "cache": R.tree_shardings(lm.cache_logical_axes(cfg), rules,
                                      mesh, cache_abs),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Step builders.
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                    rules: Optional[dict] = None, lr: float = 3e-4):
    """Train step with optional gradient accumulation (`cfg.grad_accum`
    microbatches scanned per step — activation memory scales ~1/accum,
    required to fit the >=100B configs in 16GB/chip HBM)."""
    shard = R.ShardingCtx(mesh, rules)
    opt = optimizers.adamw(lr=lr, weight_decay=0.1)
    accum = max(cfg.grad_accum, 1)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, batch, shard=shard))(params)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def mstep(g_acc, mb):
                l, g = jax.value_and_grad(
                    lambda p: lm.loss_fn(p, cfg, mb, shard=shard))(params)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return g_acc, l

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(mstep, g0, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = jnp.mean(losses)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step, opt


def make_prefill_step(cfg: ModelConfig, mesh=None, rules=None):
    shard = R.ShardingCtx(mesh, rules)

    def prefill_step(params, tokens, cond=None):
        logits, _ = lm.forward(params, cfg, tokens, cond=cond, shard=shard)
        # serving returns next-token distribution of the last position
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, rules=None):
    shard = R.ShardingCtx(mesh, rules)

    def serve_step(params, tokens, pos, cache):
        logits, new_cache = lm.decode_step(params, cfg, tokens, pos, cache,
                                           shard=shard)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)
        return next_tok, new_cache

    return serve_step


def jit_step_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 rule_overrides: Optional[dict] = None,
                 donate: bool = True):
    """Build the jitted (but not yet lowered) step + its arg shardings."""
    kind = shape.kind
    # per-arch overrides target the training layout (e.g. phi3's ZeRO-3
    # rules); prefill/decode keep the serving rule sets.
    overrides = dict(cfg.sharding_overrides or ()) if kind == "train" \
        else {}
    if rule_overrides:
        overrides.update(rule_overrides)
    rules = R.make_rules(kind, overrides)
    pshard = param_shardings(cfg, rules, mesh)
    bshard = batch_shardings(cfg, rules, mesh, kind, shape)

    if kind == "train":
        step, opt = make_train_step(cfg, mesh, rules)
        oshard = opt_shardings(cfg, rules, mesh)
        in_shardings = (pshard, oshard, bshard["batch"])
        out_shardings = (pshard, oshard, NamedSharding(mesh, P()))
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=(0, 1) if donate else ())
        return jitted, in_shardings

    if kind == "prefill":
        step = make_prefill_step(cfg, mesh, rules)
        vocab_out = R.resolve_sharding(("batch", "vocab"), rules, mesh,
                                       (shape.global_batch,
                                        cfg.vocab_size))
        names = ["tokens"] + (["cond"] if cfg.family == "vlm" else [])
        in_shardings = tuple([pshard] + [bshard[n] for n in names])
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=vocab_out)
        return jitted, in_shardings

    if kind == "decode":
        step = make_decode_step(cfg, mesh, rules)
        in_shardings = (pshard, bshard["tokens"], bshard["pos"],
                        bshard["cache"])
        out_shardings = (R.resolve_sharding(("batch",), rules, mesh,
                                            (shape.global_batch,)),
                         bshard["cache"])
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=(3,) if donate else ())
        return jitted, in_shardings

    raise ValueError(kind)
