"""Serving driver: batched prefill + decode for any ``--arch``.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    max_len = args.prompt_len + args.gen
    rng = np.random.RandomState(0)
    prompt = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (B, args.prompt_len)), jnp.int32)

    step = jax.jit(lambda p, t, pos, c: lm.decode_step(p, cfg, t, pos, c))
    cache = lm.init_cache(cfg, B, max_len)

    # prefill via decode steps (teacher forcing over the prompt)
    t0 = time.time()
    tok = prompt[:, :1]
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, prompt[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32), cache)
    prefill_s = time.time() - t0

    # greedy decode
    outs = []
    t0 = time.time()
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(args.gen):
        outs.append(tok)
        logits, cache = step(params, tok,
                             jnp.full((B,), args.prompt_len + i,
                                      jnp.int32), cache)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    decode_s = time.time() - t0

    gen = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {prefill_s*1e3:.0f} ms  decode: "
          f"{decode_s/args.gen*1e3:.1f} ms/token")
    for b in range(min(B, 2)):
        print(f"seq{b}: {np.asarray(gen[b])[:16].tolist()} ...")


if __name__ == "__main__":
    main()
