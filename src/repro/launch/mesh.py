"""Production meshes.

Single pod : (data=16, model=16)      = 256 chips (TPU v5e pod slice)
Multi-pod  : (pod=2, data=16, model=16) = 512 chips

Defined as functions (never module-level constants) so importing this
module cannot touch jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* jax init,
everything else sees the real 1-CPU environment.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~ one ICI direction)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cpu_mesh():
    """1-device mesh for smoke tests (sharding code paths stay live)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
