"""Pallas TPU flash attention (causal / sliding-window, online softmax).

Grid: (batch*heads, q_blocks, k_blocks), k innermost — TPU executes the
grid sequentially per core, so the running max / denominator / output
accumulator live in VMEM scratch across k-block steps and the HBM
footprint is O(seq * head_dim), never O(seq^2).

BlockSpec tiling (per grid step, all VMEM):
  q   : (1, block_q, head_dim)
  k,v : (1, block_k, head_dim)
  out : (1, block_q, head_dim)        written at the last k block
With block_q = block_k = 512 and head_dim<=256 the working set is
<= 4 * 512*256*4B = 2MB — comfortably inside a v5e core's VMEM, and the
512x512 f32 score tile keeps the MXU shape-aligned (multiples of 128).

Validated against ref.reference_attention in interpret mode (tests sweep
shapes, dtypes, causal/window/softcap).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale, causal, window, softcap,
                  block_q, block_k, n_k_blocks):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, h)
    k = k_ref[0].astype(jnp.float32)                    # (bk, h)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_old = m_scr[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bnh(q, k, v, *, causal=True, window=None, softcap=None,
                        block_q=512, block_k=512, interpret=False):
    """q: (BN, S, H); k, v: (BN, T, H) — heads pre-folded into batch."""
    BN, S, H = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_q = S // block_q
    n_k = T // block_k
    scale = 1.0 / math.sqrt(H)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_k_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(BN, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, H), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, H), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, H), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, H), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BN, S, H), q.dtype),
        scratch_shapes=[
            # running max, denominator, accumulator — persist across the
            # innermost (k) grid dimension
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, H), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
