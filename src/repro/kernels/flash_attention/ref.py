"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal=True, window=None, softcap=None):
    """q: (BN, S, H); k, v: (BN, T, H). Naive fp32 softmax attention."""
    BN, S, H = q.shape
    T = k.shape[1]
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(H)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
