"""Jitted public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, N, H) (kv pre-expanded to N heads by
the attention layer) and dispatches to the Pallas kernel (TPU) or the
interpret-mode kernel body (CPU validation).

Differentiable: forward runs the Pallas kernel; the VJP recomputes
attention with the reference path (flash-backward kernels are a logged
follow-up — forward is where the O(S^2) memory win lives; the backward
recompute is remat-equivalent and numerically validated in
tests/test_kernels.py::TestFlashAttentionGrad).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bnh
from repro.kernels.flash_attention.ref import reference_attention


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _fa(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    B, S, N, H = q.shape
    T = k.shape[1]
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * N, x.shape[1], H)
    out = flash_attention_bnh(
        fold(q), fold(k), fold(v), causal=causal, window=window,
        softcap=softcap, block_q=min(block_q, S), block_k=min(block_k, T),
        interpret=interpret)
    return out.reshape(B, N, S, H).transpose(0, 2, 1, 3)


def _fa_fwd(q, k, v, causal, window, softcap, block_q, block_k, interpret):
    return _fa(q, k, v, causal, window, softcap, block_q, block_k,
               interpret), (q, k, v)


def _fa_bwd(causal, window, softcap, block_q, block_k, interpret,
            res, g):
    q, k, v = res
    B, S, N, H = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * N, x.shape[1], H)

    def ref(qf, kf, vf):
        return reference_attention(qf, kf, vf, causal=causal,
                                   window=window, softcap=softcap)

    _, vjp = jax.vjp(ref, fold(q), fold(k), fold(v))
    dq, dk, dv = vjp(fold(g))
    unfold = lambda x: x.reshape(B, N, x.shape[1], H).transpose(0, 2, 1, 3)
    return unfold(dq), unfold(dk), unfold(dv)


_fa.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=512, block_k=512, interpret=None):
    """q, k, v: (B, S|T, N, H) -> (B, S, N, H)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _fa(q, k, v, causal, window, softcap, block_q, block_k,
               interpret)
