"""Jitted public wrapper for the SSD kernel (model layout (b,s,h,p))."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_bh


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xbar, log_a, Bm, Cm, *, chunk=256, interpret=None):
    """xbar: (b,s,h,p); log_a: (b,s,h); Bm, Cm: (b,s,h,n).

    Returns (y (b,s,h,p), final_state=None) matching ssd_reference's
    calling convention (the kernel keeps state in VMEM; decode uses the
    O(1) recurrence in repro.models.ssm instead).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, p = xbar.shape
    n = Bm.shape[-1]
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, t.shape[-1])
    la = log_a.transpose(0, 2, 1).reshape(b * h, s)
    y = ssd_bh(fold(xbar), la, fold(Bm), fold(Cm), chunk=chunk,
               interpret=interpret)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3), None
