"""Pure-jnp oracle for the SSD kernel: repro.models.ssm.ssd_reference."""
from repro.models.ssm import ssd_reference  # noqa: F401
