"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Implements the state-space-duality decomposition (intra-chunk quadratic
block + inter-chunk linear recurrence) with the recurrent state carried in
VMEM scratch across the sequential chunk grid dimension:

Grid: (batch*heads, n_chunks) — chunks innermost, executed in order on a
TPU core, so the (head_dim, d_state) state tile never leaves VMEM between
chunks (the GPU formulation materializes all chunk states in HBM and runs
a separate scan kernel; on TPU the sequential grid makes that round trip
unnecessary — this is the TPU-native adaptation noted in DESIGN.md).

BlockSpec tiling per grid step (VMEM):
  x    : (1, Q, P)      inputs (already dt-scaled)
  la   : (1, Q)         dt * A  (log decay)
  B, C : (1, Q, N)      input/output projections
  y    : (1, Q, P)      output
  state: (P, N) f32     scratch, persists across chunks
Q=chunk (256), P=head_dim (64), N=d_state (128): ~0.5MB — VMEM-friendly,
and the (Q,Q) intra-chunk score tile is 256x256 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, state_scr, *, chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    la = la_ref[0].astype(jnp.float32)        # (Q,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    la_cs = jnp.cumsum(la)                    # inclusive (Q,)
    # intra-chunk: L[i,j] = exp(la_cs[i] - la_cs[j]) for i >= j
    diff = la_cs[:, None] - la_cs[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    qj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(qi >= qj, jnp.exp(diff), 0.0)
    att = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q,Q)
    y = jax.lax.dot_general(att * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,P)
    # contribution of the carried state: C_i . state * exp(la_cs_i)
    state = state_scr[...]                     # (P, N)
    y += jnp.exp(la_cs)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: state' = a_chunk * state + sum_j decay_j * x_j B_j^T
    decay_end = jnp.exp(la_cs[-1] - la_cs)     # (Q,)
    xw = x * decay_end[:, None]                # (Q, P)
    new_state = jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (P, N)
    state_scr[...] = jnp.exp(la_cs[-1]) * state + new_state


def ssd_bh(x, la, Bm, Cm, *, chunk=256, interpret=False):
    """x: (BH, S, P); la: (BH, S); Bm, Cm: (BH, S, N) -> y (BH, S, P)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, la, Bm, Cm)
