"""Pure-jnp oracle: associative-scan linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(log_a, b):
    """h_t = exp(log_a_t) * h_{t-1} + b_t  over axis 1. (B,S,W)."""
    a = jnp.exp(log_a.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(
        combine, (a, b.astype(jnp.float32)), axis=1)
    return h.astype(b.dtype)
