"""Jitted wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru_scan_b


@functools.partial(jax.jit, static_argnames=("chunk", "block_w",
                                             "interpret"))
def rglru_scan(log_a, b, *, chunk=128, block_w=128, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rglru_scan_b(log_a, b, chunk=chunk, block_w=block_w,
                        interpret=interpret)
