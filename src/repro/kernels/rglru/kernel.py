"""Pallas TPU kernel for the RG-LRU linear recurrence (Griffin /
RecurrentGemma): h_t = a_t * h_{t-1} + b_t, per channel.

Same TPU-native structure as the SSD kernel: the sequence is chunked,
the inter-chunk carry lives in VMEM scratch across the sequential chunk
grid dimension, and the intra-chunk recurrence is computed in parallel
form with a masked log-space decay matrix (the per-channel analogue of
SSD's segsum):

  h_t = exp(cum_t) * h_in + sum_{j<=t} exp(cum_t - cum_j) * b_j

Grid: (batch, w_blocks, n_chunks), chunks innermost.
BlockSpec tiles (VMEM): a, b, h: (1, Q, WB); carry scratch (WB,).
Q=128, WB=128 -> decay matrix tile (Q,Q) per channel slice stays MXU
aligned and the working set is ~8MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(loga_ref, b_ref, h_ref, carry_scr, *, chunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    la = loga_ref[0].astype(jnp.float32)       # (Q, WB) log decay
    b = b_ref[0].astype(jnp.float32)           # (Q, WB)
    cum = jnp.cumsum(la, axis=0)               # inclusive

    # intra-chunk: decay[i,j] = exp(cum_i - cum_j) for i >= j (the step-j
    # input is already post-decay of step j, so the diagonal is 1).
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    qj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = qi >= qj
    # per-channel decay matrix applied via einsum over j
    diff = cum[:, None, :] - cum[None, :, :]   # (Q, Q, WB)
    decay = jnp.where(mask[..., None], jnp.exp(diff), 0.0)
    h_intra = jnp.einsum("ijw,jw->iw", decay, b)

    carry = carry_scr[...]                     # (WB,)
    h = h_intra + jnp.exp(cum) * carry[None, :]
    h_ref[0] = h.astype(h_ref.dtype)
    carry_scr[...] = h[-1].astype(jnp.float32)


def rglru_scan_b(log_a, b, *, chunk=128, block_w=128, interpret=False):
    """log_a, b: (B, S, W) -> h: (B, S, W) with h_t = e^{log_a_t} h_{t-1} + b_t."""
    B, S, W = log_a.shape
    chunk = min(chunk, S)
    block_w = min(block_w, W)
    assert S % chunk == 0 and W % block_w == 0, (S, W, chunk, block_w)
    nc = S // chunk
    nw = W // block_w

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, block_w), lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w),
                               lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), b.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
