"""Pallas TPU kernels: per-block int8 symmetric (de)quantization.

Used by the compressed cross-pod FedAvg collective (repro.fl.mesh_fl):
client deltas are quantized to int8 + one f32 scale per block before the
ring collective-permute, cutting cross-pod ICI traffic ~4x vs f32 (2x vs
bf16) — the beyond-paper distributed-optimization trick.

Grid: one program per block row; each step loads a (1, BLOCK) tile into
VMEM, reduces |max|, scales, rounds. BLOCK=2048 keeps tiles lane-aligned
(2048 = 16 x 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)                 # (BLOCK,)
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[0] = q.astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[0] = (q_ref[0].astype(jnp.float32)
                * s_ref[0, 0]).astype(x_ref.dtype)


def quantize_blocks(x2d, *, interpret=False):
    """x2d: (nb, BLOCK) -> (int8 (nb, BLOCK), f32 scales (nb, 1))."""
    nb, block = x2d.shape
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)


def dequantize_blocks(q2d, scales, out_dtype=jnp.float32, *,
                      interpret=False):
    nb, block = q2d.shape
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), out_dtype),
        interpret=interpret,
    )(q2d, scales)
