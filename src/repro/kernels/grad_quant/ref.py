"""Pure-jnp oracle for per-block int8 quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_blocks_ref(x2d):
    xf = x2d.astype(jnp.float32)
    amax = jnp.maximum(jnp.max(jnp.abs(xf), axis=1, keepdims=True), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blocks_ref(q2d, scales, out_dtype=jnp.float32):
    return (q2d.astype(jnp.float32) * scales).astype(out_dtype)
