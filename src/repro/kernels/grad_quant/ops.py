"""Jitted wrappers: quantize/dequantize arbitrary-shaped tensors by
flattening to padded (nb, BLOCK) rows."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.grad_quant import kernel as K
from repro.kernels.grad_quant import ref as R

BLOCK = 2048


def _pad_rows(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    nb = max((n + BLOCK - 1) // BLOCK, 1)
    flat = jnp.pad(flat, (0, nb * BLOCK - n))
    return flat.reshape(nb, BLOCK), n


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quantize(x, use_pallas=False, interpret=None):
    """x: any shape -> (q int8 (nb,BLOCK), scales (nb,1), meta n)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x2d, n = _pad_rows(x)
    if use_pallas:
        q, s = K.quantize_blocks(x2d, interpret=interpret)
    else:
        q, s = R.quantize_blocks_ref(x2d)
    return q, s


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "use_pallas",
                                             "interpret"))
def dequantize(q, scales, shape, dtype=jnp.float32, use_pallas=False,
               interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        x2d = K.dequantize_blocks(q, scales, dtype, interpret=interpret)
    else:
        x2d = R.dequantize_blocks_ref(q, scales, dtype)
    n = 1
    for d in shape:
        n *= d
    return x2d.reshape(-1)[:n].reshape(shape)
