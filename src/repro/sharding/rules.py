"""Logical-axis sharding rules (MaxText style).

Parameters and activations are annotated with *logical* axis names; a rule
table maps each logical name to zero-or-more mesh axes. This decouples model
code from the concrete mesh so the same model lowers on the single-pod
``(data, model)`` mesh, the multi-pod ``(pod, data, model)`` mesh, and the
1-device CPU mesh used by the smoke tests.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, MeshAxes]

# ---------------------------------------------------------------------------
# Default rule tables.
# ---------------------------------------------------------------------------
# Standard data+model parallel training (pods act as extra DP):
TRAIN_RULES: Rules = {
    "batch": ("pod", "data"),
    "fl_clients": ("pod",),       # FL-in-mesh: client axis lives on pods
    "fl_batch": ("data",),        # FL-in-mesh: per-client batch
    # Megatron-style sequence parallelism for the residual stream: the
    # scan-over-layers carry is (batch, seq, d_model); sharding seq over
    # `model` cuts the remat-saved carries by 16x (39GB -> 10.6GB/device
    # for phi3 train_4k — see EXPERIMENTS.md §Dry-run). Inside attention
    # the `model` axis is re-used by heads, so resolve_spec drops the seq
    # constraint there automatically (= all-gather at the block boundary,
    # exactly Megatron SP).
    "seq": ("model",),
    "embed": ("data",),           # FSDP shard of the d_model weight dim
    "embed_act": None,            # activations keep d_model unsharded
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "experts": ("model",),
    "expert_cap": None,
    "ssm_inner": ("model",),      # mamba2 inner channels
    "ssm_heads": ("model",),
    "ssm_state": None,
    "lru_width": ("model",),
    "conv_width": None,
    "layers": None,               # stacked-scan leading dim
    "cache_len": None,
    "cond": None,                 # conditioning (image/audio) tokens
    "norm": None,
}

# Decode: KV cache dominates memory → shard cache length over `model`
# (flash-decode style); batch over `data`.
DECODE_RULES: Rules = dict(
    TRAIN_RULES,
    batch=("data",),
    cache_len=("model",),
    kv_heads=None,        # heads often < model axis; length-sharding instead
    heads=("model",),
)


def make_rules(kind: str, overrides: Optional[Rules] = None) -> Rules:
    base = dict(TRAIN_RULES if kind in ("train", "prefill") else DECODE_RULES)
    if overrides:
        base.update(overrides)
    return base


# ---------------------------------------------------------------------------
# Resolution helpers.
# ---------------------------------------------------------------------------
def _axis_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# Mesh axes are claimed by logical axes in PRIORITY order, not positional
# order: compute-parallel dims (heads/mlp/experts/...) outrank sequence
# parallelism, which outranks everything else. This is what lets an arch
# whose head count does NOT divide the model axis (musicgen/granite: 24
# heads on a 16-way axis) fall back to sequence sharding instead of
# silently replicating its attention (observed: useful_ratio 0.016 ->
# fixed: seq claims the freed axis; see EXPERIMENTS.md §Perf).
_CLAIM_PRIORITY = {
    "batch": 0, "fl_clients": 0, "fl_batch": 0,
    "heads": 1, "kv_heads": 1, "mlp": 1, "experts": 1, "ssm_inner": 1,
    "ssm_heads": 1, "lru_width": 1, "vocab": 1, "embed": 1,
    "cache_len": 1,
    "seq": 3,
}


def resolve_spec(logical_axes: Sequence[Optional[str]], rules: Rules,
                 mesh: Mesh, shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec valid on `mesh`.

    Mesh axes are claimed in priority order (see _CLAIM_PRIORITY). When
    `shape` is given, any mapping whose dim is not divisible by the
    mesh-axis extent is dropped (jit in_shardings reject uneven
    partitions — e.g. 8 kv heads on a 16-way model axis, or granite's 40
    experts), freeing the axis for lower-priority claimants.
    """
    order = sorted(
        (i for i, n in enumerate(logical_axes) if n is not None),
        key=lambda i: (_CLAIM_PRIORITY.get(logical_axes[i], 2), i))
    used = set()
    out: list = [None] * len(logical_axes)
    for i in order:
        name = logical_axes[i]
        mesh_axes = rules.get(name, None)
        if mesh_axes is None:
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        # keep only axes present in this mesh and not already used
        mesh_axes = tuple(a for a in mesh_axes
                          if a in mesh.axis_names and a not in used)
        if shape is not None and mesh_axes:
            # drop axes (right-to-left) until the dim divides evenly
            while mesh_axes and shape[i] % _axis_size(mesh, mesh_axes) != 0:
                mesh_axes = mesh_axes[:-1]
        used.update(mesh_axes)
        if not mesh_axes:
            out[i] = None
        elif len(mesh_axes) == 1:
            out[i] = mesh_axes[0]
        else:
            out[i] = mesh_axes
    # trailing Nones can be dropped (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def resolve_sharding(logical_axes, rules: Rules, mesh: Mesh,
                     shape: Optional[Sequence[int]] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical_axes, rules, mesh,
                                            shape))


def _is_axes(x):
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_shardings(logical_tree, rules: Rules, mesh: Mesh,
                   abstract_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings; when the
    matching abstract tree is given, shardings are shape-validated."""
    if abstract_tree is None:
        return jax.tree.map(
            lambda axes: resolve_sharding(axes, rules, mesh),
            logical_tree, is_leaf=_is_axes)
    return jax.tree.map(
        lambda axes, aval: resolve_sharding(axes, rules, mesh, aval.shape),
        logical_tree, abstract_tree, is_leaf=_is_axes)


def constraint(x, logical_axes, rules: Optional[Rules], mesh: Optional[Mesh]):
    """`with_sharding_constraint` via logical names; no-op without a mesh."""
    if rules is None or mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve_sharding(logical_axes, rules, mesh, x.shape))


class ShardingCtx:
    """Threaded through model code: mesh + rules, or inert for CPU tests."""

    def __init__(self, mesh: Optional[Mesh] = None,
                 rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = rules

    def __call__(self, x, *logical_axes):
        return constraint(x, logical_axes, self.rules, self.mesh)

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.rules is not None


INERT = ShardingCtx()
