"""Synthetic data substrate.

Two generators:
  * `image_classification` — Gaussian class-prototype images standing in
    for MNIST / CIFAR-10 / AI-READI / Fed-ISIC2019 (no network access in
    this environment; the learning problem is real — clients demonstrably
    reduce loss and the global model separates classes).
  * `token_stream` — LM token batches for the assigned-architecture smoke
    tests and the mesh-FL driver.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class ImageDataset:
    x: np.ndarray        # (n, h, w, c) float32
    y: np.ndarray        # (n,) int32
    n_classes: int

    def __len__(self):
        return len(self.y)


def image_classification(n: int, img: int = 28, channels: int = 1,
                         n_classes: int = 10, noise: float = 0.35,
                         seed: int = 0) -> ImageDataset:
    rng = np.random.RandomState(seed)
    protos = rng.randn(n_classes, img, img, channels).astype(np.float32)
    y = rng.randint(0, n_classes, size=n).astype(np.int32)
    x = protos[y] + noise * rng.randn(n, img, img, channels).astype(np.float32)
    return ImageDataset(x.astype(np.float32), y, n_classes)


DATASET_SPECS = {
    # name: (img, channels, classes)  — shapes scaled to CPU-runnable sizes
    "mnist": (28, 1, 10),
    "cifar10": (32, 3, 10),
    "aireadi": (48, 3, 4),       # retinal fundus -> device category (4 src)
    "isic2019": (64, 3, 8),      # melanoma classes
}


def make_dataset(name: str, n: int, seed: int = 0) -> ImageDataset:
    img, ch, ncls = DATASET_SPECS[name]
    return image_classification(n, img, ch, ncls, seed=seed)


def minibatches(ds: ImageDataset, idx: np.ndarray, batch: int,
                seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.RandomState(seed)
    order = rng.permutation(idx)
    for i in range(0, len(order) - batch + 1, batch):
        sel = order[i:i + batch]
        yield ds.x[sel], ds.y[sel]


def token_stream(vocab: int, batch: int, seq: int, seed: int = 0
                 ) -> Iterator[dict]:
    """Markov-ish synthetic token batches (next-token predictable)."""
    rng = np.random.RandomState(seed)
    # sparse deterministic transition table makes loss reducible
    trans = rng.randint(0, vocab, size=(vocab,)).astype(np.int32)
    while True:
        start = rng.randint(0, vocab, size=(batch, 1)).astype(np.int32)
        seqs = [start[:, 0]]
        for _ in range(seq):
            nxt = trans[seqs[-1]]
            flip = rng.rand(batch) < 0.1
            nxt = np.where(flip, rng.randint(0, vocab, size=batch), nxt)
            seqs.append(nxt.astype(np.int32))
        arr = np.stack(seqs, axis=1)
        yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}
