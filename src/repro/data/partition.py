"""Dual-Dirichlet non-IID federated partitioner (paper §IV-A).

The paper partitions MNIST / CIFAR-10 / AI-READI "using a dual Dirichlet
method [FedCompass] to simulate non-IID heterogeneous data, modeling both
class imbalance and variation in client data volume":

  1. client volume   ~ Dirichlet(alpha_vol * 1_K)   -> samples per client
  2. class mixture_k ~ Dirichlet(alpha_cls * 1_C)   -> per-client class dist

Fed-ISIC2019 keeps its natural (institution) partition — modeled here by
explicit per-client fractions.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def dual_dirichlet_partition(labels: np.ndarray, n_clients: int,
                             alpha_class: float = 0.5,
                             alpha_volume: float = 2.0,
                             seed: int = 0,
                             min_per_client: int = 8) -> List[np.ndarray]:
    """Returns per-client index arrays covering a subset of `labels`."""
    rng = np.random.RandomState(seed)
    n = len(labels)
    classes = np.unique(labels)
    by_class = {c: rng.permutation(np.where(labels == c)[0])
                for c in classes}
    heads = {c: 0 for c in classes}

    volumes = rng.dirichlet([alpha_volume] * n_clients)
    volumes = np.maximum(volumes, min_per_client / n)
    volumes = volumes / volumes.sum()
    counts = np.floor(volumes * n).astype(int)

    out = []
    for ci in range(n_clients):
        mix = rng.dirichlet([alpha_class] * len(classes))
        want = np.floor(mix * counts[ci]).astype(int)
        idx: List[int] = []
        for k, c in enumerate(classes):
            take = min(want[k], len(by_class[c]) - heads[c])
            idx.extend(by_class[c][heads[c]:heads[c] + take])
            heads[c] += take
        # top up from whatever classes still have samples
        need = counts[ci] - len(idx)
        for c in classes:
            if need <= 0:
                break
            take = min(need, len(by_class[c]) - heads[c])
            idx.extend(by_class[c][heads[c]:heads[c] + take])
            heads[c] += take
            need -= take
        rng.shuffle(idx)
        out.append(np.asarray(idx, np.int64))
    return out


def natural_partition(labels: np.ndarray, fractions: Sequence[float],
                      seed: int = 0) -> List[np.ndarray]:
    """Institution-style split with fixed volume fractions (Fed-ISIC2019)."""
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(labels))
    fr = np.asarray(fractions, np.float64)
    fr = fr / fr.sum()
    bounds = np.floor(np.cumsum(fr) * len(labels)).astype(int)
    out, lo = [], 0
    for hi in bounds:
        out.append(idx[lo:hi])
        lo = hi
    return out
