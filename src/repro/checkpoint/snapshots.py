"""Client training-state snapshots in an `ObjectStore`.

The paper's clients checkpoint to cloud storage (Fig. 1) so a preempted
instance's replacement can resume mid-epoch. This module is the small
serialization layer between the round engines and
`repro.checkpoint.store`: a snapshot is a JSON-encodable dict of plain
training metadata (round index, seconds of epoch progress preserved,
seconds still owed), written through the store's atomic `put` so a
reclaim mid-write never corrupts the latest durable state.

Engines use it on the preemption-notice path (docs/events.md): a
warning-window checkpoint is `save_snapshot`, the replacement
instance's recovery is `load_snapshot`. Keys are per client and
overwrite — only the latest snapshot matters for recovery.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

from repro.checkpoint.store import ObjectStore

KEY_PREFIX = "ckpt/clients/"


def snapshot_key(client: str) -> str:
    """Store key holding `client`'s latest training snapshot."""
    return f"{KEY_PREFIX}{client}/latest"


def save_snapshot(store: ObjectStore, client: str,
                  payload: Dict[str, Any]) -> str:
    """Persist `payload` (JSON-encodable training metadata) as the
    client's latest snapshot; returns the key written."""
    key = snapshot_key(client)
    store.put(key, json.dumps(payload, sort_keys=True).encode("utf-8"))
    return key


def load_snapshot(store: ObjectStore,
                  client: str) -> Optional[Dict[str, Any]]:
    """The client's latest snapshot, or None if it never checkpointed
    (or the snapshot was deleted after a clean resume)."""
    raw = store.get(snapshot_key(client))
    if raw is None:
        return None
    return json.loads(raw.decode("utf-8"))


def delete_snapshot(store: ObjectStore, client: str) -> None:
    """Drop the client's snapshot (after a successful resume or a
    round completion that supersedes it)."""
    store.delete(snapshot_key(client))
