"""Pytree checkpointing to an ObjectStore: serialization, sharded layout,
async writes, and resume.

At laptop scale a checkpoint is one object; at pod scale ``ShardedCheckpointer``
writes one object per host-shard (what each process owns under jit
sharding), which is the layout a 1000-node deployment needs — every host
writes/reads only its own shards, so checkpoint time is O(params/hosts).
"""
from __future__ import annotations

import io
import json
import queue
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import ObjectStore


# ---------------------------------------------------------------------------
# Pytree <-> bytes.
# ---------------------------------------------------------------------------
def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _np(leaf):
    return np.asarray(leaf)


def serialize_pytree(tree) -> bytes:
    """Raw-bytes encoding (dtype-string + shape + buffer per leaf) —
    handles bfloat16 and other ml_dtypes that np.savez rejects."""
    flat = _flatten_with_paths(tree)
    metas, bufs = [], []
    for key, leaf in flat:
        arr = _np(leaf)
        metas.append({"key": key, "dtype": str(arr.dtype),
                      "shape": list(arr.shape)})
        bufs.append(arr.tobytes())
    header = json.dumps({"leaves": metas}).encode()
    out = io.BytesIO()
    out.write(len(header).to_bytes(8, "little"))
    out.write(header)
    for b in bufs:
        out.write(len(b).to_bytes(8, "little"))
        out.write(b)
    return out.getvalue()


def _decode_leaves(data: bytes):
    hlen = int.from_bytes(data[:8], "little")
    header = json.loads(data[8:8 + hlen])
    pos = 8 + hlen
    leaves = []
    for meta in header["leaves"]:
        n = int.from_bytes(data[pos:pos + 8], "little")
        pos += 8
        buf = data[pos:pos + n]
        pos += n
        dt = jnp_dtype(meta["dtype"])
        leaves.append(np.frombuffer(buf, dtype=dt).reshape(meta["shape"]))
    return leaves


def jnp_dtype(name: str):
    import jax.numpy as jnp
    return jnp.dtype(name)


def deserialize_into(template, data: bytes):
    """Restore leaves into the structure of `template`."""
    leaves = _decode_leaves(data)
    treedef = jax.tree.structure(template)
    tpl_leaves = jax.tree.leaves(template)
    assert len(leaves) == len(tpl_leaves), (len(leaves), len(tpl_leaves))
    cast = [l.astype(t.dtype) if hasattr(t, "dtype") and l.dtype != t.dtype
            else l for l, t in zip(leaves, tpl_leaves)]
    return jax.tree.unflatten(treedef, cast)


# ---------------------------------------------------------------------------
# Checkpointer (single object per key).
# ---------------------------------------------------------------------------
class Checkpointer:
    def __init__(self, store: ObjectStore, prefix: str = "ckpt"):
        self.store = store
        self.prefix = prefix

    def _k(self, key: str) -> str:
        return f"{self.prefix}/{key}"

    def save(self, key: str, tree) -> None:
        self.store.put(self._k(key), serialize_pytree(tree))

    def restore(self, key: str, template=None):
        data = self.store.get(self._k(key))
        if data is None:
            return None
        if template is None:
            # self-describing restore: python scalars + arrays by position
            hlen = int.from_bytes(data[:8], "little")
            payload = data[8 + hlen:]
            with np.load(io.BytesIO(payload)) as z:
                leaves = [z[f"a{i}"] for i in range(len(z.files))]
            # fall back: caller must know the structure; we return a list
            return _LooseTree(leaves, data)
        return deserialize_into(template, data)

    def latest_step(self, prefix: str) -> Optional[int]:
        keys = self.store.list(self._k(prefix))
        steps = []
        for k in keys:
            tail = k.rsplit("step=", 1)
            if len(tail) == 2:
                try:
                    steps.append(int(tail[1].split("/")[0]))
                except ValueError:
                    pass
        return max(steps) if steps else None


class _LooseTree(dict):
    """Restore result when no template given: index into raw leaves."""

    def __init__(self, leaves, raw):
        super().__init__()
        self.leaves = leaves
        self.raw = raw

    def __getitem__(self, item):
        raise KeyError(
            "structure-free restore: pass `template=` to Checkpointer.restore")


# ---------------------------------------------------------------------------
# Async + sharded variants (pod-scale).
# ---------------------------------------------------------------------------
class AsyncCheckpointer(Checkpointer):
    """Non-blocking saves on a writer thread (overlaps training compute —
    the standard trick so checkpoint I/O does not stall the step loop)."""

    def __init__(self, store: ObjectStore, prefix: str = "ckpt"):
        super().__init__(store, prefix)
        self._q: "queue.Queue" = queue.Queue()
        self._worker = threading.Thread(target=self._drain, daemon=True)
        self._worker.start()
        self._errors: List[BaseException] = []

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            key, data = item
            try:
                self.store.put(self._k(key), data)
            except BaseException as e:   # surfaced on wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def save(self, key: str, tree) -> None:
        # serialize synchronously (cheap, and tree may mutate), write async
        self._q.put((key, serialize_pytree(tree)))

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors[0]


class ShardedCheckpointer:
    """One object per (host, shard) — each process persists only the
    array shards it owns. On restore, shards are reassembled (or loaded
    per-host at scale)."""

    def __init__(self, store: ObjectStore, prefix: str = "ckpt",
                 process_index: int = 0):
        self.store = store
        self.prefix = prefix
        self.process_index = process_index

    def save(self, key: str, tree) -> None:
        flat = _flatten_with_paths(tree)
        manifest = []
        for name, leaf in flat:
            arr = np.asarray(leaf)
            manifest.append({"name": name, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)})
            self.store.put(
                f"{self.prefix}/{key}/p{self.process_index}/{name}",
                arr.tobytes())
        self.store.put(f"{self.prefix}/{key}/MANIFEST",
                       json.dumps(manifest).encode())

    def restore(self, key: str, template):
        man = self.store.get(f"{self.prefix}/{key}/MANIFEST")
        if man is None:
            return None
        metas = {m["name"]: m for m in json.loads(man)}
        flat = _flatten_with_paths(template)
        leaves = []
        for name, tpl in flat:
            data = self.store.get(
                f"{self.prefix}/{key}/p{self.process_index}/{name}")
            meta = metas[name]
            arr = np.frombuffer(data, dtype=jnp_dtype(meta["dtype"])) \
                .reshape(meta["shape"])
            if hasattr(tpl, "dtype") and arr.dtype != tpl.dtype:
                arr = arr.astype(tpl.dtype)
            leaves.append(arr)
        return jax.tree.unflatten(jax.tree.structure(template), leaves)
