"""Object store abstraction — the simulated S3 (paper Fig. 1: clients
checkpoint to cloud storage; server & clients exchange models through it).

Backends: in-memory (tests) and local filesystem (examples). Keys are
hierarchical strings; values are bytes. Writes are atomic (temp + rename)
so a preemption mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import os
import tempfile
import threading
from typing import Dict, List, Optional


class ObjectStore:
    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> Optional[bytes]:
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError


class MemoryStore(ObjectStore):
    def __init__(self):
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key, data):
        with self._lock:
            self._data[key] = bytes(data)

    def get(self, key):
        with self._lock:
            return self._data.get(key)

    def list(self, prefix=""):
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def delete(self, key):
        with self._lock:
            self._data.pop(key, None)


class FileStore(ObjectStore):
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        safe = key.replace("/", "__")
        return os.path.join(self.root, safe)

    def put(self, key, data):
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.root)
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)          # atomic
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get(self, key):
        path = self._path(key)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return f.read()

    def list(self, prefix=""):
        safe = prefix.replace("/", "__")
        return sorted(k.replace("__", "/") for k in os.listdir(self.root)
                      if k.startswith(safe) and not k.startswith("tmp"))

    def delete(self, key):
        path = self._path(key)
        if os.path.exists(path):
            os.unlink(path)
