"""Object store abstraction — the simulated S3 (paper Fig. 1: clients
checkpoint to cloud storage; server & clients exchange models through it).

Backends: in-memory (tests) and local filesystem (examples). Keys are
hierarchical strings; values are bytes. Writes are atomic (temp + rename)
so a preemption mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import os
import tempfile
import threading
from typing import Dict, List, Optional


class ObjectStore:
    """Abstract key -> bytes store (the simulated S3 surface)."""

    def put(self, key: str, data: bytes) -> None:
        """Durably write `data` under `key`, replacing any old value."""
        raise NotImplementedError

    def get(self, key: str) -> Optional[bytes]:
        """The bytes under `key`, or None if absent."""
        raise NotImplementedError

    def list(self, prefix: str = "") -> List[str]:
        """Sorted keys starting with `prefix`."""
        raise NotImplementedError

    def delete(self, key: str) -> None:
        """Remove `key` (a no-op when absent)."""
        raise NotImplementedError


class MemoryStore(ObjectStore):
    """In-process dict-backed store (tests, default runs)."""

    def __init__(self):
        self._data: Dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key, data):
        """Store a copy of `data` under `key`."""
        with self._lock:
            self._data[key] = bytes(data)

    def get(self, key):
        """The bytes under `key`, or None."""
        with self._lock:
            return self._data.get(key)

    def list(self, prefix=""):
        """Sorted keys starting with `prefix`."""
        with self._lock:
            return sorted(k for k in self._data if k.startswith(prefix))

    def delete(self, key):
        """Remove `key` if present."""
        with self._lock:
            self._data.pop(key, None)


class FileStore(ObjectStore):
    """Local-filesystem store; keys flatten to one directory level
    (`/` -> `__`), writes are atomic (temp file + rename)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, key: str) -> str:
        safe = key.replace("/", "__")
        return os.path.join(self.root, safe)

    def put(self, key, data):
        """Atomically write `data` under `key` (temp + rename), so a
        crash/preemption mid-write never corrupts the old value."""
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.root)
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)          # atomic
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get(self, key):
        """The bytes under `key`, or None."""
        path = self._path(key)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return f.read()

    def list(self, prefix=""):
        """Sorted keys starting with `prefix`."""
        safe = prefix.replace("/", "__")
        return sorted(k.replace("__", "/") for k in os.listdir(self.root)
                      if k.startswith(safe) and not k.startswith("tmp"))

    def delete(self, key):
        """Remove `key` if present."""
        path = self._path(key)
        if os.path.exists(path):
            os.unlink(path)
