"""Communication-cost subsystem (repro.comms): payload byte math,
uplink channel timing, engine makespan extension, egress billing
through the CostAccountant, and live-vs-replay agreement — plus the
zero-default guarantee that runs without comms modeling are untouched.
"""
import math

import pytest

from repro.cloud.accounting import CostAccountant
from repro.cloud.pricing import SpotMarket
from repro.comms import (CommsModel, TransferRates, UpdatePayload,
                         UplinkChannel, fp32_leaf_bytes,
                         quantized_leaf_bytes)
from repro.common.config import (CloudConfig, ClientProfile, FLRunConfig,
                                 MarketConfig, ProviderConfig)
from repro.core.eventlog import EventReplayer
from repro.core.events import ClientUpdateSent, EventBus, TransferBilled
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result

CLIENTS = (
    ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=3),
    ClientProfile("mid", mean_epoch_s=450, jitter=0.0, n_samples=2),
    ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
)

# one provider with every comms knob set: 100 Mbps uplink, an
# overridden zone, and a visible egress price
COMM_MARKET = MarketConfig(providers=(
    ProviderConfig(name="aws", on_demand_rate=1.0, spot_rate_mean=0.4,
                   spot_rate_sigma=0.0, n_zones=2,
                   update_egress_usd_per_mb=0.001,
                   uplink_mbps=100.0,
                   zone_uplink_mbps=(("aws-z1", 50.0),)),))


def run_policy(policy="fedcostaware", clients=CLIENTS, n_epochs=4,
               cloud=None, record=False, **cfg_kw):
    cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=n_epochs,
                      policy=policy, seed=0, **cfg_kw)
    r = FLCloudRunner(cfg, cloud_cfg=cloud or CloudConfig(
        spot_rate_sigma=0.0), record=record)
    return r, r.run()


# ---------------------------------------------------------------------------
# Payload byte math.
# ---------------------------------------------------------------------------
class TestPayload:
    def test_fp32_bytes(self):
        assert fp32_leaf_bytes(10) == 40
        assert UpdatePayload.from_mb(8.0).num_bytes == 8 * (1 << 20)
        assert UpdatePayload.from_mb(8.0).size_mb == pytest.approx(8.0)

    def test_quantized_block_layout(self):
        from repro.kernels.grad_quant.ops import BLOCK
        # one partial block still pays a full row + one scale
        assert quantized_leaf_bytes(1) == BLOCK + 4
        assert quantized_leaf_bytes(BLOCK) == BLOCK + 4
        assert quantized_leaf_bytes(BLOCK + 1) == 2 * (BLOCK + 4)
        # empty leaves clamp to one block (quantize's own minimum)
        assert quantized_leaf_bytes(0) == BLOCK + 4

    def test_quantized_bytes_match_real_quantize_output(self):
        """The accounting formula equals the true wire size of the
        arrays `grad_quant.ops.quantize` actually produces."""
        import numpy as np
        from repro.kernels.grad_quant import ops as gq
        for n in (1, 7, 2048, 2049, 5000):
            x = np.linspace(-1.0, 1.0, n, dtype=np.float32)
            q, scales = gq.quantize(x, use_pallas=False)
            wire = q.size * q.dtype.itemsize + \
                scales.size * scales.dtype.itemsize
            assert quantized_leaf_bytes(n) == wire, n

    def test_from_tree_sums_per_leaf(self):
        import numpy as np
        tree = {"a": np.zeros((3, 5), np.float32),
                "b": np.zeros((7,), np.float32)}
        p = UpdatePayload.from_tree(tree)
        assert (p.n_params, p.n_leaves) == (22, 2)
        assert p.num_bytes == 22 * 4
        q = UpdatePayload.from_tree(tree, quantized=True)
        assert q.num_bytes == quantized_leaf_bytes(15) + \
            quantized_leaf_bytes(7)
        assert q.quantized and not p.quantized

    def test_quantization_shrinks_large_payloads(self):
        big = UpdatePayload.from_mb(8.0)
        small = UpdatePayload.from_mb(8.0, quantized=True)
        assert small.num_bytes < big.num_bytes
        # asymptotically BLOCK int8 + 4 scale bytes per BLOCK fp32 bytes
        assert small.num_bytes / big.num_bytes == pytest.approx(
            0.25, rel=0.01)


# ---------------------------------------------------------------------------
# Uplink channel.
# ---------------------------------------------------------------------------
class TestChannel:
    def test_transfer_time_and_zone_override(self):
        ch = UplinkChannel({"aws": (100.0, {"aws-z1": 50.0})})
        mb = 1 << 20
        assert ch.transfer_s(mb, "aws") == pytest.approx(mb * 8 / 100e6)
        assert ch.transfer_s(mb, "aws", "aws-z1") == pytest.approx(
            mb * 8 / 50e6)
        assert ch.transfer_s(mb, "aws", "aws-z2") == pytest.approx(
            mb * 8 / 100e6)             # unknown zone -> provider base

    def test_unmodeled_bandwidth_is_instantaneous(self):
        ch = UplinkChannel({"aws": (0.0, {})})
        assert ch.transfer_s(1 << 20, "aws") == 0.0
        assert UplinkChannel({}).transfer_s(1 << 20, "gcp") == 0.0

    def test_from_market_lifts_provider_fields(self):
        market = SpotMarket.for_cloud_config(
            CloudConfig(market=COMM_MARKET), seed=0)
        ch = UplinkChannel.from_market(market)
        assert ch.uplink_mbps("aws") == 100.0
        assert ch.uplink_mbps("aws", "aws-z1") == 50.0
        assert ch.uplink_mbps("") == 100.0   # default-provider alias

    def test_comms_model_bundles_payload_and_channel(self):
        m = CommsModel(UpdatePayload.from_mb(1.0),
                       UplinkChannel({"": (100.0, {})}))
        assert m.size_mb == pytest.approx(1.0)
        assert not m.quantized
        assert m.transfer_s() == pytest.approx((1 << 20) * 8 / 100e6)


# ---------------------------------------------------------------------------
# Billing: TransferRates -> CostAccountant, live and replay.
# ---------------------------------------------------------------------------
class TestTransferBilling:
    def test_transfer_rates(self):
        r = TransferRates(egress_usd_per_mb=0.001)
        assert r.transfer_cost(8.0) == pytest.approx(0.008)
        assert r.transfer_cost(0.0) == 0.0
        assert TransferRates().transfer_cost(8.0) == 0.0

    def test_live_accountant_prices_update_sent(self):
        bus = EventBus()
        prices = SpotMarket.for_cloud_config(
            CloudConfig(market=COMM_MARKET))
        acc = CostAccountant(bus, prices=prices)
        bus.publish(ClientUpdateSent(10.0, "c0", 0, size_mb=8.0))
        assert acc.transfer_cost("c0") == pytest.approx(0.008)
        assert acc.transfer_cost_total() == pytest.approx(0.008)
        assert acc.client_cost("c0") == pytest.approx(0.008)

    def test_replay_accountant_folds_transfer_billed(self):
        bus = EventBus()
        acc = CostAccountant(bus, prices=None)      # replay mode
        bus.publish(TransferBilled(10.0, "c0", 0.008))
        bus.publish(TransferBilled(11.0, "c0", 0.002))
        assert acc.transfer_cost("c0") == pytest.approx(0.010)
        assert acc.total_cost() == pytest.approx(0.010)

    def test_zero_rate_publishes_no_billed_event(self):
        bus = EventBus()
        seen = []
        bus.subscribe(TransferBilled, seen.append)
        acc = CostAccountant(bus, prices=SpotMarket.for_cloud_config(
            CloudConfig()))
        bus.publish(ClientUpdateSent(10.0, "c0", 0, size_mb=8.0))
        assert seen == [] and acc.transfer_cost_total() == 0.0


# ---------------------------------------------------------------------------
# End-to-end: engines stretch rounds by the upload and bill egress.
# ---------------------------------------------------------------------------
class TestEngineIntegration:
    @pytest.mark.parametrize("policy",
                             ["fedcostaware", "fedcostaware_async"])
    def test_comms_extends_makespan_and_bills_egress(self, policy):
        _, base = run_policy(policy)
        _, comm = run_policy(policy, update_payload_mb=8.0,
                             cloud=CloudConfig(market=COMM_MARKET))
        assert base.comm_cost == 0.0
        assert comm.comm_cost > 0.0
        assert comm.makespan_s > 0.0

    @pytest.mark.parametrize("policy",
                             ["fedcostaware", "fedcostaware_async"])
    def test_upload_events_recorded_and_replay_agrees(self, policy):
        r, res = run_policy(policy, update_payload_mb=8.0, record=True,
                            cloud=CloudConfig(market=COMM_MARKET))
        types = [rec["type"] for rec in r.recorder.records]
        assert "ClientUpdateSent" in types
        assert "TransferBilled" in types
        sent = [rec for rec in r.recorder.records
                if rec["type"] == "ClientUpdateSent"]
        assert all(s["size_mb"] == pytest.approx(8.0) for s in sent)
        assert all(s["transfer_s"] > 0.0 for s in sent)
        rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(res.total_cost, abs=1e-9)
        assert rep.comm_cost == pytest.approx(res.comm_cost, abs=1e-9)
        assert res.comm_cost == pytest.approx(0.008 * len(sent))

    def test_upload_time_delays_sync_barrier(self):
        """With a modeled uplink the same run takes longer: the barrier
        waits for the slowest upload too."""
        _, fast = run_policy(update_payload_mb=8.0,
                             cloud=CloudConfig(market=COMM_MARKET))
        no_uplink = MarketConfig(providers=(
            dataclass_replace_provider(COMM_MARKET.providers[0]),))
        _, instant = run_policy(update_payload_mb=8.0,
                                cloud=CloudConfig(market=no_uplink))
        assert fast.makespan_s > instant.makespan_s
        # billing is independent of bandwidth modeling
        assert fast.comm_cost == pytest.approx(instant.comm_cost)

    def test_quantized_payload_bills_less(self):
        _, fp = run_policy(update_payload_mb=8.0,
                           cloud=CloudConfig(market=COMM_MARKET))
        _, q = run_policy(update_payload_mb=8.0, quantize_updates=True,
                          cloud=CloudConfig(market=COMM_MARKET))
        assert 0.0 < q.comm_cost < fp.comm_cost

    def test_default_runs_carry_no_comms_events(self):
        r, res = run_policy(record=True)
        types = {rec["type"] for rec in r.recorder.records}
        assert "ClientUpdateSent" not in types
        assert "TransferBilled" not in types
        assert res.comm_cost == 0.0

    def test_fleet_path_rejects_comms(self):
        cfg = FLRunConfig(dataset="t", clients=CLIENTS, n_epochs=2,
                          policy="fedcostaware", seed=0, fleet=True,
                          update_payload_mb=8.0)
        with pytest.raises(ValueError, match="fleet path"):
            FLCloudRunner(cfg, cloud_cfg=CloudConfig(spot_rate_sigma=0.0))


def dataclass_replace_provider(p: ProviderConfig) -> ProviderConfig:
    """COMM_MARKET's provider with the uplink unmodeled (egress rates
    kept), for the makespan-vs-billing separation test."""
    import dataclasses
    return dataclasses.replace(p, uplink_mbps=0.0, zone_uplink_mbps=())
