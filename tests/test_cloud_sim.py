"""Tests for the cloud simulator + pricing + the policy-level invariants
the paper's Table I rests on (spot = price-ratio savings; FedCostAware
strictly cheaper than plain spot under stragglers)."""
import math

import numpy as np
import pytest

from repro.common.config import CloudConfig, ClientProfile, FLRunConfig
from repro.cloud.pricing import PriceBook
from repro.cloud.simulator import CloudSimulator
from repro.core.events import InstancePreempted, InstanceReady
from repro.fl.runner import FLCloudRunner


CLOUD = CloudConfig(spot_rate_sigma=0.0)   # deterministic prices


class TestPricing:
    def test_price_bounds(self):
        pb = PriceBook(CloudConfig(), seed=3)
        for z in pb.zones:
            for t in np.linspace(0, 48 * 3600, 50):
                p = pb.spot_price(z.name, t)
                assert 0.25 * 1.008 <= p <= 1.008

    def test_integral_matches_flat_rate(self):
        pb = PriceBook(CLOUD, seed=0)
        z = pb.zones[0].name
        c = pb.cost(z, 0.0, 3600.0, on_demand=False)
        assert c == pytest.approx(pb.spot_price(z, 0.0), rel=1e-6)

    def test_on_demand_flat(self):
        pb = PriceBook(CLOUD, seed=0)
        assert pb.cost("any", 0, 7200, on_demand=True) == pytest.approx(
            2 * 1.008)

    def test_cheapest_zone(self):
        pb = PriceBook(CloudConfig(), seed=1)
        z, p = pb.cheapest_zone(0.0)
        assert p == min(pb.spot_price(zz.name, 0.0) for zz in pb.zones)


class TestSimulator:
    def test_billing_starts_at_ready(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("c")
        sim.run_until_idle()
        assert inst.state == "running"
        t_ready = inst.t_ready
        sim.now = t_ready + 3600.0
        cost = sim.accrued_cost(inst)
        assert cost == pytest.approx(
            sim.prices.spot_price(inst.zone, t_ready), rel=0.02)

    def test_min_billing(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("c")
        sim.run_until_idle()
        sim.now = inst.t_ready + 5.0      # used 5s, billed >= 60s
        sim.terminate(inst)
        assert inst.cost >= 59.0 / 3600.0 * 0.25 * 1.008

    def test_terminate_while_spinning_never_runs(self):
        sim = CloudSimulator(CLOUD, seed=0)
        ran = []
        sim.bus.subscribe(InstanceReady, lambda ev: ran.append(ev.instance))
        inst = sim.request_instance("c")
        sim.terminate(inst)
        sim.run_until_idle()
        assert ran == [] and inst.cost == 0.0

    def test_preemption_fires(self):
        cfg = CloudConfig(preemption_rate_per_hr=50.0, spot_rate_sigma=0.0)
        sim = CloudSimulator(cfg, seed=1)
        preempted = []
        sim.bus.subscribe(InstancePreempted,
                          lambda ev: preempted.append(ev.instance))
        sim.request_instance("c")
        sim.run_until_idle(t_max=10 * 3600)
        assert len(preempted) == 1

    def test_no_callback_params_on_request(self):
        """The bus is the only notification channel (PR acceptance)."""
        import inspect
        params = inspect.signature(
            CloudSimulator.request_instance).parameters
        assert "on_ready" not in params and "on_preempt" not in params


class TestBillingEdgeCases:
    """min-billing floor, zero-cost spin-up termination, and preemption
    races — the cases the incremental accountant must price identically
    to the simulator's own ledger."""

    def _ready(self, sim):
        ready = []
        sim.bus.subscribe(InstanceReady, lambda ev: ready.append(ev))
        return ready

    def test_min_billing_floor_on_short_lived_spot(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("c")
        sim.run_until_idle()
        sim.now = inst.t_ready + 2.0          # used 2s; floor is 60s
        sim.terminate(inst)
        floor = sim.prices.cost(inst.zone, inst.t_ready,
                                inst.t_ready + CLOUD.min_billing_s,
                                on_demand=False)
        assert inst.cost == pytest.approx(floor, rel=1e-9)

    def test_min_billing_floor_not_applied_to_on_demand(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("c", on_demand=True)
        sim.run_until_idle()
        sim.now = inst.t_ready + 2.0
        sim.terminate(inst)
        assert inst.cost == pytest.approx(2.0 / 3600.0 * 1.008, rel=1e-9)

    def test_terminate_while_spinning_accrues_zero(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("c")
        assert inst.state == "spinning_up"
        sim.terminate(inst)
        sim.run_until_idle()
        assert inst.cost == 0.0
        assert sim.client_cost("c") == 0.0
        # even the min-billing floor must not fire: billing never opened
        assert inst._billing_from is None and inst.t_ready is None

    def test_preemption_during_spinning_up_is_noop(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("c")
        assert inst.state == "spinning_up"
        preempted = []
        sim.bus.subscribe(InstancePreempted,
                          lambda ev: preempted.append(ev))
        assert sim.preempt(inst) is False     # reclaim races the boot
        assert inst.state == "spinning_up" and inst.cost == 0.0
        assert preempted == []
        ready = self._ready(sim)
        sim.run_until_idle()                  # boot completes normally
        assert inst.state == "running" and len(ready) == 1

    def test_double_preempt_is_noop(self):
        cfg = CloudConfig(preemption_rate_per_hr=50.0, spot_rate_sigma=0.0)
        sim = CloudSimulator(cfg, seed=1)
        inst = sim.request_instance("c")
        sim.run_until_idle(t_max=10 * 3600)
        assert inst.state == "preempted"
        cost = inst.cost
        assert sim.preempt(inst) is False
        assert inst.cost == cost


def run_policy(policy, clients=None, n_epochs=8, cloud=None, seed=0):
    clients = clients or (
        ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=3),
        ClientProfile("mid", mean_epoch_s=450, jitter=0.0, n_samples=2),
        ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
    )
    cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=n_epochs,
                      policy=policy, seed=seed)
    return FLCloudRunner(cfg, cloud_cfg=cloud or CLOUD).run()


class TestPolicies:
    def test_spot_saves_price_ratio_vs_on_demand(self):
        od = run_policy("on_demand")
        sp = run_policy("spot")
        ratio = sp.total_cost / od.total_cost
        # paper: 60.8% saving = spot/on-demand price ratio
        assert ratio == pytest.approx(0.3951 / 1.008, rel=0.03)

    def test_fedcostaware_beats_spot_with_stragglers(self):
        sp = run_policy("spot")
        fca = run_policy("fedcostaware")
        assert fca.total_cost < sp.total_cost * 0.9
        assert fca.rounds_completed == 8

    def test_all_policies_complete_all_rounds(self):
        for p in ("on_demand", "spot", "fedcostaware"):
            assert run_policy(p).rounds_completed == 8

    def test_homogeneous_clients_no_lifecycle_churn(self):
        clients = tuple(ClientProfile(f"c{i}", 600.0, jitter=0.0)
                        for i in range(3))
        res = run_policy("fedcostaware", clients=clients)
        # identical clients -> idle time ~ 0 -> no savings segments
        assert not [s for s in res.timeline if s.state == "savings"]

    def test_budget_exclusion_in_runner(self):
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        res = run_policy("fedcostaware", clients=clients, n_epochs=10)
        assert "poor" in res.excluded_clients
        assert res.rounds_completed == 10
        assert res.per_round_participants[-1] == ["rich"]

    def test_preemption_recovery_completes_run(self):
        cloud = CloudConfig(preemption_rate_per_hr=0.4, spot_rate_sigma=0.0)
        res = run_policy("fedcostaware", cloud=cloud, seed=3)
        assert res.rounds_completed == 8
        kinds = {e["kind"] for e in []}
        # run again to inspect events
        cfg = FLRunConfig(dataset="t", clients=(
            ClientProfile("slow", mean_epoch_s=900, jitter=0.0),
            ClientProfile("fast", mean_epoch_s=150, jitter=0.0)),
            n_epochs=8, policy="fedcostaware", seed=3)
        r = FLCloudRunner(cfg, cloud_cfg=cloud)
        out = r.run()
        evk = [e["kind"] for e in r.sim.event_log]
        assert out.rounds_completed == 8
        if "preempt" in evk:
            # recovery happened and the run still finished every round
            assert evk.count("request") > len(cfg.clients)

    def test_timeline_segments_cover_run(self):
        res = run_policy("fedcostaware")
        for seg in res.timeline:
            assert seg.t1 >= seg.t0 >= 0.0
        by_client = {}
        for seg in res.timeline:
            by_client.setdefault(seg.client, []).append(seg)
        for segs in by_client.values():
            ts = sorted((s.t0, s.t1) for s in segs)
            for (a0, a1), (b0, b1) in zip(ts, ts[1:]):
                assert b0 >= a0 - 1e-6   # ordered, non-overlapping starts


class TestElasticScaling:
    def test_client_joins_mid_run(self):
        clients = (
            ClientProfile("a", 600, jitter=0.0),
            ClientProfile("b", 300, jitter=0.0),
            ClientProfile("late", 200, jitter=0.0, join_round=3),
        )
        res = run_policy("fedcostaware", clients=clients, n_epochs=6)
        sizes = [len(p) for p in res.per_round_participants]
        assert sizes == [2, 2, 2, 3, 3, 3]
        assert res.rounds_completed == 6
        assert res.per_client_cost["late"] > 0

    def test_join_and_budget_leave_compose(self):
        clients = (
            ClientProfile("a", 600, jitter=0.0),
            ClientProfile("late_poor", 200, jitter=0.0, join_round=2,
                          budget=0.06),
        )
        res = run_policy("fedcostaware", clients=clients, n_epochs=8)
        sizes = [len(p) for p in res.per_round_participants]
        assert sizes[0] == 1 and max(sizes) == 2 and sizes[-1] == 1
        assert "late_poor" in res.excluded_clients
