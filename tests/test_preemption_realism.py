"""Pinned assertions for benchmarks/preemption_realism.py — the two
acceptance claims of the preemption-realism subsystem:

  (a) under the price-coupled model, interruption incidence correlates
      with trace price spikes (the mean price at reclaim instants sits
      well above the zone's time-averaged price);
  (b) notice-aware checkpointing strictly reduces lost client-seconds
      and total cost vs periodic-only checkpointing in the pinned
      replayed-interruption scenario (and "drain" improves further).
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.preemption_realism import (compare_modes,
                                           interruption_price_lift,
                                           run_mode)


class TestPriceCoupledCorrelation:
    def test_interruptions_cluster_in_price_spikes(self):
        lift = interruption_price_lift()
        assert lift["n_interruptions"] >= 5
        # spiky.csv spends 6 of 48 hours at 0.90 vs a ~0.30 base; with
        # sensitivity 8 essentially every reclaim lands inside a spike
        assert lift["lift"] > 1.5
        assert lift["mean_price_at_interrupt"] == pytest.approx(0.90,
                                                                rel=0.05)

    def test_zero_sensitivity_kills_the_correlation(self):
        flat = interruption_price_lift(sensitivity=0.0)
        assert flat["n_interruptions"] > 0
        # decoupled hazard: reclaims land at ~the time-averaged price
        assert flat["lift"] < 1.3


class TestNoticeAwareCheckpointingWins:
    @pytest.fixture(scope="class")
    def modes(self):
        return compare_modes(model="replay")

    def test_all_modes_complete_every_round(self, modes):
        assert all(m["rounds_completed"] == 3 for m in modes.values())

    def test_checkpoint_strictly_reduces_lost_work(self, modes):
        assert modes["checkpoint"]["lost_work_s"] < \
            modes["ignore"]["lost_work_s"]

    def test_checkpoint_strictly_reduces_cost(self, modes):
        assert modes["checkpoint"]["total_cost"] < \
            modes["ignore"]["total_cost"]

    def test_drain_is_at_least_as_good_as_checkpoint(self, modes):
        assert modes["drain"]["lost_work_s"] <= \
            modes["checkpoint"]["lost_work_s"]
        assert modes["drain"]["total_cost"] <= \
            modes["checkpoint"]["total_cost"]

    def test_drain_avoids_the_reclaim_entirely(self, modes):
        assert modes["drain"]["n_preemptions"] == 0
        assert modes["ignore"]["n_preemptions"] >= 1


class TestFlatModelStillWorks:
    def test_constant_model_grid_completes(self):
        r = run_mode("constant", "checkpoint", n_epochs=2)
        assert r["rounds_completed"] == 2
