"""Adversarial scenario generators (repro.cloud.scenarios): seeded
determinism down to recorded event-log bytes, cross-zone (not
cross-provider) reclaim correlation under capacity_crunch, and
flash-crash trace integrals agreeing with direct integration through
the TracePriceSource prefix sums.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from repro.cloud.pricing import SpotMarket, TracePriceSource
from repro.cloud.scenarios import (CRUNCH_JITTER_S, SCENARIOS,
                                   apply_scenario)
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 MarketConfig, ProviderConfig,
                                 ScenarioConfig)
from repro.fl.runner import FLCloudRunner

ALL_SCENARIOS = ("flash_crash", "capacity_crunch", "diurnal",
                 "price_inversion")


def two_provider_market(scenario=None, seed=3, provider=None,
                        **sckw) -> MarketConfig:
    return MarketConfig(
        providers=(
            ProviderConfig(name="aws", on_demand_rate=3.0, n_zones=3),
            ProviderConfig(name="gcp", on_demand_rate=3.2, n_zones=2),
        ),
        scenario=(None if scenario is None
                  else ScenarioConfig(name=scenario, seed=seed,
                                      provider=provider, **sckw)))


def build(scenario, seed=3, **sckw) -> SpotMarket:
    return SpotMarket.from_market_config(
        two_provider_market(scenario, seed=seed, **sckw), seed=7)


class TestRegistry:
    def test_all_generators_registered(self):
        assert set(SCENARIOS) == set(ALL_SCENARIOS)

    def test_unknown_scenario_raises(self):
        m = build(None)
        with pytest.raises(ValueError, match="unknown scenario"):
            apply_scenario(m, ScenarioConfig(name="meteor_strike"))

    def test_unknown_provider_raises(self):
        with pytest.raises(ValueError, match="not in market"):
            build("capacity_crunch", provider="azure")

    def test_inversion_needs_two_providers(self):
        single = MarketConfig(
            providers=(ProviderConfig(name="aws", n_zones=2),),
            scenario=ScenarioConfig(name="price_inversion"))
        with pytest.raises(ValueError, match=">= 2 providers"):
            SpotMarket.from_market_config(single, seed=7)


class TestDeterminism:
    @pytest.mark.parametrize("name", ALL_SCENARIOS)
    def test_same_seed_same_traces(self, name):
        """Byte-level: identical configs produce identical shaped
        prices and identical reclaim schedules."""
        m1, m2 = build(name), build(name)
        ts = np.linspace(0.0, 48 * 3600.0, 777)
        for z in m1.zones:
            np.testing.assert_array_equal(
                m1.source(z.name, z.provider).prices_at(ts),
                m2.source(z.name, z.provider).prices_at(ts))
        assert m1.interruptions == m2.interruptions

    # price_inversion is seed-free by design (fixed 6 h blocks), so
    # only the stochastic generators should move with the seed
    @pytest.mark.parametrize(
        "name", ("flash_crash", "capacity_crunch", "diurnal"))
    def test_different_seed_different_traces(self, name):
        m1, m2 = build(name, seed=3), build(name, seed=4)
        ts = np.linspace(0.0, 48 * 3600.0, 777)
        assert any(
            not np.array_equal(
                m1.source(z.name, z.provider).prices_at(ts),
                m2.source(z.name, z.provider).prices_at(ts))
            for z in m1.zones)

    def test_same_seed_byte_identical_event_log(self):
        """End to end: two recorded runs on the same scenario market
        serialize to the same bytes — the sweep's reproducibility
        contract."""
        def record():
            cloud = CloudConfig(
                market=two_provider_market("capacity_crunch",
                                           horizon_s=4 * 3600.0,
                                           step_s=60.0),
                preemption_model="correlated",
                preemption_rate_per_hr=0.2)
            clients = tuple(
                ClientProfile(f"c{i}", mean_epoch_s=500.0 + 80.0 * i,
                              jitter=0.05)
                for i in range(4))
            cfg = FLRunConfig(dataset="scn", clients=clients, n_epochs=4,
                              policy="fedcostaware", seed=11)
            r = FLCloudRunner(cfg, cloud_cfg=cloud, record=True)
            r.run()
            return r.recorder.dumps()

        assert record() == record()


class TestCapacityCrunch:
    def test_reclaims_only_on_flagged_provider(self):
        m = build("capacity_crunch", provider="gcp")
        assert m.interruptions
        assert {k[0] for k in m.interruptions} == {"gcp"}

    def test_reclaims_cover_every_flagged_zone(self):
        m = build("capacity_crunch")
        flagged_zones = {z.name for z in m.zones if z.provider == "aws"}
        assert {k[1] for k in m.interruptions} == flagged_zones

    def test_reclaims_correlate_across_zones_not_providers(self):
        """Each crunch hit reclaims every flagged zone within the
        jitter window; zones of the *other* provider see nothing (the
        correlation structure a per-zone Poisson process cannot
        make)."""
        m = build("capacity_crunch")
        times = np.array([m.interruptions[k]
                          for k in sorted(m.interruptions)])
        assert times.shape[0] == 3          # aws zones
        spread = times.max(axis=0) - times.min(axis=0)
        assert spread.max() <= CRUNCH_JITTER_S
        assert not any(k[0] == "gcp" for k in m.interruptions)

    def test_prices_squeeze_during_windows(self):
        """Flagged-provider prices rise relative to the unshaped base
        somewhere on the horizon; the other provider's never move."""
        base = build(None)
        m = build("capacity_crunch")
        ts = np.arange(0.0, 48 * 3600.0, 300.0)
        for z in m.zones:
            shaped = m.source(z.name, z.provider).prices_at(ts)
            raw = base.source(z.name, z.provider).prices_at(ts)
            if z.provider == "aws":
                assert shaped.max() > raw.max() * 1.5
            else:
                np.testing.assert_allclose(shaped, raw, rtol=0, atol=0)


class TestFlashCrash:
    def test_spikes_decay_back_to_base(self):
        base = build(None)
        m = build("flash_crash")
        ts = np.arange(0.0, 48 * 3600.0, 300.0)
        for z in m.zones:
            shaped = m.source(z.name, z.provider).prices_at(ts)
            raw = base.source(z.name, z.provider).prices_at(ts)
            assert shaped.max() > raw.max() * 1.8       # spikes exist
            # decay: most of the horizon sits within 1% of base
            close = np.abs(shaped / raw - 1.0) < 0.01
            assert close.mean() > 0.5

    def test_trace_integrals_match_direct_integration(self):
        """The prefix-sum integral of every shaped trace agrees with
        brute-force piecewise-constant integration to 1e-9 — the
        billing hot path prices flash crashes exactly."""
        m = build("flash_crash")
        for z in m.zones:
            src = m.source(z.name, z.provider)
            assert isinstance(src, TracePriceSource)
            t0, t1 = 1234.5, 30 * 3600.0 + 17.0
            grid = np.union1d(src._times, [t0, t1])
            grid = grid[(grid >= t0) & (grid <= t1)]
            direct = sum(src.price(float(a)) * (b - a)
                         for a, b in zip(grid[:-1], grid[1:]))
            assert src.integral(t0, t1) == pytest.approx(direct,
                                                         abs=1e-9)


class TestDiurnalAndInversion:
    def test_diurnal_cycles_daily(self):
        """Shaped/base ratio at the same clock hour on consecutive
        weekdays is equal; weekend days are scaled down."""
        base = build(None)
        # 7-day horizon so day 5 (weekend) sits inside the shaped trace
        m = build("diurnal", horizon_s=7 * 86400.0)
        z = m.zones[0]
        src, raw = m.source(z.name, z.provider), base.source(z.name,
                                                             z.provider)
        day = 86400.0
        t = 10 * 3600.0
        r0 = src.price(t) / raw.price(t)
        r1 = src.price(t + day) / raw.price(t + day)
        assert r1 == pytest.approx(r0, rel=1e-9)
        rw = src.price(t + 5 * day) / raw.price(t + 5 * day)
        assert rw == pytest.approx(0.8 * r0, rel=1e-9)

    def test_inversion_flips_cheapest_provider(self):
        """In even blocks the flagged provider is expensive, in odd
        blocks cheap — `cheapest_zone` arbitration flips providers."""
        m = build("price_inversion", strength=1.0)
        even_prov = m.cheapest_zone(3 * 3600.0)[0].provider
        odd_prov = m.cheapest_zone(9 * 3600.0)[0].provider
        assert even_prov != odd_prov


class TestScenarioThroughBenchmarks:
    def test_any_policy_runs_on_scenario_market(self):
        """A scenario-bearing MarketConfig is reachable from plain
        config — every existing benchmark can opt in."""
        cloud = CloudConfig(market=two_provider_market("diurnal"))
        cfg = FLRunConfig(
            dataset="scn",
            clients=(ClientProfile("a", mean_epoch_s=400.0, jitter=0.0),
                     ClientProfile("b", mean_epoch_s=700.0, jitter=0.0)),
            n_epochs=3, policy="spot", seed=0)
        res = FLCloudRunner(cfg, cloud_cfg=cloud).run()
        assert res.total_cost > 0.0
        assert res.rounds_completed == 3
