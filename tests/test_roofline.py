"""HLO cost-analysis + roofline tests: analytic cross-checks of the
call-graph-weighted FLOP/byte/collective accounting."""
import textwrap

import numpy as np
import pytest

from repro.launch import hlo_analysis as HA
from repro.launch import roofline as RF
from repro import configs


SIMPLE_HLO = textwrap.dedent("""\
    HloModule test

    %body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
      %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add.red
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
    }

    %cond.1 (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    %add.red (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[8,8]) -> f32[8,8] {
      %arg = f32[8,8]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,8]{1,0}) tuple(%zero, %arg)
      %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond.1, body=%body.1
      %big = f32[128,64]{1,0} dot(%arg, %arg), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
    }
""")


class TestHloAnalysis:
    def test_loop_weighted_flops(self):
        hc = HA.analyze_hlo_text(SIMPLE_HLO)
        # loop body dot: 2*8*8*8 = 1024 flops x 10 trips; entry dot:
        # 2*128*64*8 = 131072 x 1
        assert hc.loop_trips == {"body.1": 10}
        assert hc.flops == pytest.approx(1024 * 10 + 2 * 128 * 64 * 8)

    def test_loop_weighted_collectives(self):
        hc = HA.analyze_hlo_text(SIMPLE_HLO)
        # all-reduce of f32[8,8] = 256B x 10 trips
        assert hc.collective_bytes["all-reduce"] == 256 * 10
        assert hc.collective_counts["all-reduce"] == 10

    def test_traffic_counts_dots_and_entry_io(self):
        hc = HA.analyze_hlo_text(SIMPLE_HLO)
        # per-trip dot traffic: result 256 + 2x operand 256 = 768
        # entry dot: 32768 + 2*256 = 33280 ; entry param io = 2*256
        assert hc.hbm_bytes == pytest.approx(768 * 10 + 33280 + 2 * 256)


class TestRooflineTerms:
    def test_model_flops_train_vs_decode(self):
        cfg = configs.get_config("phi3-mini-3.8b")
        from repro.common.config import SHAPES
        t = RF.model_flops(cfg, SHAPES["train_4k"])
        d = RF.model_flops(cfg, SHAPES["decode_32k"])
        n = RF.active_param_count(cfg)
        assert t == pytest.approx(6 * n * 256 * 4096)
        assert d == pytest.approx(2 * n * 128)

    def test_moe_active_params_smaller_than_total(self):
        cfg = configs.get_config("dbrx-132b")
        from repro.models import lm
        active = RF.active_param_count(cfg)
        total = lm.param_count(cfg)
        # 16 experts top-4 -> expert params scale by 1/4
        assert active < 0.45 * total

    def test_dominant_term_classification(self):
        class FakeCompiled:
            def as_text(self):
                return SIMPLE_HLO
            def cost_analysis(self):
                return {}
        rl = RF.analyze(FakeCompiled(), n_chips=4, scan_trip_count=10,
                        model_flops_global=1e6)
        assert rl.dominant in ("compute", "memory", "collective")
        assert rl.compute_s >= 0 and rl.collective_s > 0


class TestDryrunConsistency:
    """The committed dry-run artifacts must cover every assigned cell."""

    def test_results_cover_all_cells(self):
        import json, os
        path = os.path.join(os.path.dirname(__file__), "..",
                            "benchmarks", "results", "dryrun.json")
        if not os.path.exists(path):
            pytest.skip("dry-run artifacts not generated yet")
        with open(path) as f:
            recs = json.load(f)
        have = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
        for arch, shape in configs.all_cells():
            assert (arch, shape, "single") in have, (arch, shape, "single")
        # multi-pod coverage (filled in by the final sweep)
        multi = [c for c in have if c[2] == "multi"]
        assert len(multi) >= 1
