"""Pallas kernel validation (interpret mode): shape/dtype sweeps against
the pure-jnp oracles, per the kernel contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import reference_attention
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_reference
from repro.kernels.grad_quant.ops import quantize, dequantize
from repro.kernels.grad_quant import kernel as QK, ref as QR


def _fold(x):
    B, S, N, H = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * N, S, H)


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,dtype", [
        (128, 32, jnp.float32),
        (256, 64, jnp.float32),
        (128, 64, jnp.bfloat16),
        (512, 128, jnp.float32),
    ])
    def test_shape_dtype_sweep(self, S, H, dtype):
        rng = np.random.RandomState(hash((S, H)) % 2**31)
        B, N = 2, 2
        q = jnp.asarray(rng.randn(B, S, N, H), dtype)
        k = jnp.asarray(rng.randn(B, S, N, H), dtype)
        v = jnp.asarray(rng.randn(B, S, N, H), dtype)
        out = flash_attention(q, k, v, block_q=64, block_k=64,
                              interpret=True)
        ref = reference_attention(_fold(q), _fold(k), _fold(v))
        ref = ref.reshape(B, N, S, H).transpose(0, 2, 1, 3)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window):
        rng = np.random.RandomState(7)
        B, S, N, H = 1, 256, 2, 32
        q, k, v = (jnp.asarray(rng.randn(B, S, N, H), jnp.float32)
                   for _ in range(3))
        out = flash_attention(q, k, v, window=window, block_q=64,
                              block_k=64, interpret=True)
        ref = reference_attention(_fold(q), _fold(k), _fold(v),
                                  window=window)
        ref = ref.reshape(B, N, S, H).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_softcap(self):
        rng = np.random.RandomState(8)
        B, S, N, H = 1, 128, 2, 32
        q, k, v = (jnp.asarray(rng.randn(B, S, N, H) * 3, jnp.float32)
                   for _ in range(3))
        out = flash_attention(q, k, v, softcap=10.0, block_q=64,
                              block_k=64, interpret=True)
        ref = reference_attention(_fold(q), _fold(k), _fold(v),
                                  softcap=10.0)
        ref = ref.reshape(B, N, S, H).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5)

    def test_block_size_invariance(self):
        rng = np.random.RandomState(9)
        B, S, N, H = 1, 256, 1, 32
        q, k, v = (jnp.asarray(rng.randn(B, S, N, H), jnp.float32)
                   for _ in range(3))
        o1 = flash_attention(q, k, v, block_q=32, block_k=64,
                             interpret=True)
        o2 = flash_attention(q, k, v, block_q=128, block_k=32,
                             interpret=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=2e-5)


class TestSSD:
    @pytest.mark.parametrize("s,p,n,chunk", [
        (64, 16, 16, 16), (128, 32, 64, 32), (256, 64, 128, 64),
    ])
    def test_vs_reference(self, s, p, n, chunk):
        rng = np.random.RandomState(s + p)
        b, h = 2, 3
        xbar = jnp.asarray(rng.randn(b, s, h, p) * 0.5, jnp.float32)
        log_a = jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.1, jnp.float32)
        Bm = jnp.asarray(rng.randn(b, s, h, n) * 0.3, jnp.float32)
        Cm = jnp.asarray(rng.randn(b, s, h, n) * 0.3, jnp.float32)
        yk, _ = ssd(xbar, log_a, Bm, Cm, chunk=chunk, interpret=True)
        yr, _ = ssd_reference(xbar, log_a, Bm, Cm, chunk=chunk)
        scale = float(jnp.max(jnp.abs(yr))) + 1e-9
        assert float(jnp.max(jnp.abs(yk - yr))) / scale < 1e-5

    def test_vs_sequential_recurrence(self):
        """Independent O(S) oracle: h_t = a_t h_{t-1} + B_t x_t."""
        rng = np.random.RandomState(11)
        b, s, h, p, n = 1, 64, 2, 8, 8
        xbar = jnp.asarray(rng.randn(b, s, h, p) * 0.5, jnp.float32)
        log_a = jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.2, jnp.float32)
        Bm = jnp.asarray(rng.randn(b, s, h, n) * 0.4, jnp.float32)
        Cm = jnp.asarray(rng.randn(b, s, h, n) * 0.4, jnp.float32)

        def step(st, inp):
            x_t, la_t, b_t, c_t = inp
            st = (jnp.exp(la_t)[..., None, None] * st
                  + jnp.einsum("bhp,bhn->bhpn", x_t, b_t))
            return st, jnp.einsum("bhpn,bhn->bhp", st, c_t)

        st0 = jnp.zeros((b, h, p, n))
        _, ys = jax.lax.scan(step, st0, (
            xbar.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2),
            Bm.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2, 3)))
        y_seq = ys.transpose(1, 0, 2, 3)
        yk, _ = ssd(xbar, log_a, Bm, Cm, chunk=16, interpret=True)
        scale = float(jnp.max(jnp.abs(y_seq))) + 1e-9
        assert float(jnp.max(jnp.abs(yk - y_seq))) / scale < 1e-4

    def test_chunk_invariance(self):
        rng = np.random.RandomState(12)
        b, s, h, p, n = 1, 128, 1, 8, 8
        args = (jnp.asarray(rng.randn(b, s, h, p) * 0.5, jnp.float32),
                jnp.asarray(-np.abs(rng.randn(b, s, h)) * 0.1, jnp.float32),
                jnp.asarray(rng.randn(b, s, h, n) * 0.3, jnp.float32),
                jnp.asarray(rng.randn(b, s, h, n) * 0.3, jnp.float32))
        y1, _ = ssd(*args, chunk=16, interpret=True)
        y2, _ = ssd(*args, chunk=64, interpret=True)
        scale = float(jnp.max(jnp.abs(y1))) + 1e-9
        assert float(jnp.max(jnp.abs(y1 - y2))) / scale < 1e-5


class TestGradQuant:
    @pytest.mark.parametrize("shape", [(100,), (3, 1000), (17, 65, 5)])
    def test_pallas_matches_ref(self, shape):
        rng = np.random.RandomState(sum(shape))
        x = jnp.asarray(rng.randn(*shape) * 0.01, jnp.float32)
        qp, sp = quantize(x, use_pallas=True)
        qr, sr = quantize(x, use_pallas=False)
        assert jnp.array_equal(qp, qr)
        np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                                   rtol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_roundtrip_error_bound(self, dtype):
        rng = np.random.RandomState(5)
        x = jnp.asarray(rng.randn(4, 3333), dtype)
        q, s = quantize(x, use_pallas=True)
        xd = dequantize(q, s, (4, 3333), dtype=jnp.float32,
                        use_pallas=True)
        amax = float(jnp.max(jnp.abs(x.astype(jnp.float32))))
        # symmetric int8: error <= scale/2 <= amax/254 per block
        err = float(jnp.max(jnp.abs(xd - x.astype(jnp.float32))))
        assert err <= amax / 127.0 + 1e-6

    def test_zero_tensor(self):
        x = jnp.zeros((2, 100), jnp.float32)
        q, s = quantize(x, use_pallas=True)
        xd = dequantize(q, s, (2, 100), use_pallas=True)
        assert float(jnp.max(jnp.abs(xd))) == 0.0


class TestFlashAttentionGrad:
    def test_grad_matches_reference(self):
        """use_pallas=True must be trainable: VJP through the kernel
        matches grads of the pure reference."""
        rng = np.random.RandomState(21)
        B, S, N, H = 1, 128, 2, 32
        q, k, v = (jnp.asarray(rng.randn(B, S, N, H), jnp.float32)
                   for _ in range(3))

        def loss_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v, block_q=64,
                                           block_k=64, interpret=True) ** 2)

        def loss_ref(q, k, v):
            f = lambda x: x.transpose(0, 2, 1, 3).reshape(B * N, S, H)
            o = reference_attention(f(q), f(k), f(v))
            return jnp.sum(o ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=3e-4, rtol=3e-4)

    def test_model_trains_with_pallas_attention(self):
        """End-to-end: a smoke transformer takes a grad step with
        cfg.use_pallas=True (interpret mode on CPU)."""
        import dataclasses
        from repro import configs
        from repro.models import lm
        cfg = configs.get_config("phi3-mini-3.8b", smoke=True)
        cfg = dataclasses.replace(cfg, use_pallas=True, attn_chunk=8)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(
                     rng.randint(0, cfg.vocab_size, (2, 16)), jnp.int32),
                 "labels": jnp.asarray(
                     rng.randint(0, cfg.vocab_size, (2, 16)), jnp.int32)}
        loss, grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch))(params)
        assert bool(jnp.isfinite(loss))
        gn = sum(float(jnp.sum(jnp.abs(g)))
                 for g in jax.tree.leaves(grads))
        assert gn > 0


class TestRGLRU:
    @pytest.mark.parametrize("S,W,chunk,bw", [
        (64, 16, 16, 16), (128, 64, 32, 32), (256, 32, 128, 32),
    ])
    def test_vs_associative_scan(self, S, W, chunk, bw):
        from repro.kernels.rglru.ops import rglru_scan
        from repro.kernels.rglru.ref import rglru_scan_ref
        rng = np.random.RandomState(S + W)
        log_a = jnp.asarray(-np.abs(rng.randn(2, S, W)) * 0.2, jnp.float32)
        b = jnp.asarray(rng.randn(2, S, W) * 0.5, jnp.float32)
        hk = rglru_scan(log_a, b, chunk=chunk, block_w=bw, interpret=True)
        hr = rglru_scan_ref(log_a, b)
        scale = float(jnp.max(jnp.abs(hr))) + 1e-9
        assert float(jnp.max(jnp.abs(hk - hr))) / scale < 1e-5

    def test_recurrentgemma_forward_with_pallas(self):
        """Full hybrid model forward with the RG-LRU kernel engaged."""
        import dataclasses
        from repro import configs
        from repro.models import lm
        cfg = configs.get_config("recurrentgemma-2b", smoke=True)
        cfg = dataclasses.replace(cfg, use_pallas=True, attn_chunk=8)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(1)
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)),
                           jnp.int32)
        ref_cfg = dataclasses.replace(cfg, use_pallas=False)
        lo_k, _ = lm.forward(params, cfg, toks)
        lo_r, _ = lm.forward(params, ref_cfg, toks)
        err = float(jnp.max(jnp.abs(lo_k - lo_r)))
        assert err < 2e-3, err
