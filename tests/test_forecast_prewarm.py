"""Interruption-forecast pre-warming: standby mechanics in the
ClusterManager, the ForecastPrewarmStrategy's hazard loop, and the
benchmark's two acceptance claims — strictly lower spin-up gap and no
higher cost than reactive warning handling on the spiky price trace —
with the strategy living entirely outside `fl/engines/` and `cloud/`.
"""
import pytest

from benchmarks.forecast_prewarm import (CLIENTS, compare,
                                         register_policies, run_policy,
                                         spinup_gap_s)
from repro.cloud.simulator import (RUNNING, SPINNING_UP, TERMINATED,
                                   CloudSimulator)
from repro.common.config import ClientProfile, CloudConfig
from repro.core.events import ClientReady
from repro.core.policies import POLICIES, get_policy
from repro.core.strategy import ForecastPrewarmStrategy
from repro.fl.cluster import ClusterManager

CLOUD = CloudConfig(spot_rate_sigma=0.0, spin_up_sigma=0.0)


def make_cluster(policy="spot"):
    sim = CloudSimulator(CLOUD, seed=0)
    profiles = {"x": ClientProfile("x", 100.0)}
    cluster = ClusterManager(sim, get_policy(policy), profiles)
    return sim, cluster


# ---------------------------------------------------------------------------
# Standby mechanics (ClusterManager).
# ---------------------------------------------------------------------------
class TestStandby:
    def test_standby_requires_a_tracked_instance(self):
        sim, cluster = make_cluster()
        assert cluster.request_standby("x") is None
        cluster.request("x")
        sb = cluster.request_standby("x")
        assert sb is not None and cluster.standby_of("x") is sb
        # idempotent: a second request returns the same standby
        assert cluster.request_standby("x") is sb

    def test_standby_ready_publishes_no_client_ready(self):
        sim, cluster = make_cluster()
        seen = []
        sim.bus.subscribe(ClientReady, lambda ev: seen.append(ev))
        cluster.request("x")
        sim.run_until_idle()
        assert len(seen) == 1          # the tracked instance only
        cluster.request_standby("x")
        sim.run_until_idle()
        assert len(seen) == 1          # standby holds silently

    def test_running_standby_promoted_with_resume_token(self):
        sim, cluster = make_cluster()
        seen = []
        sim.bus.subscribe(ClientReady, lambda ev: seen.append(ev))
        primary = cluster.request("x")
        sim.run_until_idle()
        sb = cluster.request_standby("x")
        sim.run_until_idle()
        assert sb.state == RUNNING
        # reclaim the primary; the recovery request promotes the
        # standby and re-announces it immediately
        sim.preempt(primary)
        cluster.request("x", resume_token={"remaining": 42.0})
        t0 = sim.now
        sim.run_until_idle()
        assert cluster.instance_of("x") is sb
        assert cluster.standby_of("x") is None
        promo = seen[-1]
        assert promo.instance is sb
        assert promo.resume_token == {"remaining": 42.0}
        assert promo.t == t0           # zero spin-up gap

    def test_spinning_standby_promoted_keeps_partial_gap(self):
        sim, cluster = make_cluster()
        primary = cluster.request("x")
        sim.run_until_idle()
        sb = cluster.request_standby("x")   # still SPINNING_UP
        assert sb.state == SPINNING_UP
        sim.preempt(primary)
        cluster.request("x", resume_token={"remaining": 1.0})
        assert cluster.instance_of("x") is sb
        sim.run_until_idle()
        assert sb.state == RUNNING          # finishes its boot, tracked

    def test_standby_reclaim_drops_it_silently(self):
        sim, cluster = make_cluster()
        cluster.request("x")
        sim.run_until_idle()
        sb = cluster.request_standby("x")
        sim.run_until_idle()
        assert sim.preempt(sb)
        assert cluster.standby_of("x") is None
        assert cluster.instance_of("x") is not None   # primary fine

    def test_cancel_standby_terminates_it(self):
        sim, cluster = make_cluster()
        cluster.request("x")
        sim.run_until_idle()
        sb = cluster.request_standby("x")
        assert cluster.cancel_standby("x") is sb
        assert sb.state == TERMINATED
        assert cluster.standby_of("x") is None

    def test_shutdown_releases_standbys(self):
        sim, cluster = make_cluster()
        cluster.request("x")
        sim.run_until_idle()
        sb = cluster.request_standby("x")
        sim.run_until_idle()
        cluster.shutdown()
        assert sb.state == TERMINATED and cluster.standby_of("x") is None


# ---------------------------------------------------------------------------
# The acceptance claims, on the pinned spiky-trace scenario.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def results():
    return compare()


class TestForecastPrewarmClaims:
    def test_scenario_exercises_reclaims(self, results):
        assert results["reactive_ckpt"]["n_preemptions"] > 0
        assert results["forecast_prewarm"]["n_preemptions"] > 0

    def test_strictly_lower_spinup_gap(self, results):
        assert results["forecast_prewarm"]["spinup_gap_s"] < \
            results["reactive_ckpt"]["spinup_gap_s"]

    def test_no_higher_cost(self, results):
        assert results["forecast_prewarm"]["total_cost"] <= \
            results["reactive_ckpt"]["total_cost"]

    def test_same_rounds_completed(self, results):
        assert results["forecast_prewarm"]["rounds_completed"] == \
            results["reactive_ckpt"]["rounds_completed"] == 8

    def test_forecast_also_reduces_lost_work(self, results):
        assert results["forecast_prewarm"]["lost_work_s"] <= \
            results["reactive_ckpt"]["lost_work_s"]

    def test_benchmark_main_asserts_pass(self):
        from benchmarks.forecast_prewarm import main
        out = main([])
        assert set(out) == {"reactive_ckpt", "forecast_prewarm"}


class TestStrategyLivesOutsideEnginesAndCloud:
    def test_module_placement(self):
        """Acceptance criterion: the new discipline is implemented
        entirely in the strategy layer — no engine or cloud edits."""
        assert ForecastPrewarmStrategy.__module__ == \
            "repro.core.strategy"

    def test_policies_are_pure_compositions(self):
        register_policies()
        for name in ("reactive_ckpt", "forecast_prewarm"):
            assert POLICIES[name].engine == "sync"
        POLICIES.pop("reactive_ckpt")
        POLICIES.pop("forecast_prewarm")


class TestHazardEstimatorFallback:
    def test_replay_model_gets_price_derived_hazard(self):
        """Under recorded-interruption replay the true reclaim times
        are not observable; the runner estimates the hazard from the
        spot price via the price-coupled formula, so the forecast
        strategy still sees the bursts coming."""
        res = run_policy("forecast_prewarm")
        # standbys only exist if the estimated hazard crossed the
        # threshold; their effect is the measured gap reduction
        assert res["spinup_gap_s"] < 1800.0

    def test_gap_metric_ignores_idle_reclaims(self):
        records = [
            {"type": "ClientLost", "client": "a", "t": 100.0},
            # idle reclaim recovery: ready without a resume token
            {"type": "ClientReady", "client": "a", "t": 400.0,
             "resume_token": None},
            {"type": "ClientLost", "client": "a", "t": 1000.0},
            {"type": "ClientReady", "client": "a", "t": 1450.0,
             "resume_token": {"remaining": 5.0}},
        ]
        assert spinup_gap_s(records) == pytest.approx(450.0)
