"""Hypothesis property-based tests on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis "
    "extra (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

# property sweeps take tens of seconds in aggregate; full-suite only
pytestmark = pytest.mark.slow

from repro.common.config import CloudConfig, ClientProfile, FLRunConfig, \
    SchedulerConfig
from repro.core.estimator import EMA
from repro.core.events import (BillingTick, BudgetExhausted, ClientReady,
                               ClientStateChanged, EventBus, InstanceReady,
                               RoundCompleted, RoundStarted, RunCompleted)
from repro.core.eventlog import (EventRecorder, EventReplayer, InstanceRef,
                                 decode_event, encode_event)
from repro.fl.algorithms import weighted_average
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result, state_totals
from repro.kernels.grad_quant.ref import quantize_blocks_ref, \
    dequantize_blocks_ref
from repro.launch.hlo_analysis import _parse_op_line, _type_bytes


# ---------------------------------------------------------------------------
# EMA invariants.
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(1.0, 1e5), min_size=1, max_size=40),
       st.floats(0.01, 0.99))
@settings(max_examples=60, deadline=None)
def test_ema_stays_within_observed_range(obs, alpha):
    e = EMA(alpha)
    for o in obs:
        e.update(o)
    assert min(obs) - 1e-6 <= e.value <= max(obs) + 1e-6


# ---------------------------------------------------------------------------
# Scheduler cost dominance: under zero-jitter profiles, FedCostAware never
# costs more than plain spot (+ small tolerance for cold-start overhead),
# and spot always beats on-demand by the price ratio.
# ---------------------------------------------------------------------------
@given(
    st.lists(st.floats(120.0, 2000.0), min_size=2, max_size=5),
    st.integers(4, 8),
)
@settings(max_examples=12, deadline=None)
def test_policy_cost_ordering(epoch_times, n_epochs):
    clients = tuple(
        ClientProfile(f"c{i}", t, jitter=0.0, cold_multiplier=1.1)
        for i, t in enumerate(epoch_times))
    cloud = CloudConfig(spot_rate_sigma=0.0)
    costs = {}
    for p in ("on_demand", "spot", "fedcostaware"):
        cfg = FLRunConfig(dataset="x", clients=clients, n_epochs=n_epochs,
                          policy=p, seed=1)
        costs[p] = FLCloudRunner(cfg, cloud_cfg=cloud).run().total_cost
    assert costs["spot"] < costs["on_demand"]
    # FCA may add cold-start overhead on very homogeneous pools; it must
    # never exceed plain spot by more than that small overhead.
    assert costs["fedcostaware"] <= costs["spot"] * 1.10


# ---------------------------------------------------------------------------
# FedAvg invariants.
# ---------------------------------------------------------------------------
@given(st.integers(1, 5), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_weighted_average_convexity(n, seed):
    rng = np.random.RandomState(seed)
    trees = [{"w": jnp.asarray(rng.randn(4), jnp.float32)}
             for _ in range(n)]
    weights = rng.rand(n) + 0.1
    avg = weighted_average(trees, list(weights))
    stacked = np.stack([np.asarray(t["w"]) for t in trees])
    lo, hi = stacked.min(0), stacked.max(0)
    a = np.asarray(avg["w"])
    assert np.all(a >= lo - 1e-5) and np.all(a <= hi + 1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_weighted_average_identity(seed):
    rng = np.random.RandomState(seed)
    t = {"w": jnp.asarray(rng.randn(8), jnp.float32)}
    avg = weighted_average([t, t, t], [1.0, 2.0, 5.0])
    np.testing.assert_allclose(np.asarray(avg["w"]), np.asarray(t["w"]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# int8 quantization error bound: |x - deq(q(x))| <= amax/127 per block.
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1), st.floats(1e-6, 1e3))
@settings(max_examples=40, deadline=None)
def test_quant_roundtrip_bound(seed, scale):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, 256) * scale, jnp.float32)
    q, s = quantize_blocks_ref(x)
    xd = dequantize_blocks_ref(q, s)
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    err = np.abs(np.asarray(xd) - np.asarray(x))
    assert np.all(err <= amax / 127.0 + 1e-7)


# ---------------------------------------------------------------------------
# HLO parser robustness: arbitrary identifiers / shapes round-trip.
# ---------------------------------------------------------------------------
@given(st.sampled_from(["f32", "bf16", "s32", "s8", "pred"]),
       st.lists(st.integers(1, 512), min_size=0, max_size=4))
@settings(max_examples=50, deadline=None)
def test_type_bytes(dtype, dims):
    sizes = {"f32": 4, "bf16": 2, "s32": 4, "s8": 1, "pred": 1}
    t = f"{dtype}[{','.join(map(str, dims))}]"
    n = 1
    for d in dims:
        n *= d
    assert _type_bytes(t) == n * sizes[dtype]


@given(st.integers(1, 4096), st.integers(1, 4096))
@settings(max_examples=30, deadline=None)
def test_parse_op_line_dot(m, n):
    line = (f"  %dot.5 = f32[{m},{n}]{{1,0}} dot(%a, %b), "
            f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}")
    parsed = _parse_op_line(line)
    assert parsed is not None
    name, type_str, opcode, rest = parsed
    assert opcode == "dot" and _type_bytes(type_str) == m * n * 4


# ---------------------------------------------------------------------------
# Event-log round-trip losslessness: any sequence of randomly generated
# events survives publish -> record -> JSONL -> parse -> replay ->
# re-record with identical encoded records.
# ---------------------------------------------------------------------------
_t = st.floats(0.0, 1e7, allow_nan=False, allow_infinity=False)
_money = st.floats(0.0, 1e4, allow_nan=False, allow_infinity=False)
_client = st.sampled_from(["a", "b", "c", "d"])
_state = st.sampled_from(["spinup", "training", "idle", "savings", "done"])
_participants = st.lists(_client, max_size=4, unique=True).map(tuple)
_costs = st.dictionaries(_client, _money, max_size=4)

_instance = st.builds(
    InstanceRef,
    iid=st.integers(1, 10_000), client=_client,
    zone=st.sampled_from(["z0", "z1", "z2"]), on_demand=st.booleans(),
    t_request=_t, t_ready=st.none() | _t, t_end=st.none() | _t,
    state=st.sampled_from(["spinning_up", "running", "terminated",
                           "preempted"]))

_event = st.one_of(
    st.builds(ClientStateChanged, t=_t, client=_client, state=_state),
    st.builds(BudgetExhausted, t=_t, client=_client),
    st.builds(RoundStarted, t=_t, round_idx=st.integers(0, 100),
              participants=_participants),
    st.builds(RoundCompleted, t=_t, round_idx=st.integers(0, 100),
              participants=_participants, client_costs=_costs),
    st.builds(RunCompleted, t=_t, makespan_s=_t, total_cost=_money,
              client_costs=_costs, rounds_completed=st.integers(0, 100),
              excluded_clients=_participants,
              final_round_idx=st.integers(-1, 100)),
    st.builds(InstanceReady, t=_t, instance=_instance),
    st.builds(BillingTick, t=_t, instance=_instance, client=_client,
              t0=_t, t1=_t, amount=_money),
    st.builds(ClientReady, t=_t, client=_client, instance=_instance,
              cold=st.booleans(),
              resume_token=st.none() | st.fixed_dictionaries(
                  {"round": st.integers(0, 100), "remaining": _money})),
)


@given(st.lists(_event, min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_eventlog_jsonl_roundtrip_lossless(events):
    bus = EventBus()
    rec = EventRecorder(bus, meta={"dataset": "prop", "seed": 0})
    for ev in events:
        bus.publish(ev)
    text = rec.dumps()
    replayer = EventReplayer.loads(text)
    assert replayer.header == rec.header
    out_bus = EventBus()
    rerec = EventRecorder(out_bus)
    replayer.replay(out_bus)
    assert rerec.records == rec.records
    # a second serialize -> parse cycle is byte-stable
    rerec.header = rec.header
    assert rerec.dumps() == text


@given(_event)
@settings(max_examples=120, deadline=None)
def test_encode_decode_single_event_identity(ev):
    rec = encode_event(ev)
    assert encode_event(decode_event(rec)) == rec


# ---------------------------------------------------------------------------
# Live vs replayed runs agree across random preemption seeds: cost
# totals and per-(client, state) timeline sums within 1e-9.
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000), st.floats(0.0, 2.0),
       st.sampled_from(["fedcostaware", "fedcostaware_async"]))
@settings(max_examples=10, deadline=None)
def test_live_vs_replayed_run_agree(seed, preempt_rate, policy):
    clients = (
        ClientProfile("slow", 800, jitter=0.0, n_samples=2),
        ClientProfile("fast", 200, jitter=0.0, n_samples=1),
    )
    cloud = CloudConfig(spot_rate_sigma=0.0,
                        preemption_rate_per_hr=preempt_rate)
    cfg = FLRunConfig(dataset="prop", clients=clients, n_epochs=4,
                      policy=policy, seed=seed)
    runner = FLCloudRunner(cfg, cloud_cfg=cloud, record=True)
    live = runner.run()
    rep = replay_result(EventReplayer.loads(runner.recorder.dumps()))
    assert abs(rep.total_cost - live.total_cost) < 1e-9
    for c in live.per_client_cost:
        assert abs(rep.per_client_cost[c] - live.per_client_cost[c]) < 1e-9
    lt, rt = state_totals(live.timeline), state_totals(rep.timeline)
    assert set(lt) == set(rt)
    for k in lt:
        assert abs(lt[k] - rt[k]) < 1e-9
    assert abs(rep.makespan_s - live.makespan_s) < 1e-9


def test_parse_op_line_tuple_type_with_comment():
    line = ("  %while.1 = (s32[], bf16[2,3]{1,0}, /*index=5*/ f32[4]{0}) "
            "while(%t), condition=%c.1, body=%b.2")
    name, type_str, opcode, rest = _parse_op_line(line)
    assert opcode == "while"
    assert "condition=%c.1" in rest
    assert _type_bytes(type_str) == 4 + 12 + 16

# ---------------------------------------------------------------------------
# Comms payload accounting vs the real quantizer: the billed egress
# bytes are exactly the wire bytes `grad_quant.ops.quantize` produces,
# and the ops-level roundtrip (flatten + pad to BLOCK rows) keeps the
# per-leaf error inside the int8 step for every shape and dtype —
# non-block-multiple sizes included.
# ---------------------------------------------------------------------------
_QUANT_SHAPES = [(1,), (3,), (17,), (255,), (2048,), (2049,),
                 (7, 11), (5, 512), (3, 1024), (4097,)]


@given(st.sampled_from(_QUANT_SHAPES),
       st.sampled_from(["float32", "bfloat16"]),
       st.integers(0, 2**31 - 1), st.floats(1e-4, 1e2))
@settings(max_examples=40, deadline=None)
def test_ops_quant_roundtrip_bounded_and_bytes_exact(shape, dtype,
                                                     seed, scale):
    from repro.comms.payload import quantized_leaf_bytes
    from repro.kernels.grad_quant import ops as gq
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(*shape) * scale, dtype)
    q, s = gq.quantize(x, use_pallas=False)
    y = gq.dequantize(q, s, shape, dtype, use_pallas=False)
    assert y.shape == x.shape and y.dtype == x.dtype
    xf = np.asarray(x, np.float32)
    err = np.abs(np.asarray(y, np.float32) - xf)
    amax = np.abs(xf).max()
    # int8 step (amax/254 rounding x2 for a low-precision scale) plus
    # the output dtype's own rounding (2^-9 relative for bf16)
    assert np.all(err <= amax * (1.0 / 127.0 + 1.0 / 256.0) + 1e-6)
    wire = q.size * q.dtype.itemsize + s.size * s.dtype.itemsize
    n = int(np.prod(shape))
    assert wire == quantized_leaf_bytes(n)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_pytree_quant_payload_accounting_exact(seed):
    """`UpdatePayload.from_tree(quantized=True)` equals the summed wire
    size of every leaf's real quantized arrays — billed egress is the
    true upload, padding overhead included."""
    from repro.comms.payload import UpdatePayload
    from repro.kernels.grad_quant import ops as gq
    rng = np.random.RandomState(seed)
    tree = {"w": jnp.asarray(rng.randn(9, 33), jnp.float32),
            "b": jnp.asarray(rng.randn(5), jnp.float32),
            "deep": [jnp.asarray(rng.randn(2049), jnp.float32)]}
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        q, s = gq.quantize(leaf, use_pallas=False)
        total += q.size * q.dtype.itemsize + s.size * s.dtype.itemsize
        y = gq.dequantize(q, s, tuple(leaf.shape), jnp.float32,
                          use_pallas=False)
        amax = float(jnp.max(jnp.abs(leaf)))
        assert float(jnp.max(jnp.abs(y - leaf))) <= amax / 127.0 + 1e-6
    assert UpdatePayload.from_tree(tree, quantized=True).num_bytes == total


# ---------------------------------------------------------------------------
# Cost-report audit: any recorded run summarizes to the replayed
# dollars and reconciles exactly (tests/test_report.py pins the golden
# traces; this sweeps random configs through the same invariant).
# ---------------------------------------------------------------------------
@given(
    st.lists(st.floats(120.0, 1500.0), min_size=2, max_size=4),
    st.sampled_from(["fedcostaware", "spot", "on_demand",
                     "fedcostaware_async"]),
    st.integers(0, 2**16),
    st.integers(2, 4),
    st.one_of(st.none(), st.floats(1.0, 16.0)),
)
@settings(max_examples=10, deadline=None)
def test_cost_report_audits_any_recorded_run(epoch_times, policy, seed,
                                             n_epochs, payload_mb):
    """For arbitrary (clients, policy, seed, rounds, comms payload):
    the report CLI's summary category totals and per-client rows equal
    the live `RunResult` dollars to 1e-9, and `reconcile` passes."""
    import tempfile
    from pathlib import Path

    from repro.cloud.report import reconcile_path, summarize_path
    from repro.common.config import MarketConfig, ProviderConfig

    clients = tuple(
        ClientProfile(f"c{i}", t, jitter=0.1, cold_multiplier=1.1)
        for i, t in enumerate(epoch_times))
    market = MarketConfig(providers=(ProviderConfig(
        name="aws", update_egress_usd_per_mb=0.001,
        uplink_mbps=100.0),))
    cfg = FLRunConfig(dataset="prop_report", clients=clients,
                      n_epochs=n_epochs, policy=policy, seed=seed,
                      update_payload_mb=payload_mb)
    runner = FLCloudRunner(cfg, cloud_cfg=CloudConfig(market=market),
                           record=True)
    res = runner.run()
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "run.events.jsonl"
        runner.recorder.dump(path)
        s = summarize_path(path)
        rec = reconcile_path(path)
    t = s["totals"]
    assert t["total"] == pytest.approx(res.total_cost, abs=1e-9)
    assert t["checkpoint"] == pytest.approx(res.checkpoint_cost,
                                            abs=1e-9)
    assert t["egress"] == pytest.approx(res.comm_cost, abs=1e-9)
    if payload_mb is not None:
        assert t["egress"] > 0.0
    assert set(s["per_client"]) == set(res.per_client_cost)
    for c, row in s["per_client"].items():
        assert row["total"] == pytest.approx(res.per_client_cost[c],
                                             abs=1e-9)
    assert rec.ok, rec.first_divergence
    assert abs(rec.delta) <= 1e-9


# ---------------------------------------------------------------------------
# Forecasting subsystem invariants (repro.forecast).
# ---------------------------------------------------------------------------
_obs_event = st.tuples(
    st.sampled_from(["price", "reclaim"]),
    st.floats(0.05, 2.0),          # price level (ignored by reclaims)
)


@given(st.sampled_from(["ewma", "quantile"]),
       st.lists(_obs_event, min_size=1, max_size=80),
       st.integers(0, 3))
@settings(max_examples=40, deadline=None)
def test_forecaster_determinism(kind, events, seed):
    """Identically-constructed forecasters fed the identical
    observation stream answer identically — no hidden randomness, so
    recorded runs replay bit-for-bit."""
    from repro.forecast import make_forecaster
    a = make_forecaster(kind, seed=seed)
    b = make_forecaster(kind, seed=seed)
    t = 0.0
    for what, price in events:
        t += 30.0
        for f in (a, b):
            if what == "price":
                f.observe_price("aws", "z1", t, price)
            else:
                f.observe_reclaim("aws", "z1", t)
    assert a.hazard_per_hr("aws", "z1", t) == \
        b.hazard_per_hr("aws", "z1", t)
    assert a.interruption_probability("aws", "z1", t, 600.0) == \
        b.interruption_probability("aws", "z1", t, 600.0)
    assert a.price_quantiles("aws", "z1") == \
        b.price_quantiles("aws", "z1")


@given(st.floats(120.0, 7200.0), st.floats(0.05, 0.6),
       st.integers(30, 120))
@settings(max_examples=40, deadline=None)
def test_ewma_hazard_converges_to_true_rate(gap_s, alpha, n):
    """Perfectly regular reclaims with gap g drive the EWMA hazard to
    exactly 3600/g — the estimator is consistent on its own model."""
    from repro.forecast import HazardEwmaForecaster
    f = HazardEwmaForecaster(base_rate_per_hr=0.1, alpha=alpha)
    f.observe_price("aws", "z1", 0.0, 0.30)
    for i in range(1, n + 1):
        f.observe_reclaim("aws", "z1", i * gap_s)
    assert f.hazard_per_hr("aws", "z1", n * gap_s) == \
        pytest.approx(3600.0 / gap_s, rel=1e-6)


@given(st.integers(0, 10_000), st.floats(0.02, 0.08),
       st.floats(0.01, 0.05))
@settings(max_examples=15, deadline=None)
def test_quantile_band_coverage_on_ou_prices(seed, sigma, lr):
    """On a synthetic Ornstein-Uhlenbeck price stream the learned
    (0.1, 0.9) band, scored online by the CalibrationTracker exactly
    as the strategy scores it, covers roughly its nominal 80% mass —
    well away from both the degenerate 0 and the vacuous 1."""
    from repro.forecast import CalibrationTracker, QuantileForecaster
    rng = np.random.default_rng(seed)
    mu, theta, dt = 0.40, 0.05, 1.0
    f = QuantileForecaster(lr=lr)
    cal = CalibrationTracker()
    x = mu
    for i in range(1500):
        x += theta * (mu - x) * dt + sigma * math.sqrt(dt) * \
            rng.standard_normal()
        x = max(x, 0.01)
        q = f.price_quantiles("aws", "z1")
        if q is not None and i > 500:     # score after burn-in only
            cal.note_band("aws", "z1", q[0.1], q[0.9])
            cal.observe_price("aws", "z1", 30.0 * i, x)
        f.observe_price("aws", "z1", 30.0 * i, x)
    assert 0.5 <= cal.coverage() <= 0.98
