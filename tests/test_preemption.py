"""Preemption-realism subsystem tests: the pluggable reclaim models
(`repro.cloud.preemption`), recorded-interruption ingestion
(`repro.cloud.traces`), and the engines' notice-aware checkpointing
path — including the warning-window edge cases:

  * warning published, then the instance is terminated before the
    reclaim lands -> the reclaim is a no-op;
  * a zero-notice provider never publishes a warning, so "checkpoint"
    engines silently degrade to lost-work semantics;
  * a notice window too short for the checkpoint write falls back to
    periodic-checkpoint (lost-work) semantics.
"""
import numpy as np
import pytest

from repro.checkpoint import snapshots
from repro.checkpoint.store import MemoryStore
from repro.cloud.preemption import (MODEL_NAMES, ConstantRateModel,
                                    CorrelatedReclaimModel,
                                    PriceCoupledModel,
                                    ReplayInterruptionModel,
                                    build_preemption_model)
from repro.cloud.pricing import SpotMarket, TracePriceSource, Zone, Provider
from repro.cloud.simulator import RUNNING, CloudSimulator
from repro.cloud.traces import (TraceFormatError,
                                build_interruption_schedule,
                                is_interruption_trace,
                                parse_interruption_file, validate_dir)
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 MarketConfig, ProviderConfig,
                                 SchedulerConfig)
from repro.core.eventlog import decode_event, encode_event
from repro.core.events import (ClientCheckpointed, ClientLost,
                               ClientPreemptionWarning,
                               ClientResumedFromCheckpoint,
                               InstancePreempted,
                               InstancePreemptionWarning)
from repro.fl.runner import FLCloudRunner

from pathlib import Path

FIXTURE_PRICES = Path(__file__).parent / "fixtures" / "prices"


def flat_market(notice_s=0.0, sensitivity=1.0):
    """One provider, one zone, constant price 0.40."""
    m = SpotMarket([Provider("p", on_demand_rate=1.0,
                             preemption_notice_s=notice_s,
                             preemption_price_sensitivity=sensitivity)])
    m.add_zone(Zone("z1", "r1", "p"),
               TracePriceSource([0.0], [0.40]))
    return m


class FakeInst:
    def __init__(self, zone="z1", provider="p"):
        self.zone, self.provider = zone, provider


# ---------------------------------------------------------------------------
# Models.
# ---------------------------------------------------------------------------
class TestConstantRateModel:
    def test_zero_rate_is_never_and_draws_nothing(self):
        rng = np.random.RandomState(0)
        before = rng.get_state()[1].copy()
        assert ConstantRateModel(0.0).next_preemption_delay(
            FakeInst(), 0.0, rng) is None
        assert np.array_equal(rng.get_state()[1], before)

    def test_matches_legacy_inline_draw(self):
        """Exact arithmetic of the pre-model code: one exponential at
        1 / (rate_per_hr / 3600)."""
        d = ConstantRateModel(2.0).next_preemption_delay(
            FakeInst(), 0.0, np.random.RandomState(7))
        want = float(np.random.RandomState(7).exponential(
            1.0 / (2.0 / 3600.0)))
        assert d == want


class TestPriceCoupledModel:
    def _spiky_market(self, s=5.0):
        m = SpotMarket([Provider("p", on_demand_rate=1.0,
                                 preemption_price_sensitivity=s)])
        # 0.30 base with a 0.90 spike in [1000, 2000)
        m.add_zone(Zone("z1", "r1", "p"),
                   TracePriceSource([0.0, 1000.0, 2000.0],
                                    [0.30, 0.90, 0.30]))
        return m

    def test_hazard_scales_with_price(self):
        # s=1: hazard is directly proportional to the price level
        # (mean price over the horizon is 0.60: half base, half spike)
        model = PriceCoupledModel(self._spiky_market(s=1.0), 1.0)
        low = model.hazard("p", "z1", 500.0)
        high = model.hazard("p", "z1", 1500.0)
        assert high > low > 0.0
        assert high / low == pytest.approx(3.0)   # 0.90 vs 0.30

    def test_zero_sensitivity_decouples(self):
        model = PriceCoupledModel(self._spiky_market(s=0.0), 1.0)
        base = 1.0 / 3600.0
        assert model.hazard("p", "z1", 500.0) == pytest.approx(base)
        assert model.hazard("p", "z1", 1500.0) == pytest.approx(base)

    def test_hazard_clamped_at_zero(self):
        # huge sensitivity + below-reference price -> clamp, not negative
        model = PriceCoupledModel(self._spiky_market(s=100.0), 1.0)
        assert model.hazard("p", "z1", 500.0) == 0.0

    def test_zero_base_rate_never_preempts(self):
        model = PriceCoupledModel(self._spiky_market(), 0.0)
        assert model.next_preemption_delay(
            FakeInst(), 0.0, np.random.RandomState(0)) is None

    def test_delays_are_deterministic_per_seed(self):
        model = PriceCoupledModel(self._spiky_market(), 5.0)
        a = model.next_preemption_delay(FakeInst(), 0.0,
                                        np.random.RandomState(3))
        b = model.next_preemption_delay(FakeInst(), 0.0,
                                        np.random.RandomState(3))
        assert a == b and a is not None


class TestReplayInterruptionModel:
    def _market(self):
        m = flat_market()
        m.add_interruptions("p", "z1", [3000.0, 1000.0])  # any order
        return m

    def test_next_recorded_time(self):
        model = ReplayInterruptionModel(self._market())
        assert model.next_preemption_delay(
            FakeInst(), 0.0, None) == 1000.0
        assert model.next_preemption_delay(
            FakeInst(), 1500.0, None) == 1500.0   # 3000 - 1500

    def test_strictly_after_now(self):
        """An instance becoming ready at the reclaim instant survives
        it (the reclaim already happened)."""
        model = ReplayInterruptionModel(self._market())
        assert model.next_preemption_delay(
            FakeInst(), 1000.0, None) == 2000.0

    def test_exhausted_schedule_is_never(self):
        model = ReplayInterruptionModel(self._market())
        assert model.next_preemption_delay(
            FakeInst(), 5000.0, None) is None

    def test_zone_without_schedule_is_never(self):
        model = ReplayInterruptionModel(flat_market())
        assert model.next_preemption_delay(
            FakeInst(), 0.0, None) is None


class TestBuildModel:
    def test_registry(self):
        m = flat_market()
        assert isinstance(build_preemption_model(
            CloudConfig(preemption_model="constant"), m),
            ConstantRateModel)
        assert isinstance(build_preemption_model(
            CloudConfig(preemption_model="price_coupled"), m),
            PriceCoupledModel)
        assert isinstance(build_preemption_model(
            CloudConfig(preemption_model="replay"), m),
            ReplayInterruptionModel)
        assert isinstance(build_preemption_model(
            CloudConfig(preemption_model="correlated"), m),
            CorrelatedReclaimModel)

    def test_registry_names_are_exhaustive(self):
        m = flat_market()
        for name in MODEL_NAMES:
            build_preemption_model(CloudConfig(preemption_model=name), m)

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError, match="unknown preemption model"):
            build_preemption_model(
                CloudConfig(preemption_model="nope"), flat_market())


# ---------------------------------------------------------------------------
# Interruption-trace ingestion.
# ---------------------------------------------------------------------------
class TestInterruptionTraces:
    def test_parse_fixture(self):
        recs = parse_interruption_file(
            FIXTURE_PRICES / "aws.interruptions.csv")
        assert len(recs) == 3
        assert recs[0].zone == "us-east-1a"

    def test_schedule_uses_market_epoch(self):
        recs = parse_interruption_file(
            FIXTURE_PRICES / "aws.interruptions.csv")
        # aws.csv's earliest record is 2024-03-01T00:00:00Z; the first
        # recorded reclaim is 11m40s after it
        epoch = min(r.timestamp for r in recs) - 700.0
        sched = build_interruption_schedule(recs, epoch=epoch)
        assert sched["us-east-1a"][0] == pytest.approx(700.0)

    def test_jsonl_parses(self, tmp_path):
        p = tmp_path / "x.interruptions.jsonl"
        p.write_text('{"Timestamp": "2024-03-01T00:00:10Z", '
                     '"AvailabilityZone": "za", '
                     '"InstanceType": "g5.xlarge"}\n')
        recs = parse_interruption_file(p)
        assert len(recs) == 1 and recs[0].zone == "za"

    def test_malformed_row_raises_with_location(self, tmp_path):
        p = tmp_path / "bad.interruptions.csv"
        p.write_text("Timestamp,AvailabilityZone,InstanceType\n"
                     "not-a-time,za,g5.xlarge\n")
        with pytest.raises(TraceFormatError, match="bad.interruptions"
                                                  ".csv:2"):
            parse_interruption_file(p)

    def test_naming_convention(self):
        assert is_interruption_trace("aws.interruptions.csv")
        assert is_interruption_trace("x/y/gcp.interruptions.jsonl")
        assert not is_interruption_trace("aws.csv")

    def test_validate_dir_routes_both_kinds(self):
        lines = validate_dir(FIXTURE_PRICES)
        assert any("interruptions" in ln for ln in lines)
        assert any("span" in ln for ln in lines)

    def test_market_config_loads_interruptions(self):
        market = SpotMarket.from_market_config(MarketConfig(providers=(
            ProviderConfig(
                name="aws",
                price_trace=str(FIXTURE_PRICES / "aws.csv"),
                interruption_trace=str(
                    FIXTURE_PRICES / "aws.interruptions.csv")),)))
        assert market.interruptions[("aws", "us-east-1a")] == \
            (700.0, 30000.0)
        assert market.interruptions[("aws", "us-east-1b")] == (20000.0,)


# ---------------------------------------------------------------------------
# Simulator-level edge cases.
# ---------------------------------------------------------------------------
def notice_cloud(notice_s=120.0, rate=50.0, model="constant"):
    return CloudConfig(
        spot_rate_sigma=0.0, spin_up_sigma=0.0, preemption_rate_per_hr=rate,
        preemption_model=model,
        market=MarketConfig(providers=(ProviderConfig(
            name="aws", spot_rate_sigma=0.0, n_zones=1,
            preemption_notice_s=notice_s),)))


class TestWarningEdgeCasesSimulator:
    def test_terminate_after_warning_makes_reclaim_noop(self):
        sim = CloudSimulator(notice_cloud(), seed=1)
        warns, reclaims = [], []
        sim.bus.subscribe(InstancePreemptionWarning, warns.append)
        sim.bus.subscribe(InstancePreempted, reclaims.append)
        inst = sim.request_instance("c")
        # stop exactly at the warning, act on it, then drain fully
        sim.run_until_idle(t_max=0.0)
        while not warns:
            t = sim._heap[0][0]
            sim.run_until_idle(t_max=t)
        sim.terminate(inst)
        sim.run_until_idle()
        assert len(warns) == 1 and reclaims == []
        assert inst.state == "terminated"
        assert inst.cost > 0.0                  # billed exactly once

    def test_replay_model_preempts_at_recorded_time(self):
        cloud = CloudConfig(
            spot_rate_sigma=0.0, spin_up_sigma=0.0,
            preemption_model="replay",
            market=MarketConfig(providers=(ProviderConfig(
                name="aws",
                price_trace=str(FIXTURE_PRICES / "aws.csv"),
                interruption_trace=str(
                    FIXTURE_PRICES / "aws.interruptions.csv")),)))
        sim = CloudSimulator(cloud, seed=0)
        hits = []
        sim.bus.subscribe(InstancePreempted, hits.append)
        sim.request_instance("c", zone="us-east-1a")
        sim.run_until_idle(t_max=3600.0)
        assert len(hits) == 1
        assert hits[0].t == pytest.approx(700.0)


# ---------------------------------------------------------------------------
# Engine-level notice handling.
# ---------------------------------------------------------------------------
CLIENTS = (ClientProfile("a", mean_epoch_s=900.0, jitter=0.0,
                         cold_multiplier=1.0, zone="us-east-1a"),)
SCHED = SchedulerConfig(checkpoint_every_s=600.0,
                        warning_ckpt_write_s=10.0)


def replay_cloud(notice_s):
    """aws.csv market + the recorded reclaim at t=700 (mid-epoch: spin
    up at 150, training to 1050)."""
    return CloudConfig(
        spot_rate_sigma=0.0, spin_up_sigma=0.0, preemption_model="replay",
        market=MarketConfig(providers=(ProviderConfig(
            name="aws", preemption_notice_s=notice_s,
            price_trace=str(FIXTURE_PRICES / "aws.csv"),
            interruption_trace=str(
                FIXTURE_PRICES / "aws.interruptions.csv")),)))


def run_notice(mode, notice_s=120.0, policy="spot", n_epochs=2):
    cfg = FLRunConfig(dataset="t", clients=CLIENTS, n_epochs=n_epochs,
                      policy=policy, seed=0, on_warning=mode)
    runner = FLCloudRunner(cfg, cloud_cfg=replay_cloud(notice_s),
                           sched_cfg=SCHED)
    seen = {"warn": [], "ckpt": [], "resume": [], "lost": []}
    runner.bus.subscribe(ClientPreemptionWarning, seen["warn"].append)
    runner.bus.subscribe(ClientCheckpointed, seen["ckpt"].append)
    runner.bus.subscribe(ClientResumedFromCheckpoint,
                         seen["resume"].append)
    runner.bus.subscribe(ClientLost, seen["lost"].append)
    res = runner.run()
    return res, seen, runner


class TestNoticeAwareEngines:
    def test_ignore_loses_work_since_periodic_checkpoint(self):
        res, seen, _ = run_notice("ignore")
        assert len(seen["lost"]) == 1 and not seen["ckpt"]
        # reclaim at 700, training started at 150 -> 550 elapsed, and
        # the 600 s periodic cadence preserved nothing
        assert res.lost_work_s == pytest.approx(550.0)
        assert res.rounds_completed == 2

    def test_checkpoint_resumes_from_warning_snapshot(self):
        res, seen, _ = run_notice("checkpoint")
        assert len(seen["ckpt"]) == 1 and len(seen["resume"]) == 1
        ck = seen["ckpt"][0]
        # warning at 580 = 430 into the epoch; 470 owed after resume
        assert ck.progress_s == pytest.approx(430.0)
        assert ck.remaining_s == pytest.approx(470.0)
        assert seen["resume"][0].remaining_s == pytest.approx(470.0)
        # only the write-window work (and nothing else) is redone
        assert res.lost_work_s == pytest.approx(120.0)
        assert res.rounds_completed == 2

    def test_checkpoint_beats_ignore_on_cost_and_lost_work(self):
        ign, _, _ = run_notice("ignore")
        ck, _, _ = run_notice("checkpoint")
        assert ck.lost_work_s < ign.lost_work_s
        assert ck.total_cost < ign.total_cost

    def test_drain_terminates_before_reclaim(self):
        res, seen, _ = run_notice("drain")
        assert len(seen["ckpt"]) == 1 and len(seen["resume"]) == 1
        assert seen["lost"] == []               # reclaim found nothing
        assert res.n_preemptions == 0
        assert res.lost_work_s == pytest.approx(10.0)  # the write window
        assert res.rounds_completed == 2

    def test_zero_notice_provider_never_warns(self):
        res, seen, _ = run_notice("checkpoint", notice_s=0.0)
        assert seen["warn"] == [] and seen["ckpt"] == []
        # degrades to exactly the lost-work semantics
        assert res.lost_work_s == pytest.approx(550.0)
        assert res.rounds_completed == 2

    def test_window_too_short_falls_back_to_lost_work(self):
        # 5 s notice < 10 s write: warning fires but no snapshot lands
        res, seen, _ = run_notice("checkpoint", notice_s=5.0)
        assert len(seen["warn"]) == 1 and seen["ckpt"] == []
        assert res.lost_work_s == pytest.approx(550.0)
        assert res.rounds_completed == 2

    def test_async_engine_checkpoint_path(self):
        res, seen, _ = run_notice("checkpoint",
                                  policy="fedcostaware_async")
        assert len(seen["ckpt"]) == 1 and len(seen["resume"]) == 1
        assert res.lost_work_s == pytest.approx(120.0)

    def test_snapshot_lands_in_store(self):
        _, seen, runner = run_notice("checkpoint")
        data = snapshots.load_snapshot(runner.ckpt_store, "a")
        assert data is not None
        assert data["remaining"] == pytest.approx(470.0)

    def test_unknown_on_warning_mode_rejected(self):
        cfg = FLRunConfig(dataset="t", clients=CLIENTS, n_epochs=1,
                          policy="spot", on_warning="checkpointing")
        with pytest.raises(ValueError, match="unknown on_warning"):
            FLCloudRunner(cfg, cloud_cfg=replay_cloud(120.0))

    def test_epoch_rollover_during_write_discards_snapshot(self):
        """The snapshot completion must not pair the old epoch's
        progress with a new epoch that started on the same warm
        instance during the write window: the stale snapshot would let
        the resume skip work that was never performed."""
        # short epoch ending inside the write window: warning at 580,
        # epoch 0 (150 -> 585) ends mid-write, epoch 1 starts at 585
        # on the same instance (fedcostaware_async re-dispatches
        # synchronously), completion fires at 590
        clients = (ClientProfile("a", mean_epoch_s=435.0, jitter=0.0,
                                 cold_multiplier=1.0, zone="us-east-1a"),)
        cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=4,
                          policy="fedcostaware_async", seed=0,
                          on_warning="checkpoint", buffer_k=1)
        runner = FLCloudRunner(cfg, cloud_cfg=replay_cloud(120.0),
                               sched_cfg=SCHED)
        ckpts = []
        runner.bus.subscribe(ClientCheckpointed, ckpts.append)
        res = runner.run()
        # the run's only warning (t=580) straddles the epoch rollover
        # at 585, so its snapshot must be discarded — pairing epoch 0's
        # 430 s progress with epoch 1's duration would produce a
        # remaining of ~5 s and skip ~320 s of never-performed work
        assert ckpts == []
        # the reclaim at 700 recovers via the periodic checkpoint of
        # the *new* epoch: 115 s elapsed, none preserved (600 s cadence)
        assert res.lost_work_s == pytest.approx(115.0)
        assert res.rounds_completed == 4

    def test_drain_moves_peer_prewarm_targets(self):
        """Under the lifecycle-managed policy, drain's recovery must
        push back already-terminated peers' pre-warm targets exactly
        like a reclaim recovery does (§III-D), instead of letting them
        idle at the barrier while the drained client redoes work."""
        clients = (ClientProfile("a", mean_epoch_s=900.0, jitter=0.0,
                                 cold_multiplier=1.0, zone="us-east-1a"),
                   ClientProfile("b", mean_epoch_s=150.0, jitter=0.0,
                                 cold_multiplier=1.0, zone="us-east-1b"))
        def run(mode):
            cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=3,
                              policy="fedcostaware", seed=0,
                              on_warning=mode)
            return FLCloudRunner(cfg, cloud_cfg=replay_cloud(120.0),
                                 sched_cfg=SCHED).run()
        drain, ignore = run("drain"), run("ignore")
        assert drain.rounds_completed == 3
        assert drain.lost_work_s < ignore.lost_work_s
        assert drain.total_cost < ignore.total_cost
        # peer "b" must not sit idle at the barrier while "a" redoes
        # its epoch: its idle time under drain stays at most ignore's
        from repro.fl.telemetry import state_totals
        d_idle = state_totals(drain.timeline).get(("b", "idle"), 0.0)
        i_idle = state_totals(ignore.timeline).get(("b", "idle"), 0.0)
        assert d_idle <= i_idle + 1e-6

    def test_terminated_before_reclaim_is_engine_noop(self):
        """Drain's own terminate races the reclaim: the later
        InstancePreempted for the drained instance must not reach the
        engine (no ClientLost, no double recovery)."""
        res, seen, runner = run_notice("drain")
        preempts = [e for e in runner.sim.event_log
                    if e["kind"] == "preempt"]
        assert preempts == [] and seen["lost"] == []


# ---------------------------------------------------------------------------
# New-event serialization (schema v3 vocabulary).
# ---------------------------------------------------------------------------
class TestCheckpointEventCodec:
    @pytest.mark.parametrize("ev", [
        ClientCheckpointed(5.0, "c1", 2, 430.0, 470.0, 700.0),
        ClientResumedFromCheckpoint(9.0, "c1", 2, 470.0),
    ])
    def test_round_trip(self, ev):
        assert decode_event(encode_event(ev)) == ev


class TestSnapshotStore:
    def test_round_trip_and_delete(self):
        store = MemoryStore()
        snapshots.save_snapshot(store, "c", {"remaining": 1.5})
        assert snapshots.load_snapshot(store, "c") == {"remaining": 1.5}
        snapshots.delete_snapshot(store, "c")
        assert snapshots.load_snapshot(store, "c") is None
