"""Per-architecture smoke tests (reduced configs, real CPU step) and
model-level invariants (decode == teacher-forced forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

KEY = jax.random.PRNGKey(0)

# the heaviest reduced configs (>5 s apiece on CPU) run in the
# full-suite profile only; the remaining architectures keep per-family
# coverage in the fast tier-1 profile
SLOW_ARCHS = {"recurrentgemma-2b", "llama-3.2-vision-90b", "mamba2-1.3b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow)
               if a in SLOW_ARCHS else a for a in configs.ARCH_IDS]


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.family == "audio":
        toks = jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.float32)
    else:
        toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks,
             "labels": jnp.asarray(
                 rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["cond"] = jnp.asarray(
            rng.randn(B, cfg.n_cond_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on the reduced config: output shapes
    correct, loss finite, no NaNs anywhere."""
    cfg = configs.get_config(arch, smoke=True)
    params = lm.init_params(cfg, KEY)
    batch = make_batch(cfg)
    B, S = batch["labels"].shape

    logits, aux = lm.forward(params, cfg, batch["tokens"],
                             cond=batch.get("cond"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_decode_matches_forward(arch):
    """Greedy decode with cache reproduces the teacher-forced logits —
    the core KV-cache/state-correctness invariant, per family."""
    cfg = configs.get_config(arch, smoke=True)
    params = lm.init_params(cfg, KEY)
    B, S = 2, 16
    batch = make_batch(cfg, B, S, seed=1)
    toks = batch["tokens"]
    full, _ = lm.forward(params, cfg, toks, cond=batch.get("cond"))

    cache = lm.init_cache(cfg, B, S)
    if cfg.family == "vlm":
        cache = _fill_cond_kv(cfg, params, cache, batch["cond"])
    step = jax.jit(lambda p, t, pos, c: lm.decode_step(p, cfg, t, pos, c))
    outs = []
    for t in range(S):
        tok_t = toks[:, t:t + 1]
        lg, cache = step(params, tok_t,
                         jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 2e-3, f"{arch}: decode/forward mismatch {err}"


def _fill_cond_kv(cfg, params, cache, cond):
    from repro.common.config import CROSS_ATTN
    def fill(cblk, pblk, pattern, stacked):
        for i, kind in enumerate(pattern):
            if kind != CROSS_ATTN:
                continue
            key = f"{i:02d}_{kind}"
            wk, wv = pblk[key]["mix"]["wk"], pblk[key]["mix"]["wv"]
            if stacked:
                cblk[key]["cond_k"] = jnp.einsum("btd,ldnh->lbtnh", cond, wk)
                cblk[key]["cond_v"] = jnp.einsum("btd,ldnh->lbtnh", cond, wv)
            else:
                cblk[key]["cond_k"] = jnp.einsum("btd,dnh->btnh", cond, wk)
                cblk[key]["cond_v"] = jnp.einsum("btd,dnh->btnh", cond, wv)
    if "blocks" in cache:
        fill(cache["blocks"], params["blocks"], cfg.pattern, True)
    if "tail" in cache:
        fill(cache["tail"], params["tail"], cfg.tail_pattern, False)
    return cache


def test_param_counts_match_published_sizes():
    """Full configs reproduce the published parameter counts (±10%)."""
    expected = {
        "mamba2-1.3b": 1.3e9, "phi3-mini-3.8b": 3.8e9, "glm4-9b": 9.4e9,
        "qwen1.5-110b": 111e9, "recurrentgemma-2b": 2.1e9,
        "granite-moe-3b-a800m": 3.3e9, "dbrx-132b": 132e9,
        "musicgen-medium": 1.5e9,
    }
    for arch, target in expected.items():
        n = lm.param_count(configs.get_config(arch))
        assert abs(n - target) / target < 0.12, (arch, n, target)


def test_moe_capacity_and_aux_loss():
    from repro.models import layers as L
    cfg = configs.get_config("dbrx-132b", smoke=True)
    params = lm.init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 32, seed=2)
    # Switch-style aux counts all top_k slots: near-uniform routing at
    # init gives ~top_k per layer (E * sum_e (K/E)(1/E) = K).
    _, aux = lm.forward(params, cfg, batch["tokens"])
    per_layer = float(aux) / cfg.num_layers
    k = cfg.moe.top_k
    assert 0.5 * k < per_layer < 2.0 * k, per_layer


def test_tail_pattern_recurrentgemma():
    cfg = configs.get_config("recurrentgemma-2b")
    assert cfg.n_super == 8 and cfg.tail_pattern == ("rglru", "rglru")
    assert cfg.num_layers == 8 * 3 + 2


def test_long_context_skips_rule():
    cells = configs.all_cells()
    assert ("mamba2-1.3b", "long_500k") in cells
    assert ("recurrentgemma-2b", "long_500k") in cells
    assert ("phi3-mini-3.8b", "long_500k") not in cells
    assert len(cells) == 32
    assert len(configs.skipped_cells()) == 8
