"""Monte-Carlo sweep harness (repro.sweep): grid construction,
byte-identical reports across repeated and serial-vs-parallel runs,
bootstrap statistics sanity, and the multiprocessing speedup contract
(slow, multi-core only).
"""
import os
import sys
import time
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from repro.sweep import (MARKETS, ScenarioSpec, bootstrap_ci, build_grid,
                         build_report, market_config, run_cell, run_sweep,
                         summarize)
from repro.sweep.report import cell_key, dumps, hash_seed, ranking_table
from repro.sweep.runner import METRICS
from repro.sweep.spec import MARKET_MODELS

SMALL_GRID = dict(policies=("spot", "fedcostaware"),
                  markets=("baseline", "capacity_crunch"),
                  seeds=range(2))


class TestGrid:
    def test_grid_is_full_cross_product(self):
        specs = build_grid(**SMALL_GRID)
        assert len(specs) == 2 * 2 * 2
        assert len(set(specs)) == len(specs)     # frozen + hashable

    def test_grid_order_is_deterministic(self):
        assert build_grid(**SMALL_GRID) == build_grid(**SMALL_GRID)

    def test_default_models_come_from_registry(self):
        specs = build_grid(**SMALL_GRID)
        for s in specs:
            assert s.preemption_model == MARKET_MODELS[s.market]

    def test_explicit_models_cross_every_market(self):
        specs = build_grid(models=("constant", "price_coupled"),
                           **SMALL_GRID)
        assert len(specs) == 2 * 2 * 2 * 2
        assert {s.preemption_model for s in specs} == {
            "constant", "price_coupled"}

    def test_unknown_market_raises(self):
        with pytest.raises(ValueError, match="unknown sweep market"):
            market_config("mars", seed=0)

    def test_engine_axis_crosses_the_grid(self):
        specs = build_grid(engines=("sync", "async_buffered"),
                           **SMALL_GRID)
        assert len(specs) == 2 * 2 * 2 * 2
        assert {s.engine for s in specs} == {"sync", "async_buffered"}
        # default: the policy's own engine, spelled as ""
        assert all(s.engine == "" for s in build_grid(**SMALL_GRID))

    def test_every_registered_market_builds(self):
        for name in MARKETS:
            cfg = market_config(name, seed=1)
            assert len(cfg.providers) == 2
            if name == "baseline":
                assert cfg.scenario is None
            else:
                assert cfg.scenario.name == name
                assert cfg.scenario.seed == 1


class TestStats:
    def test_bootstrap_ci_brackets_the_mean(self):
        rng = np.random.RandomState(0)
        x = rng.normal(10.0, 2.0, size=30)
        lo, hi = bootstrap_ci(x, seed=5)
        assert lo < x.mean() < hi
        assert hi - lo < 4.0                     # not absurdly wide

    def test_bootstrap_ci_is_seeded(self):
        # continuous data: tiny discrete samples can collide across
        # seeds at the percentile grid
        x = np.random.RandomState(3).normal(10.0, 3.0, size=20)
        assert bootstrap_ci(x, seed=7) == bootstrap_ci(x, seed=7)
        assert bootstrap_ci(x, seed=7) != bootstrap_ci(x, seed=8)

    def test_single_value_collapses(self):
        assert bootstrap_ci([3.5]) == (3.5, 3.5)

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            bootstrap_ci([])

    def test_summarize_fields(self):
        s = summarize([1.0, 2.0, 3.0], seed=0)
        assert set(s) == {"mean", "p10", "p50", "p90", "ci_lo",
                          "ci_hi", "n"}
        assert s["mean"] == pytest.approx(2.0)
        assert s["p50"] == pytest.approx(2.0)
        assert s["n"] == 3
        assert s["ci_lo"] <= s["mean"] <= s["ci_hi"]

    def test_hash_seed_is_stable(self):
        # pinned: must not depend on PYTHONHASHSEED or platform
        assert hash_seed("spot|baseline|price_coupled") == hash_seed(
            "spot|baseline|price_coupled")
        assert hash_seed("a") == ord("a")


class TestRunAndReport:
    @pytest.fixture(scope="class")
    def small(self):
        specs = build_grid(**SMALL_GRID)
        return specs, run_sweep(specs, parallel=False)

    def test_cells_return_all_metrics(self, small):
        _, results = small
        for r in results:
            assert set(r) == set(METRICS)
            assert r["cost"] > 0.0
            assert r["makespan_s"] > 0.0

    def test_run_cell_is_deterministic(self, small):
        specs, results = small
        assert run_cell(specs[0]) == results[0]

    def test_report_is_byte_identical_across_runs(self, small):
        specs, results = small
        a = dumps(build_report(specs, results))
        b = dumps(build_report(specs, run_sweep(specs, parallel=False)))
        assert a == b

    def test_report_shape(self, small):
        specs, results = small
        rep = build_report(specs, results)
        assert sorted(rep["grid"]["policies"]) == ["fedcostaware",
                                                   "spot"]
        assert len(rep["cells"]) == 4            # 2 policies x 2 markets
        for key, cell in rep["cells"].items():
            assert key == cell_key(next(s for s in specs
                                        if cell_key(s) == key))
            assert cell["seeds"] == [0, 1]
            for m in METRICS:
                assert cell[m]["n"] == 2

    def test_engine_override_is_deterministic_and_distinct(self):
        """The engine axis reaches the run: the same (policy, market,
        seed) cell under sync vs async_buffered produces different —
        and individually reproducible — metrics, keyed apart in the
        report."""
        specs = build_grid(policies=("fedcostaware",),
                           markets=("baseline",), seeds=range(2),
                           n_epochs=3, engines=("sync", "async_buffered"))
        results = run_sweep(specs, parallel=False)
        assert results == run_sweep(specs, parallel=False)
        rep = build_report(specs, results)
        keys = sorted(rep["cells"])
        assert keys == [
            "fedcostaware|baseline|price_coupled|async_buffered",
            "fedcostaware|baseline|price_coupled|sync"]
        sync_c = rep["cells"][keys[1]]["cost"]["mean"]
        async_c = rep["cells"][keys[0]]["cost"]["mean"]
        assert sync_c != async_c
        assert rep["grid"]["engines"] == ["async_buffered", "sync"]
        # default-engine specs keep the 3-part key (old reports intact)
        assert cell_key(build_grid(**SMALL_GRID)[0]).count("|") == 2

    def test_report_length_mismatch_raises(self, small):
        specs, results = small
        with pytest.raises(ValueError, match="specs vs"):
            build_report(specs, results[:-1])

    def test_ranking_table_lists_every_market(self, small):
        specs, results = small
        table = ranking_table(build_report(specs, results))
        assert "baseline:" in table
        assert "capacity_crunch:" in table
        assert "fedcostaware" in table and "spot" in table

    def test_parallel_equals_serial(self, small):
        """The pool path returns the same results in the same order as
        in-process execution — fan-out must not perturb a single
        bit."""
        specs, serial = small
        par = run_sweep(specs, parallel=True, processes=2)
        assert par == serial


@pytest.mark.slow
@pytest.mark.skipif(os.cpu_count() is None or os.cpu_count() < 4,
                    reason="speedup contract needs >= 4 cores")
def test_pool_speedup_on_four_cores():
    """With 4+ cores a 12-cell sweep over 4 workers must beat serial by
    >= 2x (generous: perfect scaling would be ~4x)."""
    specs = build_grid(policies=("spot", "fedcostaware", "on_demand"),
                       markets=("baseline", "capacity_crunch"),
                       seeds=range(2), n_clients=16, n_epochs=10)
    t0 = time.perf_counter()
    serial = run_sweep(specs, parallel=False)
    t_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    par = run_sweep(specs, parallel=True, processes=4)
    t_par = time.perf_counter() - t0
    assert par == serial
    assert t_serial / t_par >= 2.0, (
        f"pool speedup {t_serial / t_par:.2f}x < 2x "
        f"(serial {t_serial:.2f}s, parallel {t_par:.2f}s)")


class TestBenchmarkCLI:
    def test_smoke_grid_and_crunch_gate(self, tmp_path):
        """The CI smoke invocation end to end: small grid, report on
        disk, ranking printed, crunch-win gate satisfied."""
        sys.path.insert(0, str(REPO / "benchmarks"))
        try:
            import sweep as sweep_cli
        finally:
            sys.path.pop(0)
        out = tmp_path / "BENCH_sweep.json"
        report = sweep_cli.main([
            "--policies", "spot", "fedcostaware",
            "--markets", "baseline", "capacity_crunch",
            "--seeds", "3", "--serial", "--out", str(out),
            "--assert-crunch-win"])
        assert out.exists()
        assert len(report["cells"]) == 4
