"""Struct-of-arrays fleet core: equivalence against the per-object
event stack, batched-draw identity across every preemption model,
cohort sampling determinism, record/replay on the schema-v6
`FleetStepSummary` vocabulary (including per-client settled dollars),
and the scaling guarantees the core exists to buy (>= 20x over the
per-object path at n=10^4, near-linear wall-clock growth).
"""
import sys
import time
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from repro.cloud.preemption import (ConstantRateModel,
                                    CorrelatedReclaimModel,
                                    PriceCoupledModel,
                                    ReplayInterruptionModel)
from repro.cloud.pricing import SpotMarket
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 PopulationConfig, SchedulerConfig)
from repro.core.eventlog import SCHEMA_VERSION, EventReplayer
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result

# deterministic cloud: no spin-up / price / preemption randomness, so
# the per-object and fleet paths (which own different RNG lanes) see
# identical physics and must land on identical dollars
DET_CLOUD = CloudConfig(spot_rate_sigma=0.0, spin_up_sigma=0.0,
                        preemption_rate_per_hr=0.0)
SCHED = SchedulerConfig()


def _uniform_clients(n):
    return tuple(ClientProfile(name=f"c{i}",
                               mean_epoch_s=600.0 + 60.0 * (i % 7),
                               cold_multiplier=1.15, jitter=0.0)
                 for i in range(n))


def _budget_clients(n):
    """Finite budgets (screening fires) + one late joiner."""
    return tuple(ClientProfile(name=f"c{i}",
                               mean_epoch_s=300.0 + 250.0 * (i % 5),
                               cold_multiplier=1.2, jitter=0.0,
                               budget=0.55 if i % 3 == 0 else float("inf"),
                               join_round=1 if i == 2 else 0)
                 for i in range(n))


def _pair(clients, policy, n_epochs, seed):
    """Run the same config on both paths; return (per_object, fleet)."""
    a = FLCloudRunner(FLRunConfig(dataset="s", clients=clients,
                                  n_epochs=n_epochs, policy=policy,
                                  seed=seed),
                      DET_CLOUD, SCHED).run()
    b = FLCloudRunner(FLRunConfig(dataset="s", clients=clients,
                                  n_epochs=n_epochs, policy=policy,
                                  seed=seed, fleet=True),
                      DET_CLOUD, SCHED).run()
    return a, b


class TestEquivalence:
    """Below the randomness the paths share no code — agreement on
    dollars, makespan, participants, and exclusions is the oracle."""

    @pytest.mark.parametrize("policy", ["on_demand", "spot",
                                        "fedcostaware"])
    @pytest.mark.parametrize("n", [3, 8])
    def test_uniform_pool_matches(self, policy, n):
        a, b = _pair(_uniform_clients(n), policy, n_epochs=5, seed=3)
        assert b.total_cost == pytest.approx(a.total_cost, abs=1e-9)
        assert b.makespan_s == pytest.approx(a.makespan_s, abs=1e-6)
        for c in a.per_client_cost:
            assert b.per_client_cost[c] == pytest.approx(
                a.per_client_cost[c], abs=1e-9)
        assert b.rounds_completed == a.rounds_completed
        assert b.per_round_participants == a.per_round_participants

    @pytest.mark.parametrize("policy", ["spot", "fedcostaware"])
    @pytest.mark.parametrize("n", [4, 9])
    def test_budgets_joins_and_lifecycle_match(self, policy, n):
        """Budget screening, elastic join_round, and (for fedcostaware)
        Listing-1 terminate/pre-warm all active at once."""
        a, b = _pair(_budget_clients(n), policy, n_epochs=8, seed=7)
        assert b.total_cost == pytest.approx(a.total_cost, abs=1e-9)
        assert b.makespan_s == pytest.approx(a.makespan_s, abs=1e-6)
        for c in a.per_client_cost:
            assert b.per_client_cost[c] == pytest.approx(
                a.per_client_cost[c], abs=1e-9)
        assert sorted(b.excluded_clients) == sorted(a.excluded_clients)
        assert b.per_round_participants == a.per_round_participants


class TestBatchedDraws:
    def test_constant_rate_batch_is_draw_identical(self):
        """`rng.exponential(scale, size=n)` consumes the RandomState
        stream exactly like n sequential scalar draws."""
        model = ConstantRateModel(rate_per_hr=6.0)
        insts = [SimpleNamespace(provider="aws", zone=f"z{i % 3}")
                 for i in range(64)]
        batch = model.next_preemption_delays(
            insts, 0.0, np.random.RandomState(42))
        rng = np.random.RandomState(42)
        seq = [model.next_preemption_delay(i, 0.0, rng) for i in insts]
        np.testing.assert_allclose(batch, np.array(seq), rtol=0, atol=0)

    def test_zero_rate_batch_never_preempts(self):
        model = ConstantRateModel(rate_per_hr=0.0)
        out = model.next_preemption_delays(
            [SimpleNamespace(provider="aws", zone="z0")] * 5, 0.0,
            np.random.RandomState(0))
        assert np.all(np.isinf(out))

    @staticmethod
    def _market():
        m = SpotMarket.synthetic(CloudConfig(n_zones=3), seed=9)
        for z in m.zones:
            m.add_interruptions(z.provider, z.name,
                                [900.0 + 60.0 * hash(z.name) % 7,
                                 5000.0, 9000.0])
        return m

    @staticmethod
    def _insts(n=64):
        zones = ["us-east-1a", "us-east-2a", "us-west-2a"]
        return [SimpleNamespace(provider="aws", zone=zones[i % 3])
                for i in range(n)]

    def _assert_draw_identical(self, model, now=100.0):
        """Batch draws == sequential scalar draws from the same seed
        (None <-> inf), bit-exact — the guarantee that a seeded run's
        reclaim sequence does not depend on crossing
        `CloudConfig.fleet_threshold`."""
        insts = self._insts()
        batch = model.next_preemption_delays(
            insts, now, np.random.RandomState(42))
        rng = np.random.RandomState(42)
        seq = [model.next_preemption_delay(i, now, rng) for i in insts]
        seq = np.array([np.inf if d is None else d for d in seq])
        np.testing.assert_allclose(batch, seq, rtol=0, atol=0)

    def test_price_coupled_batch_is_draw_identical(self):
        self._assert_draw_identical(
            PriceCoupledModel(self._market(), base_rate_per_hr=2.0,
                              horizon_s=86400.0))

    def test_replay_batch_is_draw_identical(self):
        self._assert_draw_identical(ReplayInterruptionModel(self._market()))

    def test_correlated_batch_is_draw_identical(self):
        m = self._market()
        self._assert_draw_identical(
            CorrelatedReclaimModel(m, ConstantRateModel(rate_per_hr=4.0)))

    def test_correlated_takes_min_of_base_and_schedule(self):
        """A scheduled reclaim earlier than the base draw wins, and the
        composition consumes exactly the base model's RNG stream."""
        m = self._market()
        model = CorrelatedReclaimModel(m, ConstantRateModel(0.0001))
        insts = self._insts(8)
        rng = np.random.RandomState(7)
        out = model.next_preemption_delays(insts, 100.0, rng)
        sched = ReplayInterruptionModel(m).next_preemption_delays(
            insts, 100.0, np.random.RandomState(0))
        assert np.all(out <= sched)


class TestCohortSampling:
    POP = PopulationConfig(n_clients=5000, seed=11)

    def _run(self, seed):
        cfg = FLRunConfig(dataset="s", clients=(), n_epochs=3,
                          policy="spot", population=self.POP,
                          cohort_size=200, seed=seed)
        return FLCloudRunner(cfg, DET_CLOUD, SCHED).run()

    def test_same_seed_is_deterministic(self):
        a, b = self._run(seed=5), self._run(seed=5)
        assert a.per_round_participants == b.per_round_participants
        assert a.total_cost == pytest.approx(b.total_cost, abs=0.0)

    def test_cohorts_vary_with_seed_and_size(self):
        a, b = self._run(seed=5), self._run(seed=6)
        assert a.per_round_participants != b.per_round_participants
        assert all(len(p) == 200 for p in a.per_round_participants)


class TestRecordReplay:
    def _record(self, **kw):
        cfg = FLRunConfig(dataset="s", clients=_uniform_clients(6),
                          n_epochs=4, policy="fedcostaware", seed=2,
                          fleet=True, **kw)
        r = FLCloudRunner(cfg, DET_CLOUD, SCHED, record=True)
        live = r.run()
        return live, r.recorder.dumps()

    def test_fleet_trace_replays_to_live_totals(self):
        """A recorded fleet run replays through the replay-mode
        accountant to the same dollars — total AND per client, off the
        schema-v6 `client_cost_delta` attribution (the v5 bug: fleet
        replays silently reported every per-client cost as zero)."""
        live, blob = self._record()
        assert f'"schema": {SCHEMA_VERSION}' in blob.splitlines()[0]
        rep = replay_result(EventReplayer.loads(blob))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        assert rep.rounds_completed == live.rounds_completed
        assert rep.has_client_costs
        for c, amt in live.per_client_cost.items():
            if amt > 0.0:
                assert rep.per_client_cost[c] == pytest.approx(amt,
                                                               abs=1e-9)

    def test_v5_fleet_trace_flags_missing_attribution(self):
        """A v5-era fleet trace (summaries without `client_cost_delta`)
        still replays to the right total, but the result now *says* the
        per-client breakdown is absent instead of reporting zeros."""
        import json
        live, blob = self._record()
        lines = blob.splitlines()
        header = json.loads(lines[0])
        header["schema"] = 5
        out = [json.dumps(header)]
        for ln in lines[1:]:
            rec = json.loads(ln)
            rec.pop("client_cost_delta", None)
            out.append(json.dumps(rec))
        rep = replay_result(EventReplayer.loads("\n".join(out) + "\n"))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        assert not rep.has_client_costs
        assert rep.per_client_cost == {}

    def test_step_deltas_sum_to_client_totals(self):
        """Per-step `client_cost_delta` maps sum (per client) to the
        live run's final per-client dollars, and each step's map sums
        to its `cost_delta`."""
        import json
        from collections import defaultdict
        live, blob = self._record()
        per_client = defaultdict(float)
        for ln in blob.splitlines()[1:]:
            rec = json.loads(ln)
            if rec["type"] != "FleetStepSummary":
                continue
            step_map = rec.get("client_cost_delta", {})
            assert sum(step_map.values()) == pytest.approx(
                rec["cost_delta"], abs=1e-9)
            for c, a in step_map.items():
                per_client[c] += a
        for c, amt in live.per_client_cost.items():
            assert per_client.get(c, 0.0) == pytest.approx(amt, abs=1e-9)


@pytest.mark.slow
class TestScaling:
    """The core's reason to exist: wall-clock at cross-device scale."""

    def test_fleet_is_20x_faster_at_1e4(self):
        from benchmarks.scaling import run_fleet, run_per_object
        fleet = run_fleet(10_000, n_epochs=2, seed=0)
        obj = run_per_object(10_000, n_epochs=2, seed=0)
        assert obj["cost"] == pytest.approx(fleet["cost"], rel=0.05)
        assert obj["wall_s"] / fleet["wall_s"] >= 20.0

    def test_growth_is_near_linear_above_1e3(self):
        """wall(10n) <= 15 * wall(n): one decade of clients may cost at
        most ~1.5x-per-doubling-equivalent, i.e. the curve stays
        near-linear (best-of-two to shave timer noise)."""
        from benchmarks.scaling import run_fleet
        wall = {}
        for n in (1_000, 10_000):
            wall[n] = min(run_fleet(n, n_epochs=3, seed=0)["wall_s"]
                          for _ in range(2))
        assert wall[10_000] / wall[1_000] <= 15.0

    def test_100k_cohort_completes(self):
        from benchmarks.scaling import run_fleet
        row = run_fleet(100_000, n_epochs=2, seed=0, cohort_size=10_000)
        assert row["cost"] > 0.0
        assert row["wall_s"] < 60.0
