"""FL substrate tests: aggregation math, FedProx, end-to-end learning,
checkpoint/resume fault tolerance, dual-Dirichlet partitioner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import (CloudConfig, ClientProfile, FLRunConfig,
                                 SchedulerConfig)
from repro.checkpoint.ckpt import Checkpointer, AsyncCheckpointer, \
    ShardedCheckpointer, serialize_pytree, deserialize_into
from repro.checkpoint.store import MemoryStore, FileStore
from repro.data.partition import dual_dirichlet_partition, natural_partition
from repro.data.synthetic import make_dataset, minibatches, token_stream
from repro.fl.algorithms import ServerState, weighted_average, \
    fedprox_penalty
from repro.fl.client import FLClient
from repro.fl.runner import FLCloudRunner
from repro.fl.server import FederatedServer, JaxTrainerHooks
from repro.models import cnn
from repro.optim.optimizers import adamw, sgd


class TestAggregation:
    def test_weighted_average_exact(self):
        p1 = {"w": jnp.ones((2, 2))}
        p2 = {"w": jnp.zeros((2, 2))}
        avg = weighted_average([p1, p2], [3.0, 1.0])
        np.testing.assert_allclose(np.asarray(avg["w"]), 0.75)

    def test_fedavgm_momentum_accumulates(self):
        init = {"w": jnp.zeros(3)}
        srv = ServerState(init, "fedavgm", server_momentum=0.5)
        upd = {"w": jnp.ones(3)}
        srv.aggregate([upd], [1.0])
        w1 = np.asarray(srv.params["w"]).copy()
        srv.aggregate([{"w": jnp.asarray(w1) + 1.0}], [1.0])
        w2 = np.asarray(srv.params["w"])
        assert np.all(w2 > w1)          # momentum keeps moving

    def test_fedprox_penalty_zero_at_global(self):
        p = {"w": jnp.ones(4)}
        assert float(fedprox_penalty(p, p, mu=0.1)) == 0.0
        q = {"w": jnp.ones(4) * 2}
        assert float(fedprox_penalty(q, p, 0.1)) == pytest.approx(
            0.5 * 0.1 * 4.0)


class _StubMetrics:
    def __init__(self, n_samples):
        self.n_samples = n_samples
        self.loss = 0.0


class _StubClient:
    """Duck-typed FLClient returning a fixed parameter value."""

    def __init__(self, value, n_samples):
        self.value = float(value)
        self.n = n_samples

    def train_epoch(self, params, round_idx):
        return {"w": jnp.asarray(self.value)}, _StubMetrics(self.n)


class TestStalenessDiscount:
    """FedBuff-style staleness weighting through TrainerHooks.aggregate
    (async engines report per-client staleness; the JAX hook discounts
    each update's sample weight by 1/sqrt(1+staleness))."""

    def _hooks(self):
        server = FederatedServer({"w": jnp.asarray(0.0)})
        hooks = JaxTrainerHooks(server, {"a": _StubClient(2.0, 3),
                                         "b": _StubClient(8.0, 1)})
        hooks.run_local("a", 0)
        hooks.run_local("b", 0)
        return server, hooks

    def test_discount_factor(self):
        assert JaxTrainerHooks.staleness_discount(0) == 1.0
        assert JaxTrainerHooks.staleness_discount(3) == pytest.approx(0.5)
        assert JaxTrainerHooks.staleness_discount(8) == pytest.approx(
            1.0 / 3.0)

    def test_weighted_average_pinned_with_staleness(self):
        # weights: a = 3 * 1/sqrt(1+0) = 3, b = 1 * 1/sqrt(1+3) = 0.5
        # avg = (3*2.0 + 0.5*8.0) / 3.5 = 10/3.5
        server, hooks = self._hooks()
        hooks.aggregate(["a", "b"], 0, staleness={"a": 0, "b": 3})
        assert float(server.params["w"]) == pytest.approx(10.0 / 3.5,
                                                          rel=1e-6)

    def test_no_staleness_reduces_to_sample_weights(self):
        # plain FedAvg: (3*2.0 + 1*8.0) / 4 = 3.5
        server, hooks = self._hooks()
        hooks.aggregate(["a", "b"], 0)
        assert float(server.params["w"]) == pytest.approx(3.5, rel=1e-6)


class TestPartition:
    def test_dual_dirichlet_disjoint_and_sized(self):
        labels = np.random.RandomState(0).randint(0, 10, 5000)
        parts = dual_dirichlet_partition(labels, 5, seed=1)
        all_idx = np.concatenate(parts)
        assert len(np.unique(all_idx)) == len(all_idx)   # disjoint
        assert len(all_idx) <= len(labels)
        assert all(len(p) >= 8 for p in parts)

    def test_volume_heterogeneity(self):
        labels = np.random.RandomState(0).randint(0, 10, 20000)
        parts = dual_dirichlet_partition(labels, 6, alpha_volume=0.5,
                                         seed=2)
        sizes = sorted(len(p) for p in parts)
        assert sizes[-1] > 2 * sizes[0]   # skewed volumes

    def test_class_heterogeneity(self):
        labels = np.random.RandomState(0).randint(0, 10, 20000)
        parts = dual_dirichlet_partition(labels, 4, alpha_class=0.2,
                                         seed=3)
        # each client's class distribution is far from uniform
        for p in parts:
            hist = np.bincount(labels[p], minlength=10) / len(p)
            assert hist.max() > 0.2

    def test_natural_partition_fractions(self):
        labels = np.zeros(1000)
        parts = natural_partition(labels, [0.5, 0.3, 0.2], seed=0)
        assert [len(p) for p in parts] == [500, 300, 200]


class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16), "n": 7}}

    def test_roundtrip(self):
        t = self._tree()
        data = serialize_pytree(t)
        out = deserialize_into(t, data)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(t["a"]))
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_checkpointer_restore(self):
        ck = Checkpointer(MemoryStore())
        t = self._tree()
        ck.save("run/step=5", t)
        out = ck.restore("run/step=5", template=t)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(t["a"]))
        assert ck.restore("missing", template=t) is None

    def test_latest_step(self):
        ck = Checkpointer(MemoryStore())
        for s in (1, 5, 3):
            ck.save(f"run/step={s}", {"x": jnp.zeros(1)})
        assert ck.latest_step("run") == 5

    def test_async_checkpointer(self):
        ck = AsyncCheckpointer(MemoryStore())
        t = self._tree()
        for i in range(4):
            ck.save(f"r/step={i}", t)
        ck.wait()
        assert ck.latest_step("r") == 3

    def test_sharded_checkpointer(self):
        ck = ShardedCheckpointer(MemoryStore(), process_index=0)
        t = self._tree()
        ck.save("s1", t)
        out = ck.restore("s1", t)
        np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                      np.asarray(t["b"]["c"]))

    def test_file_store_atomic(self, tmp_path):
        fs = FileStore(str(tmp_path))
        fs.put("k/1", b"hello")
        assert fs.get("k/1") == b"hello"
        fs.put("k/1", b"world")
        assert fs.get("k/1") == b"world"
        assert fs.get("nope") is None


def _make_fl_setup(n_clients=3, n=900, checkpoint=False):
    ds = make_dataset("mnist", n, seed=0)
    parts = dual_dirichlet_partition(ds.y, n_clients, alpha_class=2.0,
                                     seed=0)
    params, apply_fn, _ = cnn.build("small_cnn", jax.random.PRNGKey(0),
                                    ds.n_classes, 1, 28)
    store = MemoryStore()
    clients = []
    for i, idx in enumerate(parts):
        def data_fn(r, idx=idx, i=i):
            return minibatches(ds, idx, 32, seed=r * 10 + i)
        clients.append(FLClient(
            f"c{i}", apply_fn, adamw(lr=1e-3), data_fn, len(idx),
            checkpointer=Checkpointer(store) if checkpoint else None,
            checkpoint_every=2))
    return ds, params, apply_fn, clients


class TestEndToEnd:
    @pytest.mark.slow
    def test_fl_learns(self):
        ds, params, apply_fn, clients = _make_fl_setup()
        server = FederatedServer(params)
        hist = server.fit(clients, 4)
        assert hist[-1]["mean_client_loss"] < hist[0]["mean_client_loss"]
        logits = apply_fn(server.params, jnp.asarray(ds.x[:256]))
        acc = float(jnp.mean(jnp.argmax(logits, -1)
                             == jnp.asarray(ds.y[:256])))
        assert acc > 0.8

    def test_resume_from_checkpoint_mid_epoch(self):
        """Fault tolerance (§III-D): resume reproduces training progress."""
        ds, params, apply_fn, clients = _make_fl_setup(checkpoint=True)
        c = clients[0]
        # full epoch
        p_full, m = c.train_epoch(params, round_idx=0)
        assert m.n_batches >= 4
        # now simulate preemption: epoch ran, checkpoints exist; resume
        p_resumed, m2 = c.train_epoch(params, round_idx=0,
                                      resume_from_batch=1)
        assert m2.n_batches < m.n_batches       # skipped preserved batches
        # resumed params close to full-epoch params (same data order)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p_full),
                                jax.tree.leaves(p_resumed)))
        assert d < 1e-4

    @pytest.mark.slow
    def test_cloud_runner_with_real_training(self):
        ds, params, apply_fn, clients = _make_fl_setup()
        server = FederatedServer(params)
        hooks = JaxTrainerHooks(server, {c.name: c for c in clients})
        profiles = tuple(ClientProfile(c.name, 300.0 * (i + 1),
                                       n_samples=c.n_samples, jitter=0.0)
                         for i, c in enumerate(clients))
        cfg = FLRunConfig(dataset="mnist", clients=profiles, n_epochs=3,
                          policy="fedcostaware")
        res = FLCloudRunner(cfg, hooks=hooks).run()
        assert res.rounds_completed == 3
        assert len(server.history) == 3
        logits = apply_fn(server.params, jnp.asarray(ds.x[:256]))
        acc = float(jnp.mean(jnp.argmax(logits, -1)
                             == jnp.asarray(ds.y[:256])))
        assert acc > 0.6


class TestTokenStream:
    def test_markov_stream_learnable_shapes(self):
        it = token_stream(vocab=64, batch=4, seq=16, seed=0)
        b = next(it)
        assert b["tokens"].shape == (4, 16)
        assert b["labels"].shape == (4, 16)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 64
