"""The paper's qualitative Fig. 4 / Fig. 5 claims, checked
quantitatively by replaying the golden Fed-ISIC2019 FedCostAware trace
(tests/golden/fed_isic2019__fedcostaware.events.jsonl, 6 clients x 20
epochs, seed 0).

These asserts used to live inline in benchmarks/fig4_timeline.py /
fig5_costs.py; moving them here makes the benchmarks pure reporters and
runs the claims against the recorded event log — no simulation, the
same artifact a user audits offline.
"""
from pathlib import Path

import pytest

from repro.fl.telemetry import replay_result, state_totals

TRACE = (Path(__file__).parent / "golden"
         / "fed_isic2019__fedcostaware.events.jsonl")


@pytest.fixture(scope="module")
def res():
    return replay_result(TRACE)


@pytest.fixture(scope="module")
def totals(res):
    return state_totals(res.timeline)


def clients_of(res):
    return sorted(res.per_client_cost)          # client_0 is the slowest


# ---------------------------------------------------------------------------
# Fig. 4: client operational states.
# ---------------------------------------------------------------------------
class TestFig4Claims:
    def test_slowest_client_never_terminated(self, res, totals):
        """The slowest client's instance is never worth stopping — it
        accrues zero 'savings' (off) time."""
        slow = clients_of(res)[0]
        assert totals.get((slow, "savings"), 0.0) == 0.0

    def test_slowest_client_pays_spinup_once(self, res):
        """No termination means no re-provisioning: exactly one spin-up
        segment (round 1's cold start) for the slowest client."""
        slow = clients_of(res)[0]
        spinups = [s for s in res.timeline
                   if s.client == slow and s.state == "spinup"]
        assert len(spinups) == 1
        assert spinups[0].t0 == 0.0

    def test_fast_client_converts_idle_to_savings(self, res, totals):
        """Faster clients are terminated at the barrier: their off time
        exceeds their billed idle time."""
        fast = clients_of(res)[-1]
        assert totals.get((fast, "savings"), 0.0) > \
            totals.get((fast, "idle"), 0.0)

    def test_all_clients_complete_all_rounds(self, res):
        assert res.rounds_completed == 20
        assert res.excluded_clients == []


# ---------------------------------------------------------------------------
# Fig. 5: accumulated per-client cost.
# ---------------------------------------------------------------------------
def curve_table(res):
    rounds = sorted({r["round"] for r in res.cost_curve})
    clients = sorted({r["client"] for r in res.cost_curve})
    table = {c: {} for c in clients}
    for rec in res.cost_curve:
        table[rec["client"]][rec["round"]] = rec["cum_cost"]
    return rounds, clients, table


class TestFig5Claims:
    def test_cost_curves_monotone_nondecreasing(self, res):
        rounds, clients, table = curve_table(res)
        for c in clients:
            seq = [table[c][r] for r in rounds if r in table[c]]
            assert all(b >= a - 1e-9 for a, b in zip(seq, seq[1:])), c

    def test_slowest_client_accrues_highest_cost(self, res):
        """Largest data volume -> longest epochs -> most billed time."""
        rounds, clients, table = curve_table(res)
        final = {c: table[c][rounds[-1]] for c in clients}
        assert max(final, key=final.get) == clients[0]

    def test_total_cost_near_paper_table1(self, res):
        """Replayed total matches the paper's $7.1740 within the repro
        tolerance already accepted by benchmarks/table1.py."""
        assert res.total_cost == pytest.approx(7.1740, rel=0.05)
