"""Pins for benchmarks/forecast_quality.py: the learned forecaster's
cost must land strictly between the reactive baseline and the oracle,
the deliberately miscalibrated forecaster must measurably lose money,
and the recorded hazard-source header must say which signal each
policy consulted."""
import pytest

from benchmarks.forecast_prewarm import (CLIENTS, SCHED, spiky_market,
                                         DEFAULT_TRACE_DIR)
from benchmarks.forecast_quality import POLICY_NAMES, compare
from repro.common.config import CloudConfig, FLRunConfig
from repro.core.policies import POLICIES, Policy, register_policy
from repro.core.strategy import ForecastPrewarmSpec
from repro.fl.runner import FLCloudRunner

ORACLE_SLACK = 0.25


@pytest.fixture(scope="module")
def results():
    return compare()


class TestForecastQualityClaims:
    def test_scenario_exercises_reclaims(self, results):
        for name in POLICY_NAMES:
            assert results[name]["n_preemptions"] > 0

    def test_learned_beats_reactive(self, results):
        assert results["learned_forecast"]["total_cost"] < \
            results["reactive_ckpt"]["total_cost"]

    def test_learned_approaches_oracle_without_beating_it(self, results):
        learned = results["learned_forecast"]["total_cost"]
        oracle = results["oracle_prewarm"]["total_cost"]
        assert oracle <= learned <= oracle * (1.0 + ORACLE_SLACK)

    def test_miscalibration_loses_money(self, results):
        assert results["miscalibrated_forecast"]["total_cost"] > \
            results["learned_forecast"]["total_cost"]

    def test_learned_shrinks_spinup_gap_between_extremes(self, results):
        """The learned policy misses the first burst (still ignorant)
        but pre-warms later ones: its stall gap lands strictly between
        the oracle's and the reactive baseline's."""
        assert results["oracle_prewarm"]["spinup_gap_s"] < \
            results["learned_forecast"]["spinup_gap_s"] < \
            results["reactive_ckpt"]["spinup_gap_s"]

    def test_all_policies_complete_the_run(self, results):
        rounds = {results[n]["rounds_completed"] for n in POLICY_NAMES}
        assert rounds == {8}

    def test_benchmark_main_asserts_pass(self):
        from benchmarks.forecast_quality import main
        out = main([])
        assert set(out) == set(POLICY_NAMES)


class TestCalibrationTelemetry:
    def test_learned_policies_publish_forecasts(self, results):
        assert results["learned_forecast"]["n_forecasts"] > 0
        assert results["miscalibrated_forecast"]["n_forecasts"] > 0
        assert results["reactive_ckpt"]["n_forecasts"] == 0
        assert results["oracle_prewarm"]["n_forecasts"] == 0

    def test_brier_tracks_the_money(self, results):
        """The dollars ordering is explained by the calibration
        ordering: the miscalibrated forecaster scores strictly worse."""
        good = results["learned_forecast"]["brier"]
        bad = results["miscalibrated_forecast"]["brier"]
        assert 0.0 <= good < bad

    def test_band_coverage_resolved(self, results):
        cov = results["learned_forecast"]["coverage"]
        assert 0.5 <= cov <= 1.0


def _run(policy: str, n_epochs: int = 2,
         preemption_model: str = "replay"):
    cloud = CloudConfig(spot_rate_sigma=0.0, spin_up_sigma=0.0,
                        spin_up_mean_s=450.0,
                        preemption_model=preemption_model,
                        preemption_rate_per_hr=1.0,
                        market=spiky_market(DEFAULT_TRACE_DIR))
    cfg = FLRunConfig(dataset="hazard_source", clients=CLIENTS,
                      n_epochs=n_epochs, policy=policy, seed=0)
    r = FLCloudRunner(cfg, cloud_cfg=cloud, sched_cfg=SCHED, record=True)
    r.run()
    return r.recorder.header


class TestHazardSourceHeader:
    """The replay fallback is now explicit in the recorded trace: the
    header names which hazard signal the run's strategies consulted."""

    def register(self, name: str, oracle: bool) -> None:
        register_policy(Policy(
            name, pick_cheapest_zone=True, on_warning="checkpoint",
            strategies=(ForecastPrewarmSpec(
                hazard_threshold_per_hr=2.0, poll_s=30.0,
                oracle=oracle),)), overwrite=True)

    def test_oracle_polling_stamps_oracle(self):
        """With a live price-coupled model the oracle strategy reads
        the model's own hazard — and the trace says so."""
        self.register("tmp_hazard_oracle", oracle=True)
        try:
            header = _run("tmp_hazard_oracle",
                          preemption_model="price_coupled")
            assert header["hazard_source"] == "oracle"
        finally:
            POLICIES.pop("tmp_hazard_oracle", None)

    def test_oracle_under_replay_degrades_to_observable(self):
        """Under recorded-interruption replay the model holds no
        hazard; the oracle strategy silently received the price-derived
        estimate before — now the trace records that substitution."""
        self.register("tmp_hazard_oracle_replay", oracle=True)
        try:
            header = _run("tmp_hazard_oracle_replay")
            assert header["hazard_source"] == "observable"
        finally:
            POLICIES.pop("tmp_hazard_oracle_replay", None)

    def test_observable_polling_stamps_observable(self):
        self.register("tmp_hazard_obs", oracle=False)
        try:
            header = _run("tmp_hazard_obs")
            assert header["hazard_source"] == "observable"
        finally:
            POLICIES.pop("tmp_hazard_obs", None)

    def test_no_hazard_consulted_no_header_key(self):
        """Policies that never poll a hazard leave the header alone —
        which is what keeps regenerated goldens byte-compatible."""
        header = _run("fedcostaware")
        assert "hazard_source" not in header
