"""Integration tests for the train/serve drivers (public entry points)."""
import os
import sys

import pytest


def test_train_driver_with_resume(tmp_path, capsys):
    from repro.launch import train as T
    ckpt = str(tmp_path / "ck")
    T.main(["--arch", "musicgen-medium", "--steps", "6", "--batch", "2",
            "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "3",
            "--log-every", "3"])
    out1 = capsys.readouterr().out
    assert "done." in out1
    # second invocation resumes from the saved step
    T.main(["--arch", "musicgen-medium", "--steps", "8", "--batch", "2",
            "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "3",
            "--log-every", "2"])
    out2 = capsys.readouterr().out
    assert "resumed from checkpoint step" in out2


def test_serve_driver(capsys):
    from repro.launch import serve as S
    S.main(["--arch", "recurrentgemma-2b", "--batch", "2",
            "--prompt-len", "6", "--gen", "6"])
    out = capsys.readouterr().out
    assert "ms/token" in out and "seq0:" in out
