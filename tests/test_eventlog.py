"""Unit tests for the event-log record/replay subsystem
(core.eventlog) and the engine-level telemetry events that feed it."""
import json

import pytest

from repro.cloud.accounting import CostAccountant
from repro.cloud.simulator import CloudSimulator
from repro.common.config import CloudConfig, ClientProfile, FLRunConfig
from repro.core.events import (EVENT_TYPES, BillingTick, BudgetExhausted,
                               ClientReady, ClientStateChanged, EventBus,
                               InstancePreempted, InstanceReady,
                               RoundCompleted, RoundStarted, RunCompleted)
from repro.core.eventlog import (SCHEMA_VERSION, EventRecorder,
                                 EventReplayer, InstanceRef, decode_event,
                                 encode_event)
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import (CostCurveRecorder, TimelineRecorder,
                                replay_result, state_totals)

CLOUD = CloudConfig(spot_rate_sigma=0.0)
CLIENTS = (
    ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=3),
    ClientProfile("mid", mean_epoch_s=450, jitter=0.0, n_samples=2),
    ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
)
ALL_POLICIES = ("on_demand", "spot", "fedcostaware", "fedcostaware_async")


def make_runner(policy, cloud=None, seed=0, n_epochs=4, **cfg_kw):
    cfg = FLRunConfig(dataset="t", clients=CLIENTS, n_epochs=n_epochs,
                      policy=policy, seed=seed, **cfg_kw)
    return FLCloudRunner(cfg, cloud_cfg=cloud or CLOUD, record=True)


# ---------------------------------------------------------------------------
# Bus wildcard subscription.
# ---------------------------------------------------------------------------
class TestSubscribeAll:
    def test_wildcard_sees_every_type(self):
        bus = EventBus()
        got = []
        bus.subscribe_all(got.append)
        bus.publish(ClientStateChanged(1.0, "a", "training"))
        bus.publish(BudgetExhausted(2.0, "a"))
        assert [type(e).__name__ for e in got] == \
            ["ClientStateChanged", "BudgetExhausted"]

    def test_wildcard_runs_before_typed(self):
        bus = EventBus()
        order = []
        bus.subscribe(BudgetExhausted, lambda ev: order.append("typed"))
        bus.subscribe_all(lambda ev: order.append("all"))
        bus.publish(BudgetExhausted(0.0, "c"))
        assert order == ["all", "typed"]

    def test_unsubscribe_all(self):
        bus = EventBus()
        got = []
        h = bus.subscribe_all(got.append)
        bus.unsubscribe_all(h)
        bus.publish(BudgetExhausted(0.0, "c"))
        assert got == []


# ---------------------------------------------------------------------------
# Encode / decode.
# ---------------------------------------------------------------------------
class TestCodec:
    def test_instance_snapshot_replaces_reference(self):
        sim = CloudSimulator(CLOUD, seed=0)
        inst = sim.request_instance("a")
        rec = encode_event(InstanceReady(1.5, inst))
        assert rec["type"] == "InstanceReady"
        snap = rec["instance"]["$instance"]
        assert snap["iid"] == inst.iid and snap["client"] == "a"
        ev = decode_event(rec)
        assert isinstance(ev.instance, InstanceRef)
        assert ev.instance.iid == inst.iid
        assert ev.instance._billing_from is None

    def test_roundtrip_all_engine_events(self):
        events = [
            RoundStarted(0.0, 0, ("a", "b")),
            RoundCompleted(9.0, 0, ("a",), {"a": 0.5, "b": 0.25}),
            ClientStateChanged(1.0, "a", "training"),
            BudgetExhausted(2.0, "b"),
            RunCompleted(10.0, 9.5, 0.75, {"a": 0.5, "b": 0.25}, 3,
                         ("b",), 2),
            ClientReady(3.0, "a", InstanceRef(1, "a", "z0", False, 0.0),
                        True, {"round": 1, "remaining": 4.5}),
            BillingTick(4.0, InstanceRef(1, "a", "z0", False, 0.0), "a",
                        1.0, 4.0, 0.01),
        ]
        for ev in events:
            rec = encode_event(ev)
            json.dumps(rec)                     # JSON-serializable
            rec2 = encode_event(decode_event(rec))
            assert rec2 == rec, type(ev).__name__

    def test_every_registered_type_decodable(self):
        assert set(EVENT_TYPES) >= {
            "InstanceRequested", "InstanceReady", "InstancePreempted",
            "InstanceTerminated", "BillingTick", "ClientReady",
            "ClientLost", "RoundStarted", "RoundCompleted",
            "ClientStateChanged", "BudgetExhausted", "RunCompleted"}

    def test_unknown_type_raises(self):
        with pytest.raises(ValueError, match="unknown event type"):
            decode_event({"type": "NopeEvent", "t": 0.0})

    def test_unserializable_field_raises(self):
        with pytest.raises(TypeError, match="not.*serializable"):
            encode_event(ClientReady(0.0, "a", object(), True))


# ---------------------------------------------------------------------------
# Recorder / replayer plumbing.
# ---------------------------------------------------------------------------
class TestRecorderReplayer:
    def test_header_carries_schema_and_meta(self):
        bus = EventBus()
        rec = EventRecorder(bus, meta={"dataset": "d", "seed": 3})
        assert rec.header == {"schema": SCHEMA_VERSION, "dataset": "d",
                              "seed": 3}

    def test_dump_load_roundtrip(self, tmp_path):
        bus = EventBus()
        rec = EventRecorder(bus, meta={"k": "v"})
        bus.publish(ClientStateChanged(1.0, "a", "spinup"))
        bus.publish(ClientStateChanged(2.0, "a", "training"))
        p = rec.dump(tmp_path / "run.events.jsonl")
        rep = EventReplayer.load(p)
        assert rep.header["k"] == "v"
        assert [type(e).__name__ for e in rep.events] == \
            ["ClientStateChanged"] * 2
        assert rep.events[1].t == 2.0

    def test_empty_log_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            EventReplayer.loads("")

    def test_replay_preserves_order(self):
        bus = EventBus()
        rec = EventRecorder(bus)
        for i in range(5):
            bus.publish(ClientStateChanged(float(i), "a", "idle"))
        out = EventBus()
        got = []
        out.subscribe(ClientStateChanged, lambda ev: got.append(ev.t))
        EventReplayer.loads(rec.dumps()).replay(out)
        assert got == [0.0, 1.0, 2.0, 3.0, 4.0]


# ---------------------------------------------------------------------------
# Engine-level telemetry events on live runs.
# ---------------------------------------------------------------------------
class TestEngineTelemetry:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_round_events_bracket_every_round(self, policy):
        r = make_runner(policy)
        res = r.run()
        types = [rec["type"] for rec in r.recorder.records]
        assert types.count("RoundCompleted") == res.rounds_completed
        assert types.count("RunCompleted") == 1
        assert types[-1] == "RunCompleted"
        started = [rec for rec in r.recorder.records
                   if rec["type"] == "RoundStarted"]
        assert [s["round_idx"] for s in started] == \
            list(range(res.rounds_completed))

    def test_round_completed_carries_cost_snapshots(self):
        r = make_runner("fedcostaware")
        r.run()
        completed = [rec for rec in r.recorder.records
                     if rec["type"] == "RoundCompleted"]
        for rec in completed:
            assert set(rec["client_costs"]) == {"slow", "mid", "fast"}
        # cumulative: each client's snapshot is non-decreasing
        for c in ("slow", "mid", "fast"):
            seq = [rec["client_costs"][c] for rec in completed]
            assert all(b >= a - 1e-9 for a, b in zip(seq, seq[1:]))

    @pytest.mark.parametrize("policy",
                             ["fedcostaware", "fedcostaware_async"])
    def test_round_invariant_when_all_clients_exhausted(self, policy):
        """When budget screening empties the pool, the never-opened
        round must not count: rounds_completed == #RoundCompleted and
        RoundStarted indices stay contiguous."""
        clients = (
            ClientProfile("p1", 300, n_samples=1, jitter=0.0,
                          budget=0.05),
            ClientProfile("p2", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=10,
                          policy=policy, seed=0)
        r = FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)
        res = r.run()
        assert set(res.excluded_clients) == {"p1", "p2"}
        assert res.rounds_completed < 10
        types = [rec["type"] for rec in r.recorder.records]
        assert types.count("RoundCompleted") == res.rounds_completed
        started = [rec["round_idx"] for rec in r.recorder.records
                   if rec["type"] == "RoundStarted"]
        assert started == list(range(res.rounds_completed))
        # final cost-curve records are labeled with a round that ran
        assert max(rec["round"] for rec in res.cost_curve) == \
            res.rounds_completed - 1

    def test_budget_exhausted_published(self):
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=10,
                          policy="fedcostaware", seed=0)
        r = FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)
        res = r.run()
        assert "poor" in res.excluded_clients
        exhausted = [rec["client"] for rec in r.recorder.records
                     if rec["type"] == "BudgetExhausted"]
        assert exhausted == ["poor"]

    def test_client_state_changes_match_timeline(self):
        r = make_runner("fedcostaware")
        res = r.run()
        opens = [rec for rec in r.recorder.records
                 if rec["type"] == "ClientStateChanged"
                 and rec["state"] != "done"]
        assert len(opens) == len(res.timeline)
        for rec, seg in zip(opens, res.timeline):
            assert (rec["client"], rec["state"], rec["t"]) == \
                (seg.client, seg.state, seg.t0)


# ---------------------------------------------------------------------------
# Live vs replayed equality (the differential oracle), all policies,
# with and without preemption.
# ---------------------------------------------------------------------------
SCENARIOS = [(p, CLOUD, 0) for p in ALL_POLICIES] + [
    ("fedcostaware",
     CloudConfig(preemption_rate_per_hr=0.5, spot_rate_sigma=0.0), 3),
    ("fedcostaware_async",
     CloudConfig(preemption_rate_per_hr=0.5, spot_rate_sigma=0.0), 3),
]


class TestLiveVsReplay:
    @pytest.mark.parametrize("policy,cloud,seed", SCENARIOS)
    def test_replay_reproduces_live_run(self, policy, cloud, seed):
        r = make_runner(policy, cloud=cloud, seed=seed, n_epochs=6)
        live = r.run()
        rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        for c in live.per_client_cost:
            assert rep.per_client_cost[c] == pytest.approx(
                live.per_client_cost[c], abs=1e-9)
        lt, rt = state_totals(live.timeline), state_totals(rep.timeline)
        assert set(lt) == set(rt)
        for k in lt:
            assert rt[k] == pytest.approx(lt[k], abs=1e-9), k
        assert rep.makespan_s == pytest.approx(live.makespan_s, abs=1e-9)
        assert rep.rounds_completed == live.rounds_completed
        assert rep.excluded_clients == live.excluded_clients
        assert [list(p) for p in rep.per_round_participants] == \
            live.per_round_participants

    def test_replayed_cost_curve_rounds_and_dollars(self):
        r = make_runner("fedcostaware")
        live = r.run()
        rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
        assert len(rep.cost_curve) == len(live.cost_curve)
        for lrec, rrec in zip(live.cost_curve, rep.cost_curve):
            assert lrec["client"] == rrec["client"]
            assert lrec["round"] == rrec["round"]
            assert rrec["cum_cost"] == pytest.approx(
                lrec["cum_cost"], abs=1e-9)

    def test_truncated_log_rejected_by_replay_result(self):
        r = make_runner("spot")
        r.run()
        lines = r.recorder.dumps().splitlines()
        truncated = "\n".join(lines[:-1])       # drop RunCompleted
        with pytest.raises(ValueError, match="RunCompleted"):
            replay_result(EventReplayer.loads(truncated))

    def test_replay_consumers_price_book_free(self):
        """Replay-mode accountant/timeline/curve never touch a price
        book or clock — the acceptance gate for offline fig4/fig5."""
        r = make_runner("fedcostaware")
        live = r.run()
        bus = EventBus()
        acct = CostAccountant(bus)
        tl = TimelineRecorder(bus)
        curve = CostCurveRecorder(bus)
        EventReplayer.loads(r.recorder.dumps()).replay(bus)
        assert acct.total_cost() == pytest.approx(
            live.total_cost, abs=1e-9)
        assert state_totals(tl.segments).keys() == \
            state_totals(live.timeline).keys()
        assert len(curve.records) == len(live.cost_curve)
