"""Tests for the event-bus orchestration layers introduced by the
runner split: EventBus, CostAccountant, the engine registry, the
behavior-preserving SyncEngine (golden pre-refactor totals), and the
FedBuff-style AsyncBufferedEngine."""
import math

import pytest

from repro.cloud.accounting import CostAccountant
from repro.cloud.simulator import CloudSimulator
from repro.common.config import CloudConfig, ClientProfile, FLRunConfig
from repro.core.events import (BillingTick, ClientReady, EventBus,
                               InstancePreempted, InstanceReady)
from repro.core.policies import POLICIES, get_policy
from repro.fl.engines import (ENGINES, AsyncBufferedEngine, SyncEngine,
                              get_engine)
from repro.fl.runner import FLCloudRunner

CLOUD = CloudConfig(spot_rate_sigma=0.0)

CLIENTS = (
    ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=3),
    ClientProfile("mid", mean_epoch_s=450, jitter=0.0, n_samples=2),
    ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
)


def run_policy(policy, clients=CLIENTS, n_epochs=8, cloud=None, seed=0,
               **cfg_kw):
    cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=n_epochs,
                      policy=policy, seed=seed, **cfg_kw)
    return FLCloudRunner(cfg, cloud_cfg=cloud or CLOUD).run()


# ---------------------------------------------------------------------------
# EventBus.
# ---------------------------------------------------------------------------
class TestEventBus:
    def test_publish_dispatches_by_exact_type(self):
        bus = EventBus()
        got = []
        bus.subscribe(InstanceReady, lambda ev: got.append(("ready", ev)))
        bus.subscribe(InstancePreempted,
                      lambda ev: got.append(("preempt", ev)))
        bus.publish(InstanceReady(1.0, "i"))
        assert [k for k, _ in got] == ["ready"]
        bus.publish(InstancePreempted(2.0, "i"))
        assert [k for k, _ in got] == ["ready", "preempt"]

    def test_subscribers_run_in_subscription_order(self):
        bus = EventBus()
        order = []
        bus.subscribe(InstanceReady, lambda ev: order.append("a"))
        bus.subscribe(InstanceReady, lambda ev: order.append("b"))
        bus.publish(InstanceReady(0.0, None))
        assert order == ["a", "b"]

    def test_unsubscribe(self):
        bus = EventBus()
        hits = []
        h = bus.subscribe(InstanceReady, lambda ev: hits.append(ev))
        bus.unsubscribe(InstanceReady, h)
        bus.publish(InstanceReady(0.0, None))
        assert hits == []

    def test_no_subscribers_is_fine(self):
        EventBus().publish(BillingTick(0.0, None, "c", 0.0, 1.0, 0.1))


# ---------------------------------------------------------------------------
# CostAccountant: incremental totals == the simulator's O(n) scans.
# ---------------------------------------------------------------------------
def make_sim_acct(cfg=CLOUD, seed=0):
    sim = CloudSimulator(cfg, seed=seed)
    acct = CostAccountant(sim.bus, sim.prices, clock=lambda: sim.now)
    return sim, acct


class TestCostAccountant:
    def test_matches_scan_with_open_segment(self):
        sim, acct = make_sim_acct()
        a = sim.request_instance("a")
        b = sim.request_instance("b")
        sim.run_until_idle()
        sim.now = max(a.t_ready, b.t_ready) + 1800.0
        assert acct.client_cost("a") == pytest.approx(
            sim.client_cost("a"), abs=1e-12)
        assert acct.total_cost() == pytest.approx(
            sim.total_cost(), abs=1e-12)

    def test_matches_scan_after_close_and_min_billing(self):
        sim, acct = make_sim_acct()
        a = sim.request_instance("a")
        sim.run_until_idle()
        sim.now = a.t_ready + 5.0           # under the 60s floor
        sim.terminate(a)
        assert acct.client_cost("a") == pytest.approx(
            sim.client_cost("a"), abs=1e-12)
        assert acct.client_cost("a") > 0

    def test_terminate_while_spinning_is_free(self):
        sim, acct = make_sim_acct()
        a = sim.request_instance("a")
        sim.terminate(a)
        sim.run_until_idle()
        assert acct.client_cost("a") == 0.0 and acct.total_cost() == 0.0

    def test_preempted_instance_closed_out(self):
        cfg = CloudConfig(preemption_rate_per_hr=50.0, spot_rate_sigma=0.0)
        sim, acct = make_sim_acct(cfg, seed=1)
        a = sim.request_instance("a")
        sim.run_until_idle(t_max=10 * 3600)
        assert a.state == "preempted"
        assert acct.client_cost("a") == pytest.approx(a.cost, abs=1e-12)
        # closed segment: advancing time must not accrue anything more
        sim.now += 3600.0
        assert acct.client_cost("a") == pytest.approx(a.cost, abs=1e-12)

    def test_full_run_agrees_with_scan(self):
        for policy in ("on_demand", "spot", "fedcostaware",
                       "fedcostaware_async"):
            r = FLCloudRunner(FLRunConfig(
                dataset="t", clients=CLIENTS, n_epochs=4, policy=policy,
                seed=0), cloud_cfg=CLOUD)
            res = r.run()
            assert res.total_cost == pytest.approx(
                r.sim.total_cost(), abs=1e-9)
            for c in ("slow", "mid", "fast"):
                assert res.per_client_cost[c] == pytest.approx(
                    r.sim.client_cost(c), abs=1e-9)


# ---------------------------------------------------------------------------
# Registry / policy wiring.
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_policies_name_registered_engines(self):
        for p in POLICIES.values():
            assert p.engine in ENGINES

    def test_async_policy_uses_async_engine(self):
        assert get_engine(get_policy("fedcostaware_async").engine) \
            is AsyncBufferedEngine
        assert get_engine(get_policy("fedcostaware").engine) is SyncEngine

    def test_unknown_engine_raises(self):
        with pytest.raises(KeyError):
            get_engine("nope")

    def test_runner_resolves_engine_from_policy(self):
        r = FLCloudRunner(FLRunConfig(
            dataset="t", clients=CLIENTS, n_epochs=1,
            policy="fedcostaware_async", seed=0), cloud_cfg=CLOUD)
        assert isinstance(r.engine, AsyncBufferedEngine)


# ---------------------------------------------------------------------------
# SyncEngine: behavior-preserving port. Totals pinned against the
# pre-refactor monolithic FLCloudRunner (seed commit), tolerance 1e-6.
# ---------------------------------------------------------------------------
GOLDEN_SYNC = {
    "on_demand": 6.17487890305501,
    "spot": 2.371925358636006,
    "fedcostaware": 1.689345246824989,
}
GOLDEN_MAKESPAN = 7497.201761277703


class TestSyncGolden:
    def test_totals_match_pre_refactor(self):
        for policy, want in GOLDEN_SYNC.items():
            res = run_policy(policy)
            assert res.total_cost == pytest.approx(want, abs=1e-6), policy
            assert res.makespan_s == pytest.approx(GOLDEN_MAKESPAN,
                                                   abs=1e-6)

    def test_paper_cost_ordering(self):
        costs = {p: run_policy(p).total_cost for p in GOLDEN_SYNC}
        assert costs["fedcostaware"] < costs["spot"] < costs["on_demand"]

    def test_table1_mnist_row_preserved(self):
        from benchmarks.table1 import ROWS, run_row
        row = next(r for r in ROWS if r.dataset == "MNIST")
        want = {"fedcostaware": 2.2597067666666666,
                "spot": 2.7192071600000003,
                "on_demand": 6.948240800000001}
        for policy, cost in want.items():
            assert run_row(row, policy).total_cost == pytest.approx(
                cost, abs=1e-6)


# ---------------------------------------------------------------------------
# AsyncBufferedEngine: the scenario the sync barrier cannot express.
# ---------------------------------------------------------------------------
STRAGGLER = (
    ClientProfile("strag", mean_epoch_s=900, jitter=0.0, n_samples=1),
    ClientProfile("f1", mean_epoch_s=300, jitter=0.0, n_samples=1),
    ClientProfile("f2", mean_epoch_s=300, jitter=0.0, n_samples=1),
)


class TestAsyncBuffered:
    def test_async_beats_sync_makespan_with_straggler(self):
        """One 3x straggler: async completes the same number of rounds
        in strictly less wall-clock (the fast clients never wait)."""
        sync = run_policy("fedcostaware", clients=STRAGGLER, n_epochs=6)
        asy = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=6)
        assert sync.rounds_completed == asy.rounds_completed == 6
        assert asy.makespan_s < sync.makespan_s

    def test_per_client_costs_from_accountant(self):
        res = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=6)
        assert set(res.per_client_cost) == {"strag", "f1", "f2"}
        assert all(v > 0 for v in res.per_client_cost.values())
        assert sum(res.per_client_cost.values()) == pytest.approx(
            res.total_cost, abs=1e-9)

    def test_buffer_k_controls_round_size(self):
        res = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=5, buffer_k=2)
        assert all(len(p) == 2 for p in res.per_round_participants)

    def test_stragglers_roll_into_later_rounds(self):
        res = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=6)
        # the straggler contributes, but not to every round
        rounds_with_strag = [i for i, p in
                             enumerate(res.per_round_participants)
                             if "strag" in p]
        assert 0 < len(rounds_with_strag) < 6

    def test_async_budget_exclusion(self):
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        res = run_policy("fedcostaware_async", clients=clients,
                         n_epochs=10)
        assert "poor" in res.excluded_clients
        assert res.rounds_completed == 10

    def test_async_survives_preemption(self):
        cloud = CloudConfig(preemption_rate_per_hr=0.5,
                            spot_rate_sigma=0.0)
        res = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=6, cloud=cloud, seed=3)
        assert res.rounds_completed == 6

    def test_timeline_well_formed(self):
        res = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=4)
        for seg in res.timeline:
            assert seg.t1 >= seg.t0 >= 0.0


# ---------------------------------------------------------------------------
# AsyncBufferedEngine edge cases: buffer_k beyond the pool, preemption
# of an already-buffered client, budget exhaustion shrinking the pool
# below buffer_k.
# ---------------------------------------------------------------------------
class TestAsyncEdgeCases:
    def test_buffer_k_larger_than_pool_clamps(self):
        """buffer_k > n_clients must clamp to the pool size (wait for
        everyone) instead of deadlocking on an unreachable target."""
        res = run_policy("fedcostaware_async", clients=STRAGGLER,
                         n_epochs=4, buffer_k=10)
        assert res.rounds_completed == 4
        assert all(len(p) == 3 for p in res.per_round_participants)

    def test_preempt_client_with_buffered_result(self):
        """Preempting a client *after* its result entered the buffer
        must not lose the contribution: the buffered result still
        aggregates, the client recovers and keeps participating."""
        cfg = FLRunConfig(dataset="t", clients=STRAGGLER, n_epochs=4,
                          policy="fedcostaware_async", seed=0,
                          buffer_k=3)
        r = FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)
        # f1 (300s epoch) buffers its round-0 result by ~500s, long
        # before strag (900s) closes the buffer at ~1200s; preempting at
        # 700s hits f1 while that result sits in the open buffer (f1 is
        # already mid-flight on its next epoch). The buffered result
        # must still aggregate into round 0.
        def preempt_f1():
            inst = r.cluster.instance_of("f1")
            assert inst is not None
            assert r.sim.preempt(inst)
        r.sim.schedule(700.0, preempt_f1)
        res = r.run()
        preempted = [rec for rec in r.recorder.records
                     if rec["type"] == "InstancePreempted"]
        assert any(p["instance"]["$instance"]["client"] == "f1"
                   for p in preempted)
        assert "f1" in res.per_round_participants[0]
        assert res.rounds_completed == 4
        assert any("f1" in p for p in res.per_round_participants[1:])

    def test_budget_exhaustion_mid_buffer(self):
        """A client excluded at a round boundary while the next buffer
        is filling: its in-flight task goes stale, the effective buffer
        target shrinks below buffer_k, and the run still completes."""
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("mid", 400, n_samples=1, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        res = run_policy("fedcostaware_async", clients=clients,
                         n_epochs=10, buffer_k=3)
        assert "poor" in res.excluded_clients
        assert res.rounds_completed == 10
        exclusion_round = next(
            i for i, p in enumerate(res.per_round_participants)
            if "poor" not in p)
        # never reappears once the ledger excluded it
        for p in res.per_round_participants[exclusion_round:]:
            assert "poor" not in p
        # post-exclusion rounds aggregate with the clamped pool of 2
        assert all(0 < len(p) <= 2
                   for p in res.per_round_participants[exclusion_round:])

    def test_budget_exhaustion_terminates_instance(self):
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=10,
                          policy="fedcostaware_async", seed=0)
        r = FLCloudRunner(cfg, cloud_cfg=CLOUD)
        res = r.run()
        assert "poor" in res.excluded_clients
        assert r.cluster.instance_of("poor") is None
        # spend stops at exclusion: poor's cost never exceeds budget by
        # more than the already-open billing segment's minimum charge
        assert res.per_client_cost["poor"] < 0.15


# ---------------------------------------------------------------------------
# ClientReady resume tokens pass through the cluster untouched.
# ---------------------------------------------------------------------------
class TestClusterEvents:
    def test_client_ready_published_for_tracked_instance(self):
        r = FLCloudRunner(FLRunConfig(
            dataset="t", clients=CLIENTS, n_epochs=2,
            policy="fedcostaware", seed=0), cloud_cfg=CLOUD)
        seen = []
        r.bus.subscribe(ClientReady, lambda ev: seen.append(ev.client))
        r.run()
        assert set(seen) >= {"slow", "mid", "fast"}


# ---------------------------------------------------------------------------
# Staleness reporting: the async engine tags each buffered result with
# its dispatch round and hands hooks the FedBuff staleness; the sync
# barrier reports nothing (every update is fresh). Legacy 2-argument
# hook overrides keep working.
# ---------------------------------------------------------------------------
class TestStalenessReporting:
    class _Recorder:
        def __init__(self):
            self.calls = []

        def run_local(self, client, round_idx):
            pass

        def aggregate(self, participants, round_idx, staleness=None):
            self.calls.append((round_idx, list(participants),
                               dict(staleness or {})))

    class _Legacy:
        """Pre-redesign hook signature: no staleness parameter."""

        def __init__(self):
            self.calls = 0

        def run_local(self, client, round_idx):
            pass

        def aggregate(self, participants, round_idx):
            self.calls += 1

    def _run(self, policy, hooks, clients=None, n_epochs=6):
        clients = clients or (
            ClientProfile("slow", mean_epoch_s=450, jitter=0.0,
                          n_samples=2),
            ClientProfile("fast", mean_epoch_s=150, jitter=0.0,
                          n_samples=1),
        )
        cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=n_epochs,
                          policy=policy, seed=0)
        return FLCloudRunner(cfg, cloud_cfg=CLOUD, hooks=hooks).run()

    def test_async_straggler_reports_positive_staleness(self):
        hooks = self._Recorder()
        res = self._run("fedcostaware_async", hooks)
        assert len(hooks.calls) == res.rounds_completed
        stale = {c: s for _, _, st in hooks.calls for c, s in st.items()}
        assert all(s >= 0 for s in stale.values())
        # the slow client's result lands rounds after its dispatch
        flat = [s for _, _, st in hooks.calls for s in st.values()]
        assert any(s > 0 for s in flat), flat
        # fresh results are reported fresh
        assert any(s == 0 for s in flat)

    def test_sync_barrier_reports_no_staleness(self):
        hooks = self._Recorder()
        res = self._run("fedcostaware", hooks)
        assert len(hooks.calls) == res.rounds_completed
        assert all(st == {} for _, _, st in hooks.calls)

    @pytest.mark.parametrize("policy",
                             ["fedcostaware", "fedcostaware_async"])
    def test_legacy_two_arg_hooks_still_work(self, policy):
        hooks = self._Legacy()
        res = self._run(policy, hooks)
        assert hooks.calls == res.rounds_completed

    def test_legacy_signature_warns_at_construction(self):
        """The signature is sniffed once when the engine is built (not
        per aggregation), and the legacy form deprecation-warns."""
        with pytest.warns(DeprecationWarning,
                          match="legacy 2-argument signature"):
            self._run("fedcostaware", self._Legacy())

    def test_staleness_signature_does_not_warn(self):
        import warnings
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            self._run("fedcostaware_async", self._Recorder())
        assert not [w for w in caught
                    if "legacy 2-argument" in str(w.message)]
