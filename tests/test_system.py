"""System-level behaviour tests: the paper's headline claims hold
end-to-end through the full stack (simulator + scheduler + policies)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.table1 import ROWS, run_row   # noqa: E402


@pytest.mark.parametrize("row", ROWS, ids=[r.dataset for r in ROWS])
def test_table1_reproduction_within_tolerance(row):
    """Every Table I cell within 2% of the paper's reported cost."""
    for policy in ("on_demand", "spot", "fedcostaware"):
        res = run_row(row, policy)
        rel = abs(res.total_cost - row.target[policy]) / row.target[policy]
        assert rel < 0.02, (row.dataset, policy, res.total_cost,
                            row.target[policy])


@pytest.mark.parametrize("row", ROWS, ids=[r.dataset for r in ROWS])
def test_savings_ordering(row):
    od = run_row(row, "on_demand").total_cost
    sp = run_row(row, "spot").total_cost
    fca = run_row(row, "fedcostaware").total_cost
    assert fca < sp < od
    # spot saving is the price ratio (paper: ~60.8%)
    assert 1 - sp / od == pytest.approx(
        1 - row.spot_rate / row.od_rate, abs=0.01)


def test_headline_peak_saving():
    """Paper abstract: 'up to 72.22% cost savings' (CIFAR-10 row)."""
    row = next(r for r in ROWS if r.dataset == "CIFAR-10")
    od = run_row(row, "on_demand").total_cost
    fca = run_row(row, "fedcostaware").total_cost
    assert 100 * (1 - fca / od) == pytest.approx(72.22, abs=1.0)
