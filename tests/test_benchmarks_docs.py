"""benchmarks/README.md must stay in sync with the scripts' `--help`
output: every flag an argparse-driven benchmark advertises has to be
documented, and every benchmark module has to have a section.

docs/reporting.md gets the same treatment for the
`python -m repro.cloud.report` CLI: every subcommand needs a section
and every flag its `--help` advertises must appear backticked.
"""
import contextlib
import io
import importlib
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

README = (REPO / "benchmarks" / "README.md").read_text()

# every script that parses flags via argparse main(argv)
ARGPARSE_SCRIPTS = ["table1", "fig4_timeline", "fig5_costs", "multicloud",
                    "preemption_realism", "forecast_prewarm",
                    "forecast_quality", "scaling", "sweep"]
_FLAG = re.compile(r"(--[a-z][a-z0-9-]*)")


def help_text(script: str) -> str:
    """Capture `python -m benchmarks.<script> --help` in-process."""
    mod = importlib.import_module(f"benchmarks.{script}")
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf), pytest.raises(SystemExit):
        mod.main(["--help"])
    return buf.getvalue()


class TestFlagsDocumented:
    @pytest.mark.parametrize("script", ARGPARSE_SCRIPTS)
    def test_every_help_flag_appears_in_readme(self, script):
        flags = set(_FLAG.findall(help_text(script))) - {"--help"}
        assert flags, f"{script} --help advertised no flags?"
        missing = sorted(f for f in flags if f"`{f}" not in README)
        assert not missing, (
            f"benchmarks/README.md does not document {script} "
            f"flag(s): {missing}")

    @pytest.mark.parametrize("script", ARGPARSE_SCRIPTS)
    def test_script_has_a_section(self, script):
        assert f"## {script}" in README


class TestEveryScriptMentioned:
    def test_all_benchmark_modules_appear(self):
        scripts = sorted(p.stem for p in (REPO / "benchmarks").glob("*.py")
                         if p.stem != "__init__")
        missing = [s for s in scripts if s not in README]
        assert not missing, (
            f"benchmarks/README.md is missing section(s) for: {missing}")


# ---------------------------------------------------------------------------
# The report CLI (`python -m repro.cloud.report`) vs docs/reporting.md.
# ---------------------------------------------------------------------------
REPORTING_MD = (REPO / "docs" / "reporting.md").read_text()
REPORT_SUBCOMMANDS = ["summary", "trends", "reconcile", "validate"]


def report_help(subcommand: str) -> str:
    """Capture `python -m repro.cloud.report <sub> --help` in-process."""
    from repro.cloud.report import main
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf), pytest.raises(SystemExit):
        main([subcommand, "--help"])
    return buf.getvalue()


class TestReportCliDocumented:
    @pytest.mark.parametrize("sub", REPORT_SUBCOMMANDS)
    def test_subcommand_has_a_section(self, sub):
        assert f"## {sub}" in REPORTING_MD, (
            f"docs/reporting.md has no `## {sub}` section")

    @pytest.mark.parametrize("sub", REPORT_SUBCOMMANDS)
    def test_every_help_flag_appears_in_reporting_md(self, sub):
        flags = set(_FLAG.findall(report_help(sub))) - {"--help"}
        missing = sorted(f for f in flags
                         if f"`{f}" not in REPORTING_MD)
        assert not missing, (
            f"docs/reporting.md does not document report {sub} "
            f"flag(s): {missing}")

    def test_top_level_help_names_every_subcommand(self):
        from repro.cloud.report import main
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf), pytest.raises(SystemExit):
            main(["--help"])
        for sub in REPORT_SUBCOMMANDS:
            assert sub in buf.getvalue()

    def test_benchmarks_readme_points_at_the_cli(self):
        assert "repro.cloud.report" in README
        assert "docs/reporting.md" in README
