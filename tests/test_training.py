"""Real-training bridge (repro.fl.training): sharded LM client steps
behind the engine hook protocol, payload-exact egress billing, the
quantized-update accuracy/egress trade, and step-time calibration
against the measured-peak roofline."""
import os

# one host device per simulated client; must precede jax import (any
# earlier test that initialized jax wins — the skipif below catches it)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=2")

import jax
import numpy as np
import pytest

from repro.common.config import (CloudConfig, ClientProfile, FLRunConfig,
                                 MarketConfig, ProviderConfig)
from repro.comms.payload import UpdatePayload
from repro.fl.runner import FLCloudRunner
from repro.fl.training import (MeshTrainerHooks, StepCalibration,
                               calibrate, calibrated_profiles)

N_CLIENTS = 2
NAMES = tuple(f"client_{i}" for i in range(N_CLIENTS))

# egress priced + uplink modeled, so real runs bill nonzero comm_cost
COMM_MARKET = MarketConfig(providers=(
    ProviderConfig(name="aws", on_demand_rate=1.0, spot_rate_mean=0.4,
                   spot_rate_sigma=0.0,
                   update_egress_usd_per_mb=0.001, uplink_mbps=100.0),))

needs_devices = pytest.mark.skipif(
    jax.device_count() < N_CLIENTS,
    reason="needs >=2 devices (XLA_FLAGS set too late — another test "
    "initialized jax first)")


def make_hooks(quantize=False, seed=0):
    return MeshTrainerHooks(NAMES, local_steps=1, batch=2, seq=8,
                            quantize=quantize, seed=seed)


def run_real(hooks, rounds=2, quantize=False, seed=0):
    clients = tuple(
        ClientProfile(n, mean_epoch_s=60.0 + 30.0 * i, jitter=0.0)
        for i, n in enumerate(NAMES))
    cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=rounds,
                      policy="fedcostaware", seed=seed,
                      quantize_updates=quantize)
    cloud = CloudConfig(spot_rate_sigma=0.0, market=COMM_MARKET)
    return FLCloudRunner(cfg, cloud_cfg=cloud, hooks=hooks).run()


# ---------------------------------------------------------------------------
# The bridge end to end: real jitted steps inside the simulated loop.
# ---------------------------------------------------------------------------
@pytest.mark.slow
@needs_devices
class TestMeshTrainerBridge:
    def test_real_run_trains_and_bills_real_payload(self):
        hooks = make_hooks()
        res = run_real(hooks, rounds=2)
        assert res.rounds_completed == 2
        assert len(hooks.losses) == 2
        assert np.isfinite(hooks.final_loss())
        # egress was billed off the live param pytree, not a modeled MB
        want = UpdatePayload.from_tree(hooks.global_params())
        assert res.comm_cost == pytest.approx(
            0.001 * want.size_mb * N_CLIENTS * 2)

    def test_aggregation_moves_the_global_model(self):
        hooks = make_hooks()
        before = jax.tree.map(np.asarray, hooks.global_params())
        run_real(hooks, rounds=1)
        after = hooks.global_params()
        moved = any(
            not np.allclose(np.asarray(a), b, atol=0)
            for a, b in zip(jax.tree_util.tree_leaves(after),
                            jax.tree_util.tree_leaves(before)))
        assert moved

    def test_quantized_egress_cheaper_at_bounded_loss_delta(self):
        fp_hooks = make_hooks(quantize=False)
        fp = run_real(fp_hooks, rounds=2)
        q_hooks = make_hooks(quantize=True)
        q = run_real(q_hooks, rounds=2, quantize=True)
        assert 0.0 < q.comm_cost < fp.comm_cost
        # the int8 codec must not distort training: the pinned bound
        # the --assert-comm-win benchmark gate enforces too
        delta = abs(q_hooks.final_loss() - fp_hooks.final_loss())
        assert delta <= 0.75


# ---------------------------------------------------------------------------
# Calibration: measured step time -> simulated epoch durations.
# ---------------------------------------------------------------------------
@pytest.mark.slow
@needs_devices
class TestCalibrationMeasured:
    def test_calibration_within_3x_of_roofline(self):
        hooks = make_hooks()
        cal = calibrate(hooks)
        assert cal.measured_round_s > 0.0
        assert cal.roofline_round_s > 0.0
        # the ISSUE acceptance band: measured within 3x of the
        # measured-peak roofline estimate (combine="sum" host model)
        assert 1.0 / 3.0 <= cal.ratio <= 3.0, cal

    def test_calibrated_epoch_differs_from_config_default(self):
        hooks = make_hooks()
        cal = calibrate(hooks)
        default = ClientProfile("c", mean_epoch_s=600.0)
        out = calibrated_profiles([default], cal, time_scale=1.0)
        assert out[0].mean_epoch_s != default.mean_epoch_s
        assert out[0].mean_epoch_s == pytest.approx(cal.measured_round_s)


# ---------------------------------------------------------------------------
# Pure profile math (no devices, runs in the fast tier).
# ---------------------------------------------------------------------------
class TestCalibrationMath:
    CAL = StepCalibration(measured_round_s=0.02, roofline_round_s=0.01,
                          flops=1e9, bytes_accessed=1e8,
                          host_peak_flops=1e11, host_bw=1e10)

    def test_ratio_and_time_scale(self):
        assert self.CAL.ratio == pytest.approx(2.0)
        assert self.CAL.mean_epoch_s(1000.0) == pytest.approx(20.0)

    def test_profiles_rescale_preserving_heterogeneity(self):
        profiles = [ClientProfile("a", mean_epoch_s=300.0),
                    ClientProfile("b", mean_epoch_s=600.0)]
        out = calibrated_profiles(profiles, self.CAL, time_scale=1000.0)
        # cohort mean lands on the measured anchor...
        assert np.mean([p.mean_epoch_s for p in out]) == \
            pytest.approx(20.0)
        # ...and the 2x client spread survives
        assert out[1].mean_epoch_s == pytest.approx(
            2.0 * out[0].mean_epoch_s)
        # everything else is untouched
        assert out[0].name == "a" and out[0].jitter == profiles[0].jitter
