"""Golden-trace regression tests for the event-log record/replay
subsystem.

Checked-in fixtures under tests/golden/ are full recorded event streams
(2 clients x 3 rounds, pinned seed) for the three spot-market policies,
plus the Fed-ISIC2019 FedCostAware row that backs the paper-claims
tests. A fresh run must reproduce each golden log field-for-field
(numeric fields to 1e-9) — any event-schema change, engine-ordering
drift, or pricing change fails here loudly. Replaying a golden trace
through a price-book-free `CostAccountant` must reproduce the pinned
dollar totals, and replaying a fresh recording of the
tests/test_engines.py config must land on that suite's pinned
pre-refactor totals.

Regenerate fixtures after an *intentional* schema/engine change with:

    PYTHONPATH=src python tests/test_golden_traces.py --regenerate
"""
import json
import math
from pathlib import Path

import pytest

from repro.cloud.accounting import CostAccountant
from repro.common.config import (CloudConfig, ClientProfile, FLRunConfig,
                                 MarketConfig, ProviderConfig)
from repro.core.events import EventBus
from repro.core.eventlog import SCHEMA_VERSION, EventReplayer
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result, state_totals

GOLDEN_DIR = Path(__file__).parent / "golden"
GOLDEN_V1_DIR = GOLDEN_DIR / "v1"
GOLDEN_V2_DIR = GOLDEN_DIR / "v2"
GOLDEN_V3_DIR = GOLDEN_DIR / "v3"
GOLDEN_V4_DIR = GOLDEN_DIR / "v4"
GOLDEN_V5_DIR = GOLDEN_DIR / "v5"
GOLDEN_V6_DIR = GOLDEN_DIR / "v6"
GOLDEN_V7_DIR = GOLDEN_DIR / "v7"
FIXTURE_PRICES = Path(__file__).parent / "fixtures" / "prices"

CLOUD = CloudConfig(spot_rate_sigma=0.0)
CLIENTS = (
    ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=2),
    ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
)
POLICIES = ("fedcostaware", "spot", "fedcostaware_async")
# every per-object golden trace name that has a fresh-run factory
# (drift + live-vs-replay coverage): the three single-provider policies
# plus the cross-provider trace-market run
TRACES = tuple(f"golden__{p}" for p in POLICIES) + ("golden__multicloud",)
# the fleet-path golden (introduced at schema v7, FleetStepSummary
# aggregates with client_cost_delta attribution): the only engine mode
# with no per-instance events, exercised by its own
# replay/live-vs-replay tests — archived version dirs v1..v6 predate it
FLEET_TRACE = "golden__fleet"
ALL_TRACES = TRACES + (FLEET_TRACE,)

# Pinned replayed CostAccountant totals for the 2x3 golden configs
# (printed by `--regenerate`; update together with the fixtures). The
# three single-provider entries predate the SpotMarket redesign and
# must never move — they prove the default synthetic market is
# bit-identical across the provider-agnostic pricing rewrite.
GOLDEN_TOTALS = {
    "golden__fedcostaware": {
        "total": 0.5328913363302961,
        "per_client": {"slow": 0.30524109,
                       "fast": 0.22765024633029604},
    },
    "golden__spot": {
        "total": 0.613665141330296,
        "per_client": {"slow": 0.30524109,
                       "fast": 0.3084240513302961},
    },
    "golden__fedcostaware_async": {
        "total": 0.0984565136697039,
        "per_client": {"slow": 0.04763677616970391,
                       "fast": 0.05081973749999999},
    },
    "golden__multicloud": {
        "total": 0.4917434348080692,
        "per_client": {"slow": 0.28167149999999996,
                       "fast": 0.21007193480806924},
    },
    "golden__fleet": {
        "total": 1.6905134002340116,
        "per_client": {"c0": 0.24349844176276375,
                       "c1": 0.25958800309305985,
                       "c2": 0.26872494406847347,
                       "c3": 0.30663318688595503,
                       "c4": 0.3068277344237595,
                       "c5": 0.30524109},
    },
}


def make_runner(policy: str) -> FLCloudRunner:
    cfg = FLRunConfig(dataset="golden", clients=CLIENTS, n_epochs=3,
                      policy=policy, seed=0)
    return FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)


def make_multicloud_runner() -> FLCloudRunner:
    """2 clients x 3 rounds on a 2-provider trace-driven market with
    per-provider billing floors, cross-provider placement enabled."""
    market = MarketConfig(providers=(
        ProviderConfig(name="aws",
                       price_trace=str(FIXTURE_PRICES / "aws.csv")),
        ProviderConfig(name="gcp", on_demand_rate=0.95,
                       min_billing_s=30.0,
                       price_trace=str(FIXTURE_PRICES / "gcp.csv")),
    ))
    cfg = FLRunConfig(dataset="multicloud", clients=CLIENTS, n_epochs=3,
                      policy="fedcostaware", seed=0, cross_provider=True)
    return FLCloudRunner(cfg, cloud_cfg=CloudConfig(
        spot_rate_sigma=0.0, market=market), record=True)


FLEET_CLIENTS = tuple(
    ClientProfile(f"c{i}", mean_epoch_s=300.0 + 120.0 * i, jitter=0.0,
                  n_samples=1)
    for i in range(6))


def make_fleet_runner() -> FLCloudRunner:
    """6 clients x 3 rounds forced onto the vectorized fleet path
    (`fleet=True` far below `fleet_threshold`): one `FleetStepSummary`
    per round instead of per-instance events, deterministic under the
    sigma-0 market."""
    cfg = FLRunConfig(dataset="golden_fleet", clients=FLEET_CLIENTS,
                      n_epochs=3, policy="fedcostaware", seed=0,
                      fleet=True)
    return FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)


def runner_for(trace: str) -> FLCloudRunner:
    if trace == "golden__multicloud":
        return make_multicloud_runner()
    if trace == FLEET_TRACE:
        return make_fleet_runner()
    return make_runner(trace.split("__", 1)[1])


def make_fed_isic_runner() -> FLCloudRunner:
    from benchmarks.table1 import ROWS
    row = ROWS[0]
    clients = tuple(
        ClientProfile(f"client_{i}", mean_epoch_s=t, cold_multiplier=1.12,
                      jitter=0.0, n_samples=int(t))
        for i, t in enumerate(row.epoch_s))
    cloud = CloudConfig(on_demand_rate=row.od_rate,
                        spot_rate_mean=row.spot_rate / 0.98,
                        spot_rate_sigma=0.0, spin_up_mean_s=row.spin_up_s,
                        spin_up_sigma=0.0)
    cfg = FLRunConfig(dataset=row.dataset, clients=clients,
                      n_epochs=row.n_epochs, policy="fedcostaware", seed=0)
    return FLCloudRunner(cfg, cloud_cfg=cloud, record=True)


def trace_path(name: str) -> Path:
    return GOLDEN_DIR / f"{name}.events.jsonl"


FED_ISIC_TRACE = "fed_isic2019__fedcostaware"


def load_golden(name: str):
    lines = trace_path(name).read_text().splitlines()
    header = json.loads(lines[0])
    return header, [json.loads(ln) for ln in lines[1:]]


# ---------------------------------------------------------------------------
# Field-for-field comparison with numeric tolerance (float ops are
# deterministic per platform but may differ in the last ulp across
# libm builds).
# ---------------------------------------------------------------------------
def assert_json_equal(got, want, where="$"):
    if isinstance(want, float) or isinstance(got, float):
        assert isinstance(got, (int, float)) and \
            isinstance(want, (int, float)), where
        if math.isnan(want):
            assert math.isnan(got), where
        else:
            assert got == pytest.approx(want, abs=1e-9, rel=1e-12), where
    elif isinstance(want, dict):
        assert isinstance(got, dict) and set(got) == set(want), where
        for k in want:
            assert_json_equal(got[k], want[k], f"{where}.{k}")
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), \
            f"{where}: {len(got)} != {len(want)} entries"
        for i, (g, w) in enumerate(zip(got, want)):
            assert_json_equal(g, w, f"{where}[{i}]")
    else:
        assert got == want, f"{where}: {got!r} != {want!r}"


# ---------------------------------------------------------------------------
# The regression oracle: fresh run == checked-in golden log.
# ---------------------------------------------------------------------------
class TestGoldenDrift:
    @pytest.mark.parametrize("trace", ALL_TRACES)
    def test_fresh_run_reproduces_golden_log(self, trace):
        header, records = load_golden(trace)
        r = runner_for(trace)
        r.run()
        assert r.recorder.header["schema"] == header["schema"]
        got = json.loads(r.recorder.dumps().splitlines()[0])
        assert_json_equal(got, header, "$header")
        assert len(r.recorder.records) == len(records), \
            "event count drift — engine ordering or vocabulary changed"
        for i, (g, w) in enumerate(zip(r.recorder.records, records)):
            assert g["type"] == w["type"], \
                f"event[{i}] type drift: {g['type']} != {w['type']}"
            assert_json_equal(g, w, f"$event[{i}]({w['type']})")

    def test_fed_isic_trace_reproduced(self):
        header, records = load_golden(FED_ISIC_TRACE)
        r = make_fed_isic_runner()
        r.run()
        assert len(r.recorder.records) == len(records)
        for i, (g, w) in enumerate(zip(r.recorder.records, records)):
            assert_json_equal(g, w, f"$event[{i}]({w['type']})")


# ---------------------------------------------------------------------------
# Replay consumers reproduce the live run from the golden bytes alone.
# ---------------------------------------------------------------------------
class TestGoldenReplay:
    @pytest.mark.parametrize("trace", ALL_TRACES)
    def test_replayed_totals_match_pinned(self, trace):
        rep = replay_result(trace_path(trace))
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)
        assert rep.rounds_completed == 3

    @pytest.mark.parametrize("trace", TRACES)
    def test_replay_matches_live_run(self, trace):
        r = runner_for(trace)
        live = r.run()
        rep = replay_result(
            EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        for c in live.per_client_cost:
            assert rep.per_client_cost[c] == pytest.approx(
                live.per_client_cost[c], abs=1e-9)
        lt, rt = state_totals(live.timeline), state_totals(rep.timeline)
        assert set(lt) == set(rt)
        for k in lt:
            assert rt[k] == pytest.approx(lt[k], abs=1e-9), k
        assert rep.makespan_s == pytest.approx(live.makespan_s, abs=1e-9)
        assert [list(p) for p in rep.per_round_participants] == \
            live.per_round_participants

    def test_replayed_sync_totals_match_test_engines_pins(self):
        """The differential oracle closes the loop to the pre-refactor
        pinned values: record a fresh run of the tests/test_engines.py
        config, replay it, and land on the same dollars."""
        from test_engines import CLIENTS as ECLIENTS
        from test_engines import CLOUD as ECLOUD
        from test_engines import GOLDEN_SYNC
        for policy, want in GOLDEN_SYNC.items():
            cfg = FLRunConfig(dataset="t", clients=ECLIENTS, n_epochs=8,
                              policy=policy, seed=0)
            r = FLCloudRunner(cfg, cloud_cfg=ECLOUD, record=True)
            r.run()
            rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
            assert rep.total_cost == pytest.approx(want, abs=1e-6), policy

    def test_fleet_replay_matches_live_run(self):
        """The fleet golden's aggregate stream alone rebuilds the live
        run's dollars: totals, per-client attribution (summed
        `client_cost_delta` folds) and makespan — participants /
        timeline stay live-only by design (no per-instance events)."""
        r = make_fleet_runner()
        live = r.run()
        rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        assert rep.has_client_costs
        assert set(rep.per_client_cost) == set(live.per_client_cost)
        for c in live.per_client_cost:
            assert rep.per_client_cost[c] == pytest.approx(
                live.per_client_cost[c], abs=1e-9)
        assert rep.makespan_s == pytest.approx(live.makespan_s, abs=1e-9)
        assert rep.rounds_completed == live.rounds_completed

    def test_schema_version_enforced(self):
        text = trace_path("golden__spot").read_text()
        lines = text.splitlines()
        header = json.loads(lines[0])
        header["schema"] = SCHEMA_VERSION + 1
        tampered = "\n".join([json.dumps(header)] + lines[1:])
        with pytest.raises(ValueError, match="schema"):
            EventReplayer.loads(tampered)

    def test_replay_without_simulator(self):
        """Replay never constructs a CloudSimulator / SpotMarket: the
        accountant runs market-free on the replay bus."""
        bus = EventBus()
        acct = CostAccountant(bus)          # no prices, no clock
        EventReplayer.load(trace_path("golden__fedcostaware")).replay(bus)
        want = GOLDEN_TOTALS["golden__fedcostaware"]
        assert acct.total_cost() == pytest.approx(want["total"], abs=1e-9)

    def test_multicloud_golden_places_cross_provider(self):
        """The cross-provider golden actually exercises the second
        provider: the trace-market fixture prices gcp below aws, so
        placements land there and snapshots carry the provider field."""
        _, records = load_golden("golden__multicloud")
        providers = {rec["instance"]["$instance"]["provider"]
                     for rec in records if "instance" in rec}
        assert "gcp" in providers


# ---------------------------------------------------------------------------
# Cross-version compat matrix. Every archived golden under
# tests/golden/v1..v7 plus the current (v8) mains must (a) load with
# its recorded schema, (b) replay to the pinned dollars, and (c)
# differ from the next version's archive by the header line alone —
# every schema bump so far has been additive (v2 additionally stamped
# the provider key onto instance snapshots, handled below). Growing to
# schema v9 means archiving the v8 goldens under tests/golden/v8 and
# appending one `SCHEMA_DIRS` row — not writing a new class.
# ---------------------------------------------------------------------------
SCHEMA_DIRS = {1: GOLDEN_V1_DIR, 2: GOLDEN_V2_DIR, 3: GOLDEN_V3_DIR,
               4: GOLDEN_V4_DIR, 5: GOLDEN_V5_DIR, 6: GOLDEN_V6_DIR,
               7: GOLDEN_V7_DIR, SCHEMA_VERSION: GOLDEN_DIR}


def archived_traces(version: int) -> tuple:
    """The trace set archived for a schema version: v1 predates the
    multi-cloud market (no multicloud golden), and the fleet golden
    joined at v7."""
    base = (tuple(f"golden__{p}" for p in POLICIES) if version == 1
            else TRACES)
    extra = (FLEET_TRACE,) if version >= 7 else ()
    return base + (FED_ISIC_TRACE,) + extra


LOAD_MATRIX = [(v, name) for v in SCHEMA_DIRS
               for name in archived_traces(v)]
TOTALS_MATRIX = [(v, name) for v in SCHEMA_DIRS
                 for name in archived_traces(v) if name in GOLDEN_TOTALS]
# adjacent-version equivalence pairs (older, trace): compared against
# version older+1 over the traces archived at the older version
PAIR_MATRIX = [(v, name) for v in SCHEMA_DIRS if v < SCHEMA_VERSION
               for name in archived_traces(v)]


class TestSchemaCompatMatrix:
    @pytest.mark.parametrize("version,name", LOAD_MATRIX)
    def test_trace_loads(self, version, name):
        rep = EventReplayer.load(
            SCHEMA_DIRS[version] / f"{name}.events.jsonl")
        assert rep.header["schema"] == version

    @pytest.mark.parametrize("version,trace", TOTALS_MATRIX)
    def test_replay_matches_pinned_totals(self, version, trace):
        rep = replay_result(
            SCHEMA_DIRS[version] / f"{trace}.events.jsonl")
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)
        # invariants that hold matrix-wide: every archived golden
        # carries full per-client attribution (BillingTicks, or v7
        # fleet summaries with client_cost_delta), and none predates
        # comms pricing with a nonzero transfer spend
        assert rep.has_client_costs
        assert rep.comm_cost == 0.0

    def test_v1_instance_refs_get_default_provider(self):
        rep = EventReplayer.load(
            GOLDEN_V1_DIR / "golden__spot.events.jsonl")
        insts = [ev.instance for ev in rep.events
                 if hasattr(ev, "instance")]
        assert insts and all(i.provider == "aws" for i in insts)

    @pytest.mark.parametrize("older,name", PAIR_MATRIX)
    def test_adjacent_streams_differ_by_header_only(self, older, name):
        """Field-for-field: each archived golden differs from the next
        version's copy only by the header's schema field — every bump
        was additive. The v1 -> v2 pair additionally gained the
        provider key on instance snapshots (asserted to be the
        single-provider default)."""
        newer = older + 1
        h_old, recs_old = load_golden(f"v{older}/{name}")
        new_rel = (name if newer == SCHEMA_VERSION
                   else f"v{newer}/{name}")
        h_new, recs_new = load_golden(new_rel)
        assert h_old["schema"] == older and h_new["schema"] == newer
        assert {k: v for k, v in h_old.items() if k != "schema"} == \
            {k: v for k, v in h_new.items() if k != "schema"}
        assert len(recs_old) == len(recs_new)
        for r_old, r_new in zip(recs_old, recs_new):
            if older == 1 and "instance" in r_new:
                snap = dict(r_new["instance"]["$instance"])
                assert snap.pop("provider") == "aws"
                r_new = dict(r_new, instance={"$instance": snap})
            assert_json_equal(r_new, r_old)


# ---------------------------------------------------------------------------
# Fixture regeneration (documented in docs/events.md).
# ---------------------------------------------------------------------------
def regenerate():
    # run everything first, write fixtures only once all runs succeeded
    # (a mid-way crash must not leave the goldens half-regenerated)
    totals = {}
    recorders = {}
    for trace in ALL_TRACES:
        r = runner_for(trace)
        res = r.run()
        recorders[trace] = r.recorder
        totals[trace] = {
            "total": res.total_cost,
            "per_client": dict(res.per_client_cost),
        }
    r = make_fed_isic_runner()
    r.run()
    recorders[FED_ISIC_TRACE] = r.recorder
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for name, rec in recorders.items():
        rec.dump(trace_path(name))
    print("GOLDEN_TOTALS =", json.dumps(totals, indent=4))


if __name__ == "__main__":
    import sys
    # make `PYTHONPATH=src python tests/test_golden_traces.py` work from
    # the repo root regardless of PYTHONPATH: the fed-isic config lives
    # in the top-level `benchmarks` package.
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    if "--regenerate" in sys.argv:
        regenerate()
    else:
        print(__doc__)
