"""Golden-trace regression tests for the event-log record/replay
subsystem.

Checked-in fixtures under tests/golden/ are full recorded event streams
(2 clients x 3 rounds, pinned seed) for the three spot-market policies,
plus the Fed-ISIC2019 FedCostAware row that backs the paper-claims
tests. A fresh run must reproduce each golden log field-for-field
(numeric fields to 1e-9) — any event-schema change, engine-ordering
drift, or pricing change fails here loudly. Replaying a golden trace
through a price-book-free `CostAccountant` must reproduce the pinned
dollar totals, and replaying a fresh recording of the
tests/test_engines.py config must land on that suite's pinned
pre-refactor totals.

Regenerate fixtures after an *intentional* schema/engine change with:

    PYTHONPATH=src python tests/test_golden_traces.py --regenerate
"""
import json
import math
from pathlib import Path

import pytest

from repro.cloud.accounting import CostAccountant
from repro.common.config import CloudConfig, ClientProfile, FLRunConfig
from repro.core.events import EventBus
from repro.core.eventlog import SCHEMA_VERSION, EventReplayer
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result, state_totals

GOLDEN_DIR = Path(__file__).parent / "golden"

CLOUD = CloudConfig(spot_rate_sigma=0.0)
CLIENTS = (
    ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=2),
    ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
)
POLICIES = ("fedcostaware", "spot", "fedcostaware_async")

# Pinned replayed CostAccountant totals for the 2x3 golden configs
# (printed by `--regenerate`; update together with the fixtures).
GOLDEN_TOTALS = {
    "fedcostaware": {
        "total": 0.5328913363302961,
        "per_client": {"slow": 0.30524109,
                       "fast": 0.22765024633029604},
    },
    "spot": {
        "total": 0.613665141330296,
        "per_client": {"slow": 0.30524109,
                       "fast": 0.3084240513302961},
    },
    "fedcostaware_async": {
        "total": 0.0984565136697039,
        "per_client": {"slow": 0.04763677616970391,
                       "fast": 0.05081973749999999},
    },
}


def make_runner(policy: str) -> FLCloudRunner:
    cfg = FLRunConfig(dataset="golden", clients=CLIENTS, n_epochs=3,
                      policy=policy, seed=0)
    return FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)


def make_fed_isic_runner() -> FLCloudRunner:
    from benchmarks.table1 import ROWS
    row = ROWS[0]
    clients = tuple(
        ClientProfile(f"client_{i}", mean_epoch_s=t, cold_multiplier=1.12,
                      jitter=0.0, n_samples=int(t))
        for i, t in enumerate(row.epoch_s))
    cloud = CloudConfig(on_demand_rate=row.od_rate,
                        spot_rate_mean=row.spot_rate / 0.98,
                        spot_rate_sigma=0.0, spin_up_mean_s=row.spin_up_s,
                        spin_up_sigma=0.0)
    cfg = FLRunConfig(dataset=row.dataset, clients=clients,
                      n_epochs=row.n_epochs, policy="fedcostaware", seed=0)
    return FLCloudRunner(cfg, cloud_cfg=cloud, record=True)


def trace_path(name: str) -> Path:
    return GOLDEN_DIR / f"{name}.events.jsonl"


FED_ISIC_TRACE = "fed_isic2019__fedcostaware"


def load_golden(name: str):
    lines = trace_path(name).read_text().splitlines()
    header = json.loads(lines[0])
    return header, [json.loads(ln) for ln in lines[1:]]


# ---------------------------------------------------------------------------
# Field-for-field comparison with numeric tolerance (float ops are
# deterministic per platform but may differ in the last ulp across
# libm builds).
# ---------------------------------------------------------------------------
def assert_json_equal(got, want, where="$"):
    if isinstance(want, float) or isinstance(got, float):
        assert isinstance(got, (int, float)) and \
            isinstance(want, (int, float)), where
        if math.isnan(want):
            assert math.isnan(got), where
        else:
            assert got == pytest.approx(want, abs=1e-9, rel=1e-12), where
    elif isinstance(want, dict):
        assert isinstance(got, dict) and set(got) == set(want), where
        for k in want:
            assert_json_equal(got[k], want[k], f"{where}.{k}")
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), \
            f"{where}: {len(got)} != {len(want)} entries"
        for i, (g, w) in enumerate(zip(got, want)):
            assert_json_equal(g, w, f"{where}[{i}]")
    else:
        assert got == want, f"{where}: {got!r} != {want!r}"


# ---------------------------------------------------------------------------
# The regression oracle: fresh run == checked-in golden log.
# ---------------------------------------------------------------------------
class TestGoldenDrift:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_fresh_run_reproduces_golden_log(self, policy):
        header, records = load_golden(f"golden__{policy}")
        r = make_runner(policy)
        r.run()
        assert r.recorder.header["schema"] == header["schema"]
        got = json.loads(r.recorder.dumps().splitlines()[0])
        assert_json_equal(got, header, "$header")
        assert len(r.recorder.records) == len(records), \
            "event count drift — engine ordering or vocabulary changed"
        for i, (g, w) in enumerate(zip(r.recorder.records, records)):
            assert g["type"] == w["type"], \
                f"event[{i}] type drift: {g['type']} != {w['type']}"
            assert_json_equal(g, w, f"$event[{i}]({w['type']})")

    def test_fed_isic_trace_reproduced(self):
        header, records = load_golden(FED_ISIC_TRACE)
        r = make_fed_isic_runner()
        r.run()
        assert len(r.recorder.records) == len(records)
        for i, (g, w) in enumerate(zip(r.recorder.records, records)):
            assert_json_equal(g, w, f"$event[{i}]({w['type']})")


# ---------------------------------------------------------------------------
# Replay consumers reproduce the live run from the golden bytes alone.
# ---------------------------------------------------------------------------
class TestGoldenReplay:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_replayed_totals_match_pinned(self, policy):
        rep = replay_result(trace_path(f"golden__{policy}"))
        want = GOLDEN_TOTALS[policy]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)
        assert rep.rounds_completed == 3

    @pytest.mark.parametrize("policy", POLICIES)
    def test_replay_matches_live_run(self, policy):
        r = make_runner(policy)
        live = r.run()
        rep = replay_result(
            EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        for c in live.per_client_cost:
            assert rep.per_client_cost[c] == pytest.approx(
                live.per_client_cost[c], abs=1e-9)
        lt, rt = state_totals(live.timeline), state_totals(rep.timeline)
        assert set(lt) == set(rt)
        for k in lt:
            assert rt[k] == pytest.approx(lt[k], abs=1e-9), k
        assert rep.makespan_s == pytest.approx(live.makespan_s, abs=1e-9)
        assert [list(p) for p in rep.per_round_participants] == \
            live.per_round_participants

    def test_replayed_sync_totals_match_test_engines_pins(self):
        """The differential oracle closes the loop to the pre-refactor
        pinned values: record a fresh run of the tests/test_engines.py
        config, replay it, and land on the same dollars."""
        from test_engines import CLIENTS as ECLIENTS
        from test_engines import CLOUD as ECLOUD
        from test_engines import GOLDEN_SYNC
        for policy, want in GOLDEN_SYNC.items():
            cfg = FLRunConfig(dataset="t", clients=ECLIENTS, n_epochs=8,
                              policy=policy, seed=0)
            r = FLCloudRunner(cfg, cloud_cfg=ECLOUD, record=True)
            r.run()
            rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
            assert rep.total_cost == pytest.approx(want, abs=1e-6), policy

    def test_schema_version_enforced(self):
        text = trace_path("golden__spot").read_text()
        lines = text.splitlines()
        header = json.loads(lines[0])
        header["schema"] = SCHEMA_VERSION + 1
        tampered = "\n".join([json.dumps(header)] + lines[1:])
        with pytest.raises(ValueError, match="schema"):
            EventReplayer.loads(tampered)

    def test_replay_without_simulator(self):
        """Replay never constructs a CloudSimulator / PriceBook: the
        accountant runs price-book-free on the replay bus."""
        bus = EventBus()
        acct = CostAccountant(bus)          # no prices, no clock
        EventReplayer.load(trace_path("golden__fedcostaware")).replay(bus)
        want = GOLDEN_TOTALS["fedcostaware"]
        assert acct.total_cost() == pytest.approx(want["total"], abs=1e-9)


# ---------------------------------------------------------------------------
# Fixture regeneration (documented in README).
# ---------------------------------------------------------------------------
def regenerate():
    # run everything first, write fixtures only once all runs succeeded
    # (a mid-way crash must not leave the goldens half-regenerated)
    totals = {}
    recorders = {}
    for policy in POLICIES:
        r = make_runner(policy)
        res = r.run()
        recorders[f"golden__{policy}"] = r.recorder
        totals[policy] = {
            "total": res.total_cost,
            "per_client": dict(res.per_client_cost),
        }
    r = make_fed_isic_runner()
    r.run()
    recorders[FED_ISIC_TRACE] = r.recorder
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for name, rec in recorders.items():
        rec.dump(trace_path(name))
    print("GOLDEN_TOTALS =", json.dumps(totals, indent=4))


if __name__ == "__main__":
    import sys
    # make `PYTHONPATH=src python tests/test_golden_traces.py` work from
    # the repo root regardless of PYTHONPATH: the fed-isic config lives
    # in the top-level `benchmarks` package.
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    if "--regenerate" in sys.argv:
        regenerate()
    else:
        print(__doc__)
