"""Golden-trace regression tests for the event-log record/replay
subsystem.

Checked-in fixtures under tests/golden/ are full recorded event streams
(2 clients x 3 rounds, pinned seed) for the three spot-market policies,
plus the Fed-ISIC2019 FedCostAware row that backs the paper-claims
tests. A fresh run must reproduce each golden log field-for-field
(numeric fields to 1e-9) — any event-schema change, engine-ordering
drift, or pricing change fails here loudly. Replaying a golden trace
through a price-book-free `CostAccountant` must reproduce the pinned
dollar totals, and replaying a fresh recording of the
tests/test_engines.py config must land on that suite's pinned
pre-refactor totals.

Regenerate fixtures after an *intentional* schema/engine change with:

    PYTHONPATH=src python tests/test_golden_traces.py --regenerate
"""
import json
import math
from pathlib import Path

import pytest

from repro.cloud.accounting import CostAccountant
from repro.common.config import (CloudConfig, ClientProfile, FLRunConfig,
                                 MarketConfig, ProviderConfig)
from repro.core.events import EventBus
from repro.core.eventlog import SCHEMA_VERSION, EventReplayer
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result, state_totals

GOLDEN_DIR = Path(__file__).parent / "golden"
GOLDEN_V1_DIR = GOLDEN_DIR / "v1"
GOLDEN_V2_DIR = GOLDEN_DIR / "v2"
GOLDEN_V3_DIR = GOLDEN_DIR / "v3"
GOLDEN_V4_DIR = GOLDEN_DIR / "v4"
GOLDEN_V5_DIR = GOLDEN_DIR / "v5"
GOLDEN_V6_DIR = GOLDEN_DIR / "v6"
FIXTURE_PRICES = Path(__file__).parent / "fixtures" / "prices"

CLOUD = CloudConfig(spot_rate_sigma=0.0)
CLIENTS = (
    ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=2),
    ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
)
POLICIES = ("fedcostaware", "spot", "fedcostaware_async")
# every golden trace name that has a fresh-run factory (drift +
# live-vs-replay coverage): the three single-provider policies plus the
# cross-provider trace-market run
TRACES = tuple(f"golden__{p}" for p in POLICIES) + ("golden__multicloud",)

# Pinned replayed CostAccountant totals for the 2x3 golden configs
# (printed by `--regenerate`; update together with the fixtures). The
# three single-provider entries predate the SpotMarket redesign and
# must never move — they prove the default synthetic market is
# bit-identical across the provider-agnostic pricing rewrite.
GOLDEN_TOTALS = {
    "golden__fedcostaware": {
        "total": 0.5328913363302961,
        "per_client": {"slow": 0.30524109,
                       "fast": 0.22765024633029604},
    },
    "golden__spot": {
        "total": 0.613665141330296,
        "per_client": {"slow": 0.30524109,
                       "fast": 0.3084240513302961},
    },
    "golden__fedcostaware_async": {
        "total": 0.0984565136697039,
        "per_client": {"slow": 0.04763677616970391,
                       "fast": 0.05081973749999999},
    },
    "golden__multicloud": {
        "total": 0.4917434348080692,
        "per_client": {"slow": 0.28167149999999996,
                       "fast": 0.21007193480806924},
    },
}


def make_runner(policy: str) -> FLCloudRunner:
    cfg = FLRunConfig(dataset="golden", clients=CLIENTS, n_epochs=3,
                      policy=policy, seed=0)
    return FLCloudRunner(cfg, cloud_cfg=CLOUD, record=True)


def make_multicloud_runner() -> FLCloudRunner:
    """2 clients x 3 rounds on a 2-provider trace-driven market with
    per-provider billing floors, cross-provider placement enabled."""
    market = MarketConfig(providers=(
        ProviderConfig(name="aws",
                       price_trace=str(FIXTURE_PRICES / "aws.csv")),
        ProviderConfig(name="gcp", on_demand_rate=0.95,
                       min_billing_s=30.0,
                       price_trace=str(FIXTURE_PRICES / "gcp.csv")),
    ))
    cfg = FLRunConfig(dataset="multicloud", clients=CLIENTS, n_epochs=3,
                      policy="fedcostaware", seed=0, cross_provider=True)
    return FLCloudRunner(cfg, cloud_cfg=CloudConfig(
        spot_rate_sigma=0.0, market=market), record=True)


def runner_for(trace: str) -> FLCloudRunner:
    if trace == "golden__multicloud":
        return make_multicloud_runner()
    return make_runner(trace.split("__", 1)[1])


def make_fed_isic_runner() -> FLCloudRunner:
    from benchmarks.table1 import ROWS
    row = ROWS[0]
    clients = tuple(
        ClientProfile(f"client_{i}", mean_epoch_s=t, cold_multiplier=1.12,
                      jitter=0.0, n_samples=int(t))
        for i, t in enumerate(row.epoch_s))
    cloud = CloudConfig(on_demand_rate=row.od_rate,
                        spot_rate_mean=row.spot_rate / 0.98,
                        spot_rate_sigma=0.0, spin_up_mean_s=row.spin_up_s,
                        spin_up_sigma=0.0)
    cfg = FLRunConfig(dataset=row.dataset, clients=clients,
                      n_epochs=row.n_epochs, policy="fedcostaware", seed=0)
    return FLCloudRunner(cfg, cloud_cfg=cloud, record=True)


def trace_path(name: str) -> Path:
    return GOLDEN_DIR / f"{name}.events.jsonl"


FED_ISIC_TRACE = "fed_isic2019__fedcostaware"


def load_golden(name: str):
    lines = trace_path(name).read_text().splitlines()
    header = json.loads(lines[0])
    return header, [json.loads(ln) for ln in lines[1:]]


# ---------------------------------------------------------------------------
# Field-for-field comparison with numeric tolerance (float ops are
# deterministic per platform but may differ in the last ulp across
# libm builds).
# ---------------------------------------------------------------------------
def assert_json_equal(got, want, where="$"):
    if isinstance(want, float) or isinstance(got, float):
        assert isinstance(got, (int, float)) and \
            isinstance(want, (int, float)), where
        if math.isnan(want):
            assert math.isnan(got), where
        else:
            assert got == pytest.approx(want, abs=1e-9, rel=1e-12), where
    elif isinstance(want, dict):
        assert isinstance(got, dict) and set(got) == set(want), where
        for k in want:
            assert_json_equal(got[k], want[k], f"{where}.{k}")
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), \
            f"{where}: {len(got)} != {len(want)} entries"
        for i, (g, w) in enumerate(zip(got, want)):
            assert_json_equal(g, w, f"{where}[{i}]")
    else:
        assert got == want, f"{where}: {got!r} != {want!r}"


# ---------------------------------------------------------------------------
# The regression oracle: fresh run == checked-in golden log.
# ---------------------------------------------------------------------------
class TestGoldenDrift:
    @pytest.mark.parametrize("trace", TRACES)
    def test_fresh_run_reproduces_golden_log(self, trace):
        header, records = load_golden(trace)
        r = runner_for(trace)
        r.run()
        assert r.recorder.header["schema"] == header["schema"]
        got = json.loads(r.recorder.dumps().splitlines()[0])
        assert_json_equal(got, header, "$header")
        assert len(r.recorder.records) == len(records), \
            "event count drift — engine ordering or vocabulary changed"
        for i, (g, w) in enumerate(zip(r.recorder.records, records)):
            assert g["type"] == w["type"], \
                f"event[{i}] type drift: {g['type']} != {w['type']}"
            assert_json_equal(g, w, f"$event[{i}]({w['type']})")

    def test_fed_isic_trace_reproduced(self):
        header, records = load_golden(FED_ISIC_TRACE)
        r = make_fed_isic_runner()
        r.run()
        assert len(r.recorder.records) == len(records)
        for i, (g, w) in enumerate(zip(r.recorder.records, records)):
            assert_json_equal(g, w, f"$event[{i}]({w['type']})")


# ---------------------------------------------------------------------------
# Replay consumers reproduce the live run from the golden bytes alone.
# ---------------------------------------------------------------------------
class TestGoldenReplay:
    @pytest.mark.parametrize("trace", TRACES)
    def test_replayed_totals_match_pinned(self, trace):
        rep = replay_result(trace_path(trace))
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)
        assert rep.rounds_completed == 3

    @pytest.mark.parametrize("trace", TRACES)
    def test_replay_matches_live_run(self, trace):
        r = runner_for(trace)
        live = r.run()
        rep = replay_result(
            EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(live.total_cost, abs=1e-9)
        for c in live.per_client_cost:
            assert rep.per_client_cost[c] == pytest.approx(
                live.per_client_cost[c], abs=1e-9)
        lt, rt = state_totals(live.timeline), state_totals(rep.timeline)
        assert set(lt) == set(rt)
        for k in lt:
            assert rt[k] == pytest.approx(lt[k], abs=1e-9), k
        assert rep.makespan_s == pytest.approx(live.makespan_s, abs=1e-9)
        assert [list(p) for p in rep.per_round_participants] == \
            live.per_round_participants

    def test_replayed_sync_totals_match_test_engines_pins(self):
        """The differential oracle closes the loop to the pre-refactor
        pinned values: record a fresh run of the tests/test_engines.py
        config, replay it, and land on the same dollars."""
        from test_engines import CLIENTS as ECLIENTS
        from test_engines import CLOUD as ECLOUD
        from test_engines import GOLDEN_SYNC
        for policy, want in GOLDEN_SYNC.items():
            cfg = FLRunConfig(dataset="t", clients=ECLIENTS, n_epochs=8,
                              policy=policy, seed=0)
            r = FLCloudRunner(cfg, cloud_cfg=ECLOUD, record=True)
            r.run()
            rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
            assert rep.total_cost == pytest.approx(want, abs=1e-6), policy

    def test_schema_version_enforced(self):
        text = trace_path("golden__spot").read_text()
        lines = text.splitlines()
        header = json.loads(lines[0])
        header["schema"] = SCHEMA_VERSION + 1
        tampered = "\n".join([json.dumps(header)] + lines[1:])
        with pytest.raises(ValueError, match="schema"):
            EventReplayer.loads(tampered)

    def test_replay_without_simulator(self):
        """Replay never constructs a CloudSimulator / SpotMarket: the
        accountant runs market-free on the replay bus."""
        bus = EventBus()
        acct = CostAccountant(bus)          # no prices, no clock
        EventReplayer.load(trace_path("golden__fedcostaware")).replay(bus)
        want = GOLDEN_TOTALS["golden__fedcostaware"]
        assert acct.total_cost() == pytest.approx(want["total"], abs=1e-9)

    def test_multicloud_golden_places_cross_provider(self):
        """The cross-provider golden actually exercises the second
        provider: the trace-market fixture prices gcp below aws, so
        placements land there and snapshots carry the provider field."""
        _, records = load_golden("golden__multicloud")
        providers = {rec["instance"]["$instance"]["provider"]
                     for rec in records if "instance" in rec}
        assert "gcp" in providers


# ---------------------------------------------------------------------------
# v1 -> v2 compat: pre-redesign recordings (no provider field, schema 1)
# must still replay to the same pinned dollars.
# ---------------------------------------------------------------------------
class TestSchemaV1Compat:
    V1_TRACES = tuple(f"golden__{p}" for p in POLICIES) + (FED_ISIC_TRACE,)

    @pytest.mark.parametrize("name", V1_TRACES)
    def test_v1_trace_loads(self, name):
        rep = EventReplayer.load(GOLDEN_V1_DIR / f"{name}.events.jsonl")
        assert rep.header["schema"] == 1

    @pytest.mark.parametrize("policy", POLICIES)
    def test_v1_replay_matches_pinned_totals(self, policy):
        rep = replay_result(
            GOLDEN_V1_DIR / f"golden__{policy}.events.jsonl")
        want = GOLDEN_TOTALS[f"golden__{policy}"]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)

    def test_v1_instance_refs_get_default_provider(self):
        rep = EventReplayer.load(
            GOLDEN_V1_DIR / "golden__spot.events.jsonl")
        insts = [ev.instance for ev in rep.events
                 if hasattr(ev, "instance")]
        assert insts and all(i.provider == "aws" for i in insts)

    @pytest.mark.parametrize("policy", POLICIES)
    def test_v1_and_v2_streams_are_equivalent(self, policy):
        """Field-for-field: the archived v2 golden differs from its
        v1 ancestor only by the schema bump and the provider key each
        instance snapshot gained."""
        h1, recs1 = load_golden(f"v1/golden__{policy}")
        h2, recs2 = load_golden(f"v2/golden__{policy}")
        assert h1["schema"] == 1 and h2["schema"] == 2
        assert {k: v for k, v in h1.items() if k != "schema"} == \
            {k: v for k, v in h2.items() if k != "schema"}
        assert len(recs1) == len(recs2)
        for r1, r2 in zip(recs1, recs2):
            if "instance" in r2:
                snap = dict(r2["instance"]["$instance"])
                assert snap.pop("provider") == "aws"
                r2 = dict(r2, instance={"$instance": snap})
            assert_json_equal(r2, r1)


# ---------------------------------------------------------------------------
# v2 -> v3 compat: the checkpoint-vocabulary bump is purely additive
# (new event types only), so archived schema-2 recordings must replay
# unchanged and differ from the regenerated v3 goldens by the header
# alone.
# ---------------------------------------------------------------------------
class TestSchemaV2Compat:
    V2_TRACES = TRACES + (FED_ISIC_TRACE,)

    @pytest.mark.parametrize("name", V2_TRACES)
    def test_v2_trace_loads(self, name):
        rep = EventReplayer.load(GOLDEN_V2_DIR / f"{name}.events.jsonl")
        assert rep.header["schema"] == 2

    @pytest.mark.parametrize("trace", TRACES)
    def test_v2_replay_matches_pinned_totals(self, trace):
        rep = replay_result(GOLDEN_V2_DIR / f"{trace}.events.jsonl")
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)

    @pytest.mark.parametrize("name", V2_TRACES)
    def test_v2_and_v3_streams_are_equivalent(self, name):
        """The default path publishes none of the new v3 events, so the
        archived v3 goldens carry identical event bodies — only the
        header's schema field moved."""
        h2, recs2 = load_golden(f"v2/{name}")
        h3, recs3 = load_golden(f"v3/{name}")
        assert h2["schema"] == 2 and h3["schema"] == 3
        assert {k: v for k, v in h2.items() if k != "schema"} == \
            {k: v for k, v in h3.items() if k != "schema"}
        assert len(recs2) == len(recs3)
        for r2, r3 in zip(recs2, recs3):
            assert_json_equal(r3, r2)


# ---------------------------------------------------------------------------
# v3 -> v4 compat: the strategy-API bump is purely additive (new event
# types + an optional ClientCheckpointed field), so archived schema-3
# recordings must replay unchanged and differ from the regenerated v4
# goldens by the header alone — the acceptance proof that the strategy
# redesign moved zero events.
# ---------------------------------------------------------------------------
class TestSchemaV3Compat:
    V3_TRACES = TRACES + (FED_ISIC_TRACE,)

    @pytest.mark.parametrize("name", V3_TRACES)
    def test_v3_trace_loads(self, name):
        rep = EventReplayer.load(GOLDEN_V3_DIR / f"{name}.events.jsonl")
        assert rep.header["schema"] == 3

    @pytest.mark.parametrize("trace", TRACES)
    def test_v3_replay_matches_pinned_totals(self, trace):
        rep = replay_result(GOLDEN_V3_DIR / f"{trace}.events.jsonl")
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)

    @pytest.mark.parametrize("name", V3_TRACES)
    def test_v3_and_v4_streams_are_equivalent(self, name):
        """Under the composable strategy API the four Table-I policies
        publish the exact pre-redesign event bodies — only the
        header's schema field moved."""
        h3, recs3 = load_golden(f"v3/{name}")
        h4, recs4 = load_golden(f"v4/{name}")
        assert h3["schema"] == 3 and h4["schema"] == 4
        assert {k: v for k, v in h3.items() if k != "schema"} == \
            {k: v for k, v in h4.items() if k != "schema"}
        assert len(recs3) == len(recs4)
        for r3, r4 in zip(recs3, recs4):
            assert_json_equal(r4, r3)


# ---------------------------------------------------------------------------
# v4 -> v5 compat: the fleet-core bump is purely additive (one new
# aggregate event type, FleetStepSummary, published only by the
# vectorized fleet path), so archived schema-4 recordings must replay
# unchanged and differ from the regenerated v5 goldens by the header
# alone — the acceptance proof that runs below
# `CloudConfig.fleet_threshold` moved zero events.
# ---------------------------------------------------------------------------
class TestSchemaV4Compat:
    V4_TRACES = TRACES + (FED_ISIC_TRACE,)

    @pytest.mark.parametrize("name", V4_TRACES)
    def test_v4_trace_loads(self, name):
        rep = EventReplayer.load(GOLDEN_V4_DIR / f"{name}.events.jsonl")
        assert rep.header["schema"] == 4

    @pytest.mark.parametrize("trace", TRACES)
    def test_v4_replay_matches_pinned_totals(self, trace):
        rep = replay_result(GOLDEN_V4_DIR / f"{trace}.events.jsonl")
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)

    @pytest.mark.parametrize("name", V4_TRACES)
    def test_v4_and_v5_streams_are_equivalent(self, name):
        """Per-object runs publish no fleet summaries, so the four
        Table-I policies carry the exact pre-fleet event bodies — only
        the header's schema field moved."""
        h4, recs4 = load_golden(f"v4/{name}")
        h5, recs5 = load_golden(f"v5/{name}")
        assert h4["schema"] == 4 and h5["schema"] == 5
        assert {k: v for k, v in h4.items() if k != "schema"} == \
            {k: v for k, v in h5.items() if k != "schema"}
        assert len(recs4) == len(recs5)
        for r4, r5 in zip(recs4, recs5):
            assert_json_equal(r5, r4)


# ---------------------------------------------------------------------------
# v5 -> v6 compat: the per-client fleet-attribution bump is purely
# additive (one optional FleetStepSummary field, published only by the
# fleet path), so archived schema-5 recordings must replay unchanged
# and differ from the regenerated v6 goldens by the header alone.
# ---------------------------------------------------------------------------
class TestSchemaV5Compat:
    V5_TRACES = TRACES + (FED_ISIC_TRACE,)

    @pytest.mark.parametrize("name", V5_TRACES)
    def test_v5_trace_loads(self, name):
        rep = EventReplayer.load(GOLDEN_V5_DIR / f"{name}.events.jsonl")
        assert rep.header["schema"] == 5

    @pytest.mark.parametrize("trace", TRACES)
    def test_v5_replay_matches_pinned_totals(self, trace):
        rep = replay_result(GOLDEN_V5_DIR / f"{trace}.events.jsonl")
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)
        # per-object traces carry full BillingTick attribution, so even
        # a v5 log's per-client breakdown is complete
        assert rep.has_client_costs

    @pytest.mark.parametrize("name", V5_TRACES)
    def test_v5_and_v6_streams_are_equivalent(self, name):
        """Per-object runs publish no fleet summaries, so the goldens
        carry identical event bodies across the attribution bump — only
        the header's schema field moved."""
        h5, recs5 = load_golden(f"v5/{name}")
        h6, recs6 = load_golden(f"v6/{name}")
        assert h5["schema"] == 5 and h6["schema"] == 6
        assert {k: v for k, v in h5.items() if k != "schema"} == \
            {k: v for k, v in h6.items() if k != "schema"}
        assert len(recs5) == len(recs6)
        for r5, r6 in zip(recs5, recs6):
            assert_json_equal(r6, r5)


# ---------------------------------------------------------------------------
# v6 -> v7 compat: the comms bump is purely additive (ClientUpdateSent +
# TransferBilled, published only when a run enables comms modeling via
# `FLRunConfig.update_payload_mb` or payload-exposing trainer hooks), so
# archived schema-6 recordings must replay unchanged and differ from the
# regenerated v7 goldens by the header alone — the acceptance proof that
# zero-default transfer rates moved zero events.
# ---------------------------------------------------------------------------
class TestSchemaV6Compat:
    V6_TRACES = TRACES + (FED_ISIC_TRACE,)

    @pytest.mark.parametrize("name", V6_TRACES)
    def test_v6_trace_loads(self, name):
        rep = EventReplayer.load(GOLDEN_V6_DIR / f"{name}.events.jsonl")
        assert rep.header["schema"] == 6

    @pytest.mark.parametrize("trace", TRACES)
    def test_v6_replay_matches_pinned_totals(self, trace):
        rep = replay_result(GOLDEN_V6_DIR / f"{trace}.events.jsonl")
        want = GOLDEN_TOTALS[trace]
        assert rep.total_cost == pytest.approx(want["total"], abs=1e-9)
        for c, v in want["per_client"].items():
            assert rep.per_client_cost[c] == pytest.approx(v, abs=1e-9)
        # pre-comms logs naturally carry no transfer spend
        assert rep.comm_cost == 0.0

    @pytest.mark.parametrize("name", V6_TRACES)
    def test_v6_and_v7_streams_are_equivalent(self, name):
        """Comms-free runs publish no upload/transfer events, so the
        goldens carry identical event bodies across the comms bump —
        only the header's schema field moved."""
        h6, recs6 = load_golden(f"v6/{name}")
        h7, recs7 = load_golden(name)
        assert h6["schema"] == 6 and h7["schema"] == 7
        assert {k: v for k, v in h6.items() if k != "schema"} == \
            {k: v for k, v in h7.items() if k != "schema"}
        assert len(recs6) == len(recs7)
        for r6, r7 in zip(recs6, recs7):
            assert_json_equal(r7, r6)


# ---------------------------------------------------------------------------
# Fixture regeneration (documented in docs/events.md).
# ---------------------------------------------------------------------------
def regenerate():
    # run everything first, write fixtures only once all runs succeeded
    # (a mid-way crash must not leave the goldens half-regenerated)
    totals = {}
    recorders = {}
    for trace in TRACES:
        r = runner_for(trace)
        res = r.run()
        recorders[trace] = r.recorder
        totals[trace] = {
            "total": res.total_cost,
            "per_client": dict(res.per_client_cost),
        }
    r = make_fed_isic_runner()
    r.run()
    recorders[FED_ISIC_TRACE] = r.recorder
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    for name, rec in recorders.items():
        rec.dump(trace_path(name))
    print("GOLDEN_TOTALS =", json.dumps(totals, indent=4))


if __name__ == "__main__":
    import sys
    # make `PYTHONPATH=src python tests/test_golden_traces.py` work from
    # the repo root regardless of PYTHONPATH: the fed-isic config lives
    # in the top-level `benchmarks` package.
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    if "--regenerate" in sys.argv:
        regenerate()
    else:
        print(__doc__)
