"""Documentation health checks, enforced in tier-1 (and by the CI
`docs` job):

  * a docstring-coverage floor over the public API — the in-repo
    equivalent of `interrogate --fail-under` (which the CI docs job
    also runs), so the floor holds even where interrogate is not
    installed;
  * a markdown link check over README.md, docs/ and benchmarks/README.md
    so the reference set cannot rot silently: relative links must
    resolve, intra-doc anchors must match a real heading, and
    repo-path mentions in backticks must exist.
"""
import ast
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

# The public-API surface the docstring floor covers. Interrogate's CI
# invocation mirrors this list; keep the two in sync.
PUBLIC_MODULES = [
    "src/repro/core/events.py",
    "src/repro/core/eventlog.py",
    "src/repro/core/policies.py",
    "src/repro/core/strategy.py",
    "src/repro/cloud/pricing.py",
    "src/repro/cloud/simulator.py",
    "src/repro/cloud/preemption.py",
    "src/repro/cloud/traces.py",
    "src/repro/cloud/accounting.py",
    "src/repro/cloud/fleet.py",
    "src/repro/cloud/scenarios.py",
    "src/repro/cloud/report.py",
    "src/repro/fl/fleet.py",
    "src/repro/sweep/__init__.py",
    "src/repro/sweep/spec.py",
    "src/repro/sweep/runner.py",
    "src/repro/sweep/stats.py",
    "src/repro/sweep/report.py",
    "src/repro/fl/engines/base.py",
    "src/repro/fl/engines/__init__.py",
    "src/repro/fl/runner.py",
    "src/repro/fl/cluster.py",
    "src/repro/fl/telemetry.py",
    "src/repro/fl/types.py",
    "src/repro/fl/training.py",
    "src/repro/comms/__init__.py",
    "src/repro/comms/payload.py",
    "src/repro/comms/channel.py",
    "src/repro/comms/billing.py",
    "src/repro/forecast/__init__.py",
    "src/repro/forecast/feed.py",
    "src/repro/forecast/predictors.py",
    "src/repro/forecast/calibration.py",
    "src/repro/forecast/decision.py",
    "src/repro/forecast/strategy.py",
    "src/repro/checkpoint/store.py",
    "src/repro/checkpoint/snapshots.py",
]
DOC_COVERAGE_FLOOR = 0.9

MARKDOWN_FILES = ["README.md", "benchmarks/README.md",
                  "docs/index.md", "docs/architecture.md",
                  "docs/events.md", "docs/markets.md",
                  "docs/sweep.md", "docs/training.md",
                  "docs/reporting.md", "docs/forecasting.md"]


# ---------------------------------------------------------------------------
# Docstring coverage (interrogate-equivalent).
# ---------------------------------------------------------------------------
def _doc_targets(tree: ast.Module):
    """Yield (qualname, has_docstring) for the module, every public
    class, and every public function/method (nested functions and
    `_private` names excluded, mirroring interrogate's
    --ignore-private --ignore-nested-functions)."""
    yield "<module>", ast.get_docstring(tree) is not None
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            yield node.name, ast.get_docstring(node) is not None
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) and \
                        not sub.name.startswith("_"):
                    yield (f"{node.name}.{sub.name}",
                           ast.get_docstring(sub) is not None)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not node.name.startswith("_"):
            yield node.name, ast.get_docstring(node) is not None


class TestDocstringCoverage:
    @pytest.mark.parametrize("module", PUBLIC_MODULES)
    def test_module_meets_floor(self, module):
        tree = ast.parse((REPO / module).read_text())
        targets = list(_doc_targets(tree))
        missing = [name for name, ok in targets if not ok]
        coverage = 1.0 - len(missing) / len(targets)
        assert coverage >= DOC_COVERAGE_FLOOR, (
            f"{module}: docstring coverage {coverage:.0%} < "
            f"{DOC_COVERAGE_FLOOR:.0%}; missing: {missing}")

    @pytest.mark.parametrize("module", PUBLIC_MODULES)
    def test_module_docstring_present(self, module):
        tree = ast.parse((REPO / module).read_text())
        assert ast.get_docstring(tree), f"{module} has no module docstring"


# ---------------------------------------------------------------------------
# Markdown link check.
# ---------------------------------------------------------------------------
_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
# backticked repo paths like `src/repro/core/events.py`
_CODE_PATH = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples)/[A-Za-z0-9_/.\-]+)`")


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\s-]", "", s)
    return re.sub(r"[\s]+", "-", s).strip("-")


def _anchors(md_path: Path):
    return {_slugify(ln.lstrip("#"))
            for ln in md_path.read_text().splitlines()
            if ln.startswith("#")}


class TestMarkdownLinks:
    @pytest.mark.parametrize("md", MARKDOWN_FILES)
    def test_relative_links_resolve(self, md):
        md_path = REPO / md
        broken = []
        for target in _LINK.findall(md_path.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue                    # external: not checked offline
            path_part, _, anchor = target.partition("#")
            dest = (md_path.parent / path_part).resolve() if path_part \
                else md_path
            if path_part and not dest.exists():
                broken.append(target)
                continue
            if anchor and dest.suffix == ".md" and \
                    anchor not in _anchors(dest):
                broken.append(f"{target} (missing anchor)")
        assert not broken, f"{md}: broken link(s): {broken}"

    @pytest.mark.parametrize("md", MARKDOWN_FILES)
    def test_backticked_repo_paths_exist(self, md):
        text = (REPO / md).read_text()
        missing = [p for p in _CODE_PATH.findall(text)
                   if not (REPO / p).exists()]
        assert not missing, f"{md}: stale repo path(s): {missing}"

    def test_docs_index_links_every_reference_page(self):
        index = (REPO / "docs/index.md").read_text()
        for page in ("architecture.md", "events.md", "markets.md"):
            assert page in index

    def test_readme_points_at_docs(self):
        readme = (REPO / "README.md").read_text()
        for page in ("docs/architecture.md", "docs/events.md",
                     "docs/markets.md", "benchmarks/README.md"):
            assert page in readme, f"README lost its pointer to {page}"
