"""FL-in-the-mesh tests (2 fake pods on CPU): plain vs compressed FedAvg
agreement, sync-barrier invariants, and the FL round step."""
import os

# 2 host devices so a real (pod=2) mesh exists; must precede jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.common import compat
from repro.fl import mesh_fl
from repro.models import lm
from repro.sharding import rules as R

pytestmark = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs >=2 devices (XLA_FLAGS set too "
    "late — another test initialized jax first)")


def make_mesh():
    return jax.make_mesh((2, 1, 1), ("pod", "data", "model"))


def tiny_tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "w": jnp.asarray(rng.randn(2, 8, 16) * 0.1, jnp.float32),
        "b": jnp.asarray(rng.randn(2, 16) * 0.1, jnp.float32),
    }


class TestFedAvgSync:
    def test_weighted_mean_and_broadcast(self):
        stk = tiny_tree()
        w = jnp.asarray([3.0, 1.0])
        out = mesh_fl.fedavg_sync(stk, w)
        expect = (3 * np.asarray(stk["w"][0]) + np.asarray(stk["w"][1])) / 4
        np.testing.assert_allclose(np.asarray(out["w"][0]), expect,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out["w"][0]),
                                   np.asarray(out["w"][1]), rtol=0)

    def test_compressed_matches_plain_within_int8(self):
        mesh = make_mesh()
        stk = tiny_tree(1)
        glob = jax.tree.map(lambda p: p[0] * 0.9, stk)   # deltas ~0.1 scale
        w = jnp.asarray([1.0, 2.0])
        plain = mesh_fl.fedavg_sync(stk, w)
        with compat.set_mesh(mesh):
            comp = jax.jit(
                lambda s, g, ww: mesh_fl.fedavg_sync_compressed(
                    s, g, ww, mesh, 2))(stk, glob, w)
        for k in ("w", "b"):
            delta_amax = float(jnp.max(jnp.abs(
                stk[k] - glob[k][None])))
            err = float(jnp.max(jnp.abs(comp[k] - plain[k])))
            # int8 per-tensor quantization error bound on the delta
            assert err <= 2 * delta_amax / 127 + 1e-6, (k, err)

    def test_round_step_sync_barrier(self):
        mesh = make_mesh()
        rules = R.make_rules("train")
        shard = R.ShardingCtx(mesh, rules)
        cfg = configs.get_config("phi3-mini-3.8b", smoke=True)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        stk = mesh_fl.stack_params_for_clients(params, 2)
        mu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), stk)
        rng = np.random.RandomState(0)
        batch = {
            "tokens": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (2, 2, 2, 16)), jnp.int32),
            "labels": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (2, 2, 2, 16)), jnp.int32),
        }
        weights = jnp.asarray([1.0, 1.0])
        step = mesh_fl.make_fl_round_step(cfg, opt=1e-2, shard=shard,
                                          local_steps=2, mesh=mesh,
                                          n_pods=2)
        with compat.set_mesh(mesh):
            new_stk, new_mu, losses = jax.jit(step)(stk, mu, batch, weights)
        assert losses.shape == (2,)
        assert bool(jnp.all(jnp.isfinite(losses)))
        # after the barrier every client holds the identical model
        for leaf in jax.tree.leaves(new_stk):
            assert float(jnp.max(jnp.abs(
                leaf[0].astype(jnp.float32)
                - leaf[1].astype(jnp.float32)))) < 1e-5
        # and it differs from the initial model (training happened)
        moved = sum(float(jnp.sum(jnp.abs(
            a[0].astype(jnp.float32) - b[0].astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(new_stk),
                            jax.tree.leaves(stk)))
        assert moved > 0
