"""Unit tests for the FedCostAware core: estimators, Listing-1 logic,
budget adherence, dynamic schedule adjustment."""
import math

import pytest

from repro.common.config import SchedulerConfig
from repro.core.budget import BudgetLedger
from repro.core.estimator import EMA, TimeEstimator
from repro.core.scheduler import FedCostAwareScheduler


def make_sched(alpha=0.5, threshold=100.0, buffer=30.0, spin_prior=120.0):
    est = TimeEstimator(alpha, spin_up_prior=spin_prior)
    ledger = BudgetLedger()
    cfg = SchedulerConfig(ema_alpha=alpha, t_threshold_s=threshold,
                          t_buffer_s=buffer, calibration_rounds=2)
    return FedCostAwareScheduler(cfg, est, ledger)


class TestEMA:
    def test_first_observation_initializes(self):
        e = EMA(0.3)
        assert e.get(5.0) == 5.0
        e.update(10.0)
        assert e.value == 10.0

    def test_ema_smoothing(self):
        e = EMA(0.25)
        e.update(100.0)
        e.update(200.0)
        assert e.value == pytest.approx(0.25 * 200 + 0.75 * 100)

    def test_estimator_cold_warm_separate(self):
        t = TimeEstimator(0.5)
        t.observe_epoch("c", 100.0, cold=True)
        t.observe_epoch("c", 60.0, cold=False)
        m = t.model("c")
        assert m.predict_epoch(cold=True) == 100.0
        assert m.predict_epoch(cold=False) == 60.0

    def test_fallback_between_cold_and_warm(self):
        t = TimeEstimator(0.5)
        t.observe_epoch("c", 80.0, cold=False)
        assert t.model("c").predict_epoch(cold=True) == 80.0


class TestListing1:
    """evaluate_termination / estimate_slowest_finish_time (paper Listing 1)."""

    def _setup_round(self, s, finishes):
        s.begin_round(5)   # past calibration
        for name, (start, cold) in finishes.items():
            s.register_dispatch(name, start, cold, includes_spin_up=False)

    def test_no_termination_during_calibration(self):
        s = make_sched()
        s.begin_round(0)
        s.register_dispatch("a", 0.0, True, False)
        s.register_dispatch("b", 0.0, True, False)
        s.est.observe_epoch("b", 1000.0, cold=True)
        assert s.evaluate_termination("a", 10.0, more_rounds=True) is None

    def test_terminates_when_idle_exceeds_threshold(self):
        s = make_sched(threshold=100.0, buffer=30.0, spin_prior=120.0)
        s.est.observe_epoch("slow", 1000.0, cold=False)
        s.est.observe_epoch("fast", 100.0, cold=False)
        s.est.observe_spin_up("fast", 120.0)
        self._setup_round(s, {"slow": (0.0, False), "fast": (0.0, False)})
        # fast finishes at t=100; slow's estimated finish = 1000
        # idle = 900; 900 - 120 > 100 -> terminate
        prewarm = s.evaluate_termination("fast", 100.0, more_rounds=True)
        assert prewarm is not None
        # spin_up_start = F_s - spin - buffer = 1000 - 120 - 30
        assert prewarm == pytest.approx(850.0)
        assert s.prewarm_queue["fast"] == pytest.approx(850.0)

    def test_keeps_instance_when_saving_below_threshold(self):
        s = make_sched(threshold=100.0, spin_prior=120.0)
        s.est.observe_epoch("slow", 300.0, cold=False)
        s.est.observe_epoch("fast", 100.0, cold=False)
        self._setup_round(s, {"slow": (0.0, False), "fast": (0.0, False)})
        # idle = 200; 200 - 120 = 80 < 100 -> keep running
        assert s.evaluate_termination("fast", 100.0, more_rounds=True) is None

    def test_no_prewarm_on_last_round(self):
        s = make_sched(threshold=10.0, spin_prior=60.0)
        s.est.observe_epoch("slow", 1000.0, cold=False)
        s.est.observe_epoch("fast", 50.0, cold=False)
        self._setup_round(s, {"slow": (0.0, False), "fast": (0.0, False)})
        out = s.evaluate_termination("fast", 50.0, more_rounds=False)
        assert out == math.inf and "fast" not in s.prewarm_queue

    def test_slowest_finish_uses_cold_estimate_for_cold_clients(self):
        s = make_sched()
        s.est.observe_epoch("c", 500.0, cold=True)
        s.est.observe_epoch("c", 200.0, cold=False)
        s.begin_round(5)
        s.register_dispatch("c", 100.0, cold=True, includes_spin_up=False)
        assert s.estimate_finish("c") == pytest.approx(600.0)
        s.states["c"].is_cold_start = False
        assert s.estimate_finish("c") == pytest.approx(300.0)

    def test_includes_spin_up_in_estimate(self):
        s = make_sched(spin_prior=120.0)
        s.est.observe_epoch("c", 200.0, cold=True)
        s.begin_round(5)
        s.register_dispatch("c", 0.0, cold=True, includes_spin_up=True)
        assert s.estimate_finish("c") == pytest.approx(320.0)


class TestDynamicAdjustment:
    """§III-D: preemption recovery pushes back pre-warm targets."""

    def test_prewarms_move_later_on_recovery(self):
        s = make_sched(threshold=10.0, buffer=30.0, spin_prior=120.0)
        for c, t in [("a", 1000.0), ("b", 100.0), ("crash", 800.0)]:
            s.est.observe_epoch(c, t, cold=False)
            s.est.observe_spin_up(c, 120.0)
        s.begin_round(5)
        for c in ("a", "b", "crash"):
            s.register_dispatch(c, 0.0, False, False)
        s.evaluate_termination("b", 100.0, more_rounds=True)
        orig = s.prewarm_queue["b"]
        # crash recovers and will now finish at t=2000 (> a's 1000)
        moved = s.on_preemption_recovery("crash", 2000.0)
        assert moved["b"] > orig
        assert moved["b"] == pytest.approx(2000.0 - 120.0 - 30.0)

    def test_recovery_earlier_than_slowest_no_move(self):
        s = make_sched(threshold=10.0, buffer=30.0, spin_prior=120.0)
        for c, t in [("a", 1000.0), ("b", 100.0)]:
            s.est.observe_epoch(c, t, cold=False)
            s.est.observe_spin_up(c, 120.0)
        s.begin_round(5)
        for c in ("a", "b"):
            s.register_dispatch(c, 0.0, False, False)
        s.evaluate_termination("b", 100.0, more_rounds=True)
        moved = s.on_preemption_recovery("b", 500.0)   # before a's 1000
        assert moved == {}


class TestBudget:
    def test_exclusion_is_permanent(self):
        l = BudgetLedger()
        l.register("a", 1.0)
        l.register("b", 10.0)
        l.sync_spend("a", 0.95)
        keep = l.screen_round(["a", "b"], lambda c: 0.10)
        assert keep == ["b"] and l.is_excluded("a")
        l.sync_spend("a", 0.0)   # even with budget back, stays excluded
        keep = l.screen_round(["a", "b"], lambda c: 0.0)
        assert keep == ["b"]

    def test_affordable_client_participates(self):
        l = BudgetLedger()
        l.register("a", 5.0)
        l.sync_spend("a", 1.0)
        assert l.screen_round(["a"], lambda c: 3.99) == ["a"]
