"""Tests for the composable SchedulingStrategy API: typed directives,
the DirectiveExecutor, the declarative Policy composition (+ legacy
boolean compat shim), engine-registry validation at construction,
§III-D pre-warm rescheduling edges, and checkpoint-aware cost
accounting (StorageRates)."""
import dataclasses
import warnings
from pathlib import Path

import pytest

from repro.cloud.pricing import Provider, StorageRates
from repro.common.config import (ClientProfile, CloudConfig, FLRunConfig,
                                 MarketConfig, ProviderConfig,
                                 SchedulerConfig)
from repro.core.eventlog import EventReplayer
from repro.core.policies import (ON_WARNING_MODES, POLICIES, Policy,
                                 get_policy, make_scheduler,
                                 register_policy)
from repro.core.strategy import (BudgetScreen, BudgetScreenSpec,
                                 Checkpoint, Directive, Drain,
                                 ForecastPrewarmSpec,
                                 ForecastPrewarmStrategy,
                                 LifecycleSpec, LifecycleStrategy,
                                 PreWarm, ScreenOut,
                                 SchedulingStrategy, SpinUp,
                                 StrategySpec, Terminate,
                                 WarningReaction, WarningReactionSpec)
from repro.fl.cluster import ClusterManager
from repro.fl.runner import FLCloudRunner
from repro.fl.telemetry import replay_result

FIXTURE_PRICES = Path(__file__).parent / "fixtures" / "prices"
CLOUD = CloudConfig(spot_rate_sigma=0.0)


def run_recorded(policy="fedcostaware", clients=None, n_epochs=3,
                 cloud=None, **cfg_kw):
    clients = clients or (
        ClientProfile("slow", mean_epoch_s=900, jitter=0.0, n_samples=2),
        ClientProfile("fast", mean_epoch_s=150, jitter=0.0, n_samples=1),
    )
    cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=n_epochs,
                      policy=policy, seed=0, **cfg_kw)
    r = FLCloudRunner(cfg, cloud_cfg=cloud or CLOUD, record=True)
    res = r.run()
    return r, res


# ---------------------------------------------------------------------------
# Declarative Policy composition.
# ---------------------------------------------------------------------------
class TestPolicyComposition:
    def test_table1_policies_are_declarative(self):
        fca = get_policy("fedcostaware")
        assert fca.strategies == (LifecycleSpec(), BudgetScreenSpec())
        assert get_policy("spot").strategies == ()
        assert get_policy("on_demand").strategies == ()
        assert get_policy("fedcostaware_async").strategies == \
            fca.strategies

    def test_boolean_views_derive_from_strategies(self):
        fca = get_policy("fedcostaware")
        assert fca.manage_lifecycle and fca.enforce_budgets
        spot = get_policy("spot")
        assert not spot.manage_lifecycle and not spot.enforce_budgets

    def test_replace_keeps_strategies_and_raises_no_warning(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            p = dataclasses.replace(get_policy("fedcostaware"),
                                    on_warning="drain")
        assert p.on_warning == "drain"
        assert p.strategies == get_policy("fedcostaware").strategies

    def test_unknown_on_warning_names_policy(self):
        with pytest.raises(ValueError, match="badpol"):
            Policy("badpol", on_warning="explode")
        assert "checkpoint" in ON_WARNING_MODES

    def test_unknown_engine_rejected_at_construction(self):
        """Satellite: an unknown engine key fails at Policy
        construction (naming the policy), not deep inside the runner."""
        with pytest.raises(ValueError, match="mypolicy.*no_such_engine"):
            Policy("mypolicy", engine="no_such_engine")

    def test_known_engines_accepted(self):
        for engine in ("sync", "async_buffered", "fedbuff"):
            assert Policy(f"p_{engine}", engine=engine).engine == engine

    def test_non_spec_strategy_rejected(self):
        with pytest.raises(ValueError, match="StrategySpec"):
            Policy("p", strategies=("lifecycle",))

    def test_register_policy(self):
        p = Policy("registered_test_policy", pick_cheapest_zone=True,
                   strategies=(BudgetScreenSpec(),))
        register_policy(p, overwrite=True)
        assert get_policy("registered_test_policy") is p
        with pytest.raises(ValueError, match="already registered"):
            register_policy(p)
        POLICIES.pop("registered_test_policy")


class TestLegacyBooleanShim:
    def test_positional_boolean_construction_warns_and_maps(self):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            p = Policy("legacy", False, True, True, True)
        assert p.strategies == (LifecycleSpec(), BudgetScreenSpec())
        assert p.pick_cheapest_zone and not p.on_demand
        assert p.manage_lifecycle and p.enforce_budgets

    def test_legacy_equals_declarative(self):
        with pytest.warns(DeprecationWarning):
            legacy = Policy("fedcostaware", False, True, True, True)
        assert legacy == get_policy("fedcostaware")

    def test_false_flags_map_to_empty_composition(self):
        with pytest.warns(DeprecationWarning):
            p = Policy("spotlike", False, False, False, True)
        assert p.strategies == ()
        assert p.pick_cheapest_zone and not p.on_demand
        assert p == dataclasses.replace(get_policy("spot"),
                                        name="spotlike")

    def test_flags_and_strategies_together_rejected(self):
        with pytest.raises(ValueError, match="not both"):
            Policy("p", manage_lifecycle=True,
                   strategies=(LifecycleSpec(),))

    def test_declarative_construction_warns_nothing(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            Policy("quiet", pick_cheapest_zone=True,
                   strategies=(LifecycleSpec(),))


# ---------------------------------------------------------------------------
# Directives + executor behavior, through full runs.
# ---------------------------------------------------------------------------
class TestDirectives:
    def test_directive_dataclasses(self):
        for d in (SpinUp("c"), Terminate("c"), PreWarm("c", 5.0),
                  Checkpoint("c"), Drain("c"), ScreenOut("c", 2)):
            assert isinstance(d, Directive) and d.client == "c"
        assert Terminate("c", standby=True).standby

    def test_specs_build_matching_strategies(self):
        p = get_policy("fedcostaware")
        assert isinstance(LifecycleSpec().build(p), LifecycleStrategy)
        assert isinstance(BudgetScreenSpec().build(p), BudgetScreen)
        wr = WarningReactionSpec().build(
            dataclasses.replace(p, on_warning="drain"))
        assert isinstance(wr, WarningReaction) and wr.mode == "drain"
        assert WarningReactionSpec(mode="checkpoint").build(p).mode == \
            "checkpoint"
        assert isinstance(ForecastPrewarmSpec(oracle=True).build(p),
                          ForecastPrewarmStrategy)

    def test_default_streams_carry_no_directive_events(self):
        r, _ = run_recorded("fedcostaware")
        types = {rec["type"] for rec in r.recorder.records}
        assert "DirectiveIssued" not in types

    def test_trace_directives_publishes_issued_events(self):
        r, _ = run_recorded("fedcostaware", n_epochs=5,
                            trace_directives=True)
        issued = [rec for rec in r.recorder.records
                  if rec["type"] == "DirectiveIssued"]
        kinds = {rec["kind"] for rec in issued}
        # post-calibration non-final rounds terminate + pre-warm the
        # fast client; the final round terminates without a pre-warm
        assert {"Terminate", "PreWarm"} <= kinds
        for rec in issued:
            assert rec["client"] in ("slow", "fast")

    def test_traced_run_totals_match_untraced(self):
        _, res_a = run_recorded("fedcostaware")
        _, res_b = run_recorded("fedcostaware", trace_directives=True)
        assert res_b.total_cost == pytest.approx(res_a.total_cost,
                                                 abs=1e-9)
        assert res_b.makespan_s == pytest.approx(res_a.makespan_s,
                                                 abs=1e-9)

    def test_screen_out_event_order(self):
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        r, res = run_recorded("fedcostaware", clients=clients,
                              n_epochs=6)
        assert "poor" in res.excluded_clients
        recs = r.recorder.records
        i = next(i for i, rec in enumerate(recs)
                 if rec["type"] == "BudgetExhausted")
        assert recs[i]["client"] == "poor"
        assert recs[i + 1]["type"] == "ClientScreenedOut"
        assert recs[i + 1]["client"] == "poor"
        assert recs[i + 1]["round_idx"] >= 1
        # the screened client's tracked instance is torn down next
        assert recs[i + 2]["type"] == "ClientStateChanged"
        assert (recs[i + 2]["client"], recs[i + 2]["state"]) == \
            ("poor", "idle")

    def test_screened_out_round_trips_through_replay(self):
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        r, res = run_recorded("fedcostaware", clients=clients,
                              n_epochs=6)
        rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
        assert rep.total_cost == pytest.approx(res.total_cost, abs=1e-9)
        assert rep.excluded_clients == res.excluded_clients


# ---------------------------------------------------------------------------
# Custom compositions run end-to-end with zero engine edits.
# ---------------------------------------------------------------------------
class TestCustomComposition:
    def test_budget_screen_only_policy(self):
        register_policy(Policy(
            "budget_only_test", pick_cheapest_zone=True,
            strategies=(BudgetScreenSpec(),)), overwrite=True)
        clients = (
            ClientProfile("rich", 600, n_samples=2, jitter=0.0),
            ClientProfile("poor", 200, n_samples=1, jitter=0.0,
                          budget=0.05),
        )
        r, res = run_recorded("budget_only_test", clients=clients,
                              n_epochs=6)
        assert "poor" in res.excluded_clients
        # no lifecycle component: nothing ever enters "savings"
        assert not any(rec.get("state") == "savings"
                       for rec in r.recorder.records
                       if rec["type"] == "ClientStateChanged")
        POLICIES.pop("budget_only_test")

    def test_custom_strategy_class_via_spec(self):
        """A user-defined strategy plugs in through a spec — the
        extension path new disciplines use."""
        seen = []

        class CountingStrategy(SchedulingStrategy):
            def on_client_result(self, client, t, more_rounds):
                seen.append((client, t))
                return []

        @dataclasses.dataclass(frozen=True)
        class CountingSpec(StrategySpec):
            def build(self, policy):
                return CountingStrategy()

        register_policy(Policy(
            "counting_test", pick_cheapest_zone=True,
            strategies=(CountingSpec(),)), overwrite=True)
        _, res = run_recorded("counting_test")
        assert res.rounds_completed == 3
        # one result report per client per round except round-closers
        assert len(seen) == sum(len(p) - 1
                                for p in res.per_round_participants)
        POLICIES.pop("counting_test")


# ---------------------------------------------------------------------------
# §III-D pre-warm rescheduling edges (satellite).
# ---------------------------------------------------------------------------
class TestPrewarmReschedulingEdges:
    def _sched_with_prewarm(self):
        sched = make_scheduler(get_policy("fedcostaware"),
                               SchedulerConfig(t_threshold_s=10.0,
                                               t_buffer_s=30.0),
                               spin_up_prior=120.0)
        for c, t in [("slow", 1000.0), ("fast", 100.0),
                     ("crash", 800.0)]:
            sched.est.observe_epoch(c, t, cold=False)
            sched.est.observe_spin_up(c, 120.0)
        sched.begin_round(5)
        for c in ("slow", "fast", "crash"):
            sched.register_dispatch(c, 0.0, False, False)
        prewarm_t = sched.evaluate_termination("fast", 100.0,
                                               more_rounds=True)
        assert prewarm_t == pytest.approx(850.0)
        return sched

    def test_earlier_move_is_deliberately_not_applied(self):
        """The `new_t > old_t` guard is intentional: a pre-warm target
        is a *cost floor* — §III-D exists to avoid late arrivals, and
        firing earlier than originally promised only buys idle
        instance-seconds. When the schedule contracts (the slowest
        client beats its estimate), the queued target stays put."""
        sched = self._sched_with_prewarm()
        # the slowest client finishes far earlier than its estimate,
        # contracting F_s from 1000 to 600
        sched.on_result("slow", 600.0, 600.0, cold=False,
                        spin_up_observed=None)
        moved = sched.on_preemption_recovery("crash", 650.0)
        assert moved == {}
        assert sched.prewarm_queue["fast"] == pytest.approx(850.0)

    def test_later_move_still_applies(self):
        sched = self._sched_with_prewarm()
        moved = sched.on_preemption_recovery("crash", 2000.0)
        assert moved["fast"] == pytest.approx(2000.0 - 120.0 - 30.0)
        assert sched.prewarm_queue["fast"] == moved["fast"]

    def test_recovery_after_all_prewarms_fired_is_noop(self):
        """A recovery landing after every queued pre-warm already spun
        its instance up must not double-request: the moved target
        re-fires, sees the client already tracked, and no-ops."""
        sim_cloud = CloudConfig(spot_rate_sigma=0.0, spin_up_sigma=0.0)
        from repro.cloud.simulator import CloudSimulator
        sim = CloudSimulator(sim_cloud, seed=0)
        policy = get_policy("fedcostaware")
        sched = make_scheduler(policy, SchedulerConfig())
        profiles = {"x": ClientProfile("x", 100.0)}
        cluster = ClusterManager(sim, policy, profiles, sched)
        sched.prewarm_queue["x"] = 100.0
        cluster.schedule_prewarm("x", 100.0)
        sim.run_until_idle()
        assert cluster.instance_of("x") is not None
        n_before = len(sim.instances_of("x"))
        assert n_before == 1
        # late §III-D move arrives after the fire: reschedule + drain
        sched.prewarm_queue["x"] = sim.now + 500.0
        cluster.schedule_prewarm("x", sim.now + 500.0)
        sim.run_until_idle()
        assert len(sim.instances_of("x")) == n_before


# ---------------------------------------------------------------------------
# Checkpoint-aware cost accounting (satellite).
# ---------------------------------------------------------------------------
# the preemption_realism pinned scenario, with storage rates attached
CKPT_CLIENTS = (
    ClientProfile("a", mean_epoch_s=900.0, jitter=0.0, n_samples=2,
                  zone="us-east-1a"),
    ClientProfile("b", mean_epoch_s=400.0, jitter=0.0, n_samples=1,
                  zone="us-east-1b"),
)
CKPT_SCHED = SchedulerConfig(checkpoint_every_s=600.0,
                             warning_ckpt_write_s=10.0,
                             warning_ckpt_size_mb=100.0)
PUT_USD, EGRESS_USD_PER_MB = 0.000005, 0.00009


def ckpt_cloud(put=PUT_USD, egress=EGRESS_USD_PER_MB):
    market = MarketConfig(providers=(ProviderConfig(
        name="aws",
        price_trace=str(FIXTURE_PRICES / "aws.csv"),
        interruption_trace=str(FIXTURE_PRICES / "aws.interruptions.csv"),
        preemption_notice_s=120.0,
        storage_put_usd=put,
        storage_egress_usd_per_mb=egress),))
    return CloudConfig(spot_rate_sigma=0.0, spin_up_sigma=0.0,
                       preemption_model="replay", market=market)


def run_ckpt(mode="checkpoint", put=PUT_USD, egress=EGRESS_USD_PER_MB):
    cfg = FLRunConfig(dataset="ckpt_cost", clients=CKPT_CLIENTS,
                      n_epochs=3, policy="spot", seed=0,
                      on_warning=mode)
    r = FLCloudRunner(cfg, cloud_cfg=ckpt_cloud(put, egress),
                      sched_cfg=CKPT_SCHED, record=True)
    return r, r.run()


class TestCheckpointCostAccounting:
    def test_storage_rates_checkpoint_cost(self):
        rates = StorageRates(put_usd=0.01, egress_usd_per_mb=0.001)
        assert rates.checkpoint_cost(100.0) == pytest.approx(0.11)
        assert StorageRates().checkpoint_cost(1e6) == 0.0

    def test_provider_carries_storage_rates(self):
        pc = ProviderConfig(name="aws", storage_put_usd=0.5,
                            storage_egress_usd_per_mb=0.25)
        p = Provider.from_provider_config(pc)
        assert p.storage == StorageRates(0.5, 0.25)
        # legacy scalar CloudConfig providers stay free
        assert Provider.from_cloud_config(CLOUD).storage == \
            StorageRates()

    def test_checkpoint_writes_are_billed(self):
        r, res = run_ckpt()
        ckpts = [rec for rec in r.recorder.records
                 if rec["type"] == "ClientCheckpointed"]
        assert ckpts, "scenario must produce warning checkpoints"
        per_write = PUT_USD + 100.0 * EGRESS_USD_PER_MB
        want = len(ckpts) * per_write
        assert res.checkpoint_cost == pytest.approx(want, abs=1e-12)
        # included in the run's dollar totals
        assert r.accountant.checkpoint_cost_total() == \
            pytest.approx(want, abs=1e-12)
        billed = [rec for rec in r.recorder.records
                  if rec["type"] == "CheckpointBilled"]
        assert len(billed) == len(ckpts)
        for rec in billed:
            assert rec["amount"] == pytest.approx(per_write, abs=1e-12)
        for rec in ckpts:
            assert rec["size_mb"] == pytest.approx(100.0)
            # billed against the provider that wrote the snapshot
            assert rec["provider"] == "aws"

    def test_checkpoint_cost_included_in_totals(self):
        _, free = run_ckpt(put=0.0, egress=0.0)
        _, paid = run_ckpt()
        assert free.checkpoint_cost == 0.0
        assert paid.total_cost == pytest.approx(
            free.total_cost + paid.checkpoint_cost, abs=1e-9)

    def test_replay_rebuilds_checkpoint_cost_without_market(self):
        r, res = run_ckpt()
        rep = replay_result(EventReplayer.loads(r.recorder.dumps()))
        assert rep.checkpoint_cost == pytest.approx(
            res.checkpoint_cost, abs=1e-12)
        assert rep.total_cost == pytest.approx(res.total_cost, abs=1e-9)
        for c in res.per_client_cost:
            assert rep.per_client_cost[c] == pytest.approx(
                res.per_client_cost[c], abs=1e-9)

    def test_default_rates_keep_checkpoints_free(self):
        _, res = run_ckpt(put=0.0, egress=0.0)
        assert res.checkpoint_cost == 0.0

    def test_drain_vs_checkpoint_tradeoff_includes_storage(self):
        """The Table-1 trade-off surface: both modes pay the same
        per-write storage dollars, so the drain-vs-checkpoint cost
        comparison now includes them."""
        _, ck = run_ckpt("checkpoint")
        _, dr = run_ckpt("drain")
        assert ck.checkpoint_cost > 0 and dr.checkpoint_cost > 0
