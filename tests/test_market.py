"""Tests for the provider-agnostic SpotMarket API: trace-driven price
sources, cross-provider arbitration, and per-provider billing."""
import math
from pathlib import Path

import numpy as np
import pytest

from repro.cloud.accounting import CostAccountant
from repro.cloud.pricing import (Provider, SpotMarket, TracePriceSource,
                                 Zone)
from repro.cloud.simulator import CloudSimulator
from repro.cloud.traces import (TraceFormatError, load_price_trace,
                                parse_price_file, shared_epoch,
                                validate_dir)
from repro.common.config import (CloudConfig, MarketConfig,
                                 ProviderConfig)
from repro.core.events import (InstancePreempted,
                               InstancePreemptionWarning)

FIXTURES = Path(__file__).parent / "fixtures" / "prices"


class _Flat:
    """Constant-price source for arbitration tests."""

    def __init__(self, p):
        self._p = p

    def price(self, t):
        return self._p

    def integral(self, t0, t1):
        return self._p * max(t1 - t0, 0.0)


def two_provider_market(price_a=0.5, price_b=0.5):
    m = SpotMarket([Provider("aws", on_demand_rate=1.0),
                    Provider("gcp", on_demand_rate=0.9)])
    m.add_zone(Zone("aws-1a", "aws-1", "aws"), _Flat(price_a))
    m.add_zone(Zone("gcp-1a", "gcp-1", "gcp"), _Flat(price_b))
    return m


# ---------------------------------------------------------------------------
# TracePriceSource: piecewise-constant history at irregular times.
# ---------------------------------------------------------------------------
class TestTracePriceSource:
    TIMES = [0.0, 700.0, 1000.0, 5200.0, 9000.0]
    PRICES = [0.40, 0.35, 0.55, 0.30, 0.45]

    def _src(self):
        return TracePriceSource(self.TIMES, self.PRICES)

    def test_price_lookup_is_left_step(self):
        s = self._src()
        assert s.price(0.0) == 0.40
        assert s.price(699.9) == 0.40
        assert s.price(700.0) == 0.35
        assert s.price(4000.0) == 0.55

    def test_horizon_clamp(self):
        s = self._src()
        assert s.price(-100.0) == 0.40       # before first update
        assert s.price(1e9) == 0.45          # last price extends
        # integral past the horizon grows at the last price
        base = s.integral(0.0, 9000.0)
        assert s.integral(0.0, 9000.0 + 100.0) == \
            pytest.approx(base + 100.0 * 0.45, rel=1e-12)

    def test_integral_matches_numpy_cumsum_reference(self):
        s = self._src()
        # dense step-function reference on a 1s grid via cumsum
        grid = np.arange(0.0, 9500.0, 1.0)
        idx = np.clip(np.searchsorted(self.TIMES, grid, side="right") - 1,
                      0, len(self.PRICES) - 1)
        dense = np.concatenate(
            [[0.0], np.cumsum(np.asarray(self.PRICES)[idx])])
        for t0, t1 in [(0.0, 9000.0), (650.0, 720.0), (999.0, 5201.0),
                       (100.0, 100.0), (3000.0, 2000.0)]:
            want = dense[int(t1)] - dense[int(t0)] if t1 > t0 else 0.0
            assert s.integral(t0, t1) == pytest.approx(want, rel=1e-12)

    def test_irregular_intervals_random_reference(self):
        rng = np.random.RandomState(0)
        times = np.cumsum(rng.uniform(5.0, 500.0, size=40))
        prices = rng.uniform(0.2, 1.0, size=40)
        s = TracePriceSource(times, prices)
        for _ in range(20):
            t0, t1 = sorted(rng.uniform(times[0], times[-1], size=2))
            # brute-force segment walk
            want, t = 0.0, t0
            while t < t1:
                i = max(np.searchsorted(times, t, side="right") - 1, 0)
                seg_end = times[i + 1] if i + 1 < len(times) else t1
                step = min(seg_end, t1) - t
                want += prices[i] * step
                t += step
            assert s.integral(t0, t1) == pytest.approx(want, rel=1e-9)

    def test_rejects_bad_input(self):
        with pytest.raises(ValueError, match="ascending"):
            TracePriceSource([0.0, 10.0, 5.0], [1.0, 1.0, 1.0])
        with pytest.raises(ValueError, match="negative"):
            TracePriceSource([0.0, 1.0], [0.5, -0.1])
        with pytest.raises(ValueError):
            TracePriceSource([], [])


# ---------------------------------------------------------------------------
# Trace file loading (the real-format fixtures).
# ---------------------------------------------------------------------------
class TestTraceLoader:
    def test_fixture_roundtrip(self):
        records = parse_price_file(FIXTURES / "aws.csv")
        zones = load_price_trace(FIXTURES / "aws.csv", provider="aws")
        assert [z.name for z, _ in zones] == ["us-east-1a", "us-east-1b"]
        assert all(z.provider == "aws" and z.region == "us-east-1"
                   for z, _ in zones)
        t0 = min(r.timestamp for r in records)
        for zone, src in zones:
            zrecs = [r for r in records if r.zone == zone.name]
            for r in zrecs:
                assert src.price(r.timestamp - t0) == \
                    pytest.approx(r.price, rel=1e-12)

    def test_gcp_zone_region_split(self):
        zones = load_price_trace(FIXTURES / "gcp.csv", provider="gcp")
        assert [z.name for z, _ in zones] == \
            ["us-central1-a", "us-central1-b"]
        assert all(z.region == "us-central1" for z, _ in zones)

    def test_shared_epoch_alignment(self):
        paths = [FIXTURES / "aws.csv", FIXTURES / "gcp.csv"]
        epoch = shared_epoch(paths)
        aws_first = min(r.timestamp
                        for r in parse_price_file(paths[0]))
        assert epoch == aws_first          # aws starts 7.5 min earlier
        # with the shared epoch, the gcp trace starts at t=450s, and
        # its pre-horizon prices clamp to the first record
        (za, sa), (zb, sb) = load_price_trace(paths[1], provider="gcp",
                                              epoch=epoch)
        assert sb.horizon[0] == pytest.approx(450.0)
        assert sb.price(0.0) == sb.price(450.0)

    def test_validate_dir_reports_all_fixtures(self):
        lines = validate_dir(FIXTURES)
        # aws.csv + gcp.csv + spiky.csv + spiky_early.csv price
        # histories and the aws/spiky_early interruption records
        assert len(lines) == 6
        assert any("aws.csv" in ln for ln in lines)
        assert any("aws.interruptions.csv" in ln for ln in lines)
        assert any("spiky_early.csv" in ln for ln in lines)
        assert any("spiky_early.interruptions.csv" in ln
                   for ln in lines)

    def test_malformed_rows_raise(self, tmp_path):
        hdr = ("Timestamp,AvailabilityZone,InstanceType,"
               "ProductDescription,SpotPrice\n")
        cases = {
            "badcols.csv": hdr + "2024-03-01T00:00:00Z,z1,g5.xlarge\n",
            "badprice.csv": hdr
            + "2024-03-01T00:00:00Z,z1,g5.xlarge,Linux/UNIX,oops\n",
            "negprice.csv": hdr
            + "2024-03-01T00:00:00Z,z1,g5.xlarge,Linux/UNIX,-1\n",
            "badtime.csv": hdr
            + "not-a-time,z1,g5.xlarge,Linux/UNIX,0.4\n",
            "badheader.csv": "a,b,c\n",
            "empty.csv": hdr,
        }
        for name, content in cases.items():
            p = tmp_path / name
            p.write_text(content)
            with pytest.raises(TraceFormatError):
                parse_price_file(p)

    def test_jsonl_format(self, tmp_path):
        p = tmp_path / "x.jsonl"
        p.write_text(
            '{"Timestamp": "2024-03-01T00:00:00Z", "AvailabilityZone": '
            '"us-east-1a", "InstanceType": "g5.xlarge", '
            '"ProductDescription": "Linux/UNIX", "SpotPrice": "0.41"}\n'
            '{"Timestamp": "2024-03-01T01:00:00Z", "AvailabilityZone": '
            '"us-east-1a", "InstanceType": "g5.xlarge", '
            '"ProductDescription": "Linux/UNIX", "SpotPrice": 0.44}\n')
        [(zone, src)] = load_price_trace(p)
        assert zone.name == "us-east-1a"
        assert src.price(0.0) == pytest.approx(0.41)
        assert src.price(3600.0) == pytest.approx(0.44)


# ---------------------------------------------------------------------------
# Cross-provider arbitration.
# ---------------------------------------------------------------------------
class TestCheapestZoneArbitration:
    def test_tie_breaks_to_first_registered(self):
        m = two_provider_market(0.5, 0.5)
        z, p = m.cheapest_zone(0.0)
        assert (z.provider, z.name) == ("aws", "aws-1a") and p == 0.5

    def test_tie_break_follows_registration_not_name(self):
        # register gcp first: the tie now resolves to gcp even though
        # "aws-1a" sorts first lexicographically
        m = SpotMarket([Provider("gcp", 0.9), Provider("aws", 1.0)])
        m.add_zone(Zone("gcp-1a", "gcp-1", "gcp"), _Flat(0.5))
        m.add_zone(Zone("aws-1a", "aws-1", "aws"), _Flat(0.5))
        z, _ = m.cheapest_zone(0.0)
        assert z.provider == "gcp"

    def test_strictly_cheaper_provider_wins(self):
        m = two_provider_market(0.5, 0.49)
        z, p = m.cheapest_zone(0.0)
        assert z.provider == "gcp" and p == 0.49

    def test_provider_restriction(self):
        m = two_provider_market(0.5, 0.3)
        z, p = m.cheapest_zone(0.0, providers=["aws"])
        assert z.provider == "aws" and p == 0.5

    def test_allowed_zone_restriction(self):
        m = two_provider_market(0.3, 0.5)
        z, _ = m.cheapest_zone(0.0, allowed=["gcp-1a"])
        assert z.name == "gcp-1a"

    def test_no_candidates_raises(self):
        m = two_provider_market()
        with pytest.raises(ValueError, match="no zone"):
            m.cheapest_zone(0.0, providers=["azure"])

    def test_default_synthetic_tie_break_is_zone_zero(self):
        """sigma=0 makes zones 0 and 3 tie at 0.98x mean; the
        pre-redesign `min` picked zone 0 — registration order must
        preserve that."""
        m = SpotMarket.synthetic(CloudConfig(spot_rate_sigma=0.0), seed=0)
        z, _ = m.cheapest_zone(0.0)
        assert z.name == "us-east-1a"


# ---------------------------------------------------------------------------
# Per-provider billing semantics through the simulator + accountant.
# ---------------------------------------------------------------------------
def _mixed_market_cfg():
    return CloudConfig(spot_rate_sigma=0.0, market=MarketConfig(providers=(
        ProviderConfig(name="aws", spot_rate_sigma=0.0, n_zones=1,
                       min_billing_s=60.0),
        ProviderConfig(name="gcp", spot_rate_sigma=0.0, n_zones=1,
                       spot_rate_mean=0.30, min_billing_s=30.0),
    )))


class TestPerProviderBilling:
    @pytest.mark.parametrize("prov,floor_s", [("aws", 60.0),
                                              ("gcp", 30.0)])
    def test_min_billing_floor_is_per_provider(self, prov, floor_s):
        cfg = _mixed_market_cfg()
        sim = CloudSimulator(cfg, seed=0)
        acct = CostAccountant(sim.bus, sim.market, clock=lambda: sim.now)
        inst = sim.request_instance(f"c_{prov}", zone="us-east-1a",
                                    provider=prov)
        sim.run_until_idle()
        sim.now = inst.t_ready + 2.0       # used 2s; floor applies
        sim.terminate(inst)
        want = sim.market.cost(inst.zone, inst.t_ready,
                               inst.t_ready + floor_s,
                               on_demand=False, provider=prov)
        assert inst.cost == pytest.approx(want, rel=1e-9)
        # the accountant's incremental totals agree with the ledger
        assert acct.client_cost(f"c_{prov}") == \
            pytest.approx(want, rel=1e-9)
        # the two floors genuinely differ: gcp's 30s floor bills half
        # the seconds of aws's 60s floor
        assert floor_s / 60.0 == pytest.approx(
            want / sim.market.cost(inst.zone, inst.t_ready,
                                   inst.t_ready + 60.0, on_demand=False,
                                   provider=prov), rel=0.25)

    def test_billing_granularity_rounds_up(self):
        cfg = CloudConfig(spot_rate_sigma=0.0, market=MarketConfig(
            providers=(ProviderConfig(name="aws", spot_rate_sigma=0.0,
                                      n_zones=1, min_billing_s=0.0,
                                      billing_granularity_s=3600.0),)))
        sim = CloudSimulator(cfg, seed=0)
        inst = sim.request_instance("c")
        sim.run_until_idle()
        sim.now = inst.t_ready + 1800.0        # half a billing unit used
        sim.terminate(inst)
        want = sim.market.cost(inst.zone, inst.t_ready,
                               inst.t_ready + 3600.0, on_demand=False)
        assert inst.cost == pytest.approx(want, rel=1e-9)

    def test_preemption_warning_precedes_reclaim(self):
        cfg = CloudConfig(spot_rate_sigma=0.0, preemption_rate_per_hr=50.0,
                          market=MarketConfig(providers=(
                              ProviderConfig(name="aws",
                                             spot_rate_sigma=0.0,
                                             n_zones=1,
                                             preemption_notice_s=120.0),)))
        sim = CloudSimulator(cfg, seed=1)
        warns, reclaims = [], []
        sim.bus.subscribe(InstancePreemptionWarning,
                          lambda ev: warns.append(ev))
        sim.bus.subscribe(InstancePreempted,
                          lambda ev: reclaims.append(ev))
        sim.request_instance("c")
        sim.run_until_idle(t_max=10 * 3600)
        assert len(warns) == 1 and len(reclaims) == 1
        assert warns[0].t <= reclaims[0].t
        assert warns[0].reclaim_at == pytest.approx(reclaims[0].t)

    def test_default_market_has_no_warning_events(self):
        sim = CloudSimulator(CloudConfig(spot_rate_sigma=0.0,
                                         preemption_rate_per_hr=50.0),
                             seed=1)
        warns = []
        sim.bus.subscribe(InstancePreemptionWarning,
                          lambda ev: warns.append(ev))
        sim.request_instance("c")
        sim.run_until_idle(t_max=10 * 3600)
        assert warns == []


# ---------------------------------------------------------------------------
# Market construction from config.
# ---------------------------------------------------------------------------
class TestMarketConstruction:
    def test_for_cloud_config_defaults_to_synthetic(self):
        cfg = CloudConfig()
        m = SpotMarket.for_cloud_config(cfg, seed=0)
        assert list(m.providers) == ["aws"]
        assert len(m.zones) == cfg.n_zones

    def test_synthetic_matches_legacy_pricebook(self):
        from repro.cloud.pricing import PriceBook
        cfg = CloudConfig()
        a = SpotMarket.synthetic(cfg, seed=3)
        b = PriceBook(cfg, seed=3)
        for z in a.zones:
            for t in (0.0, 3600.0, 86400.0):
                assert a.spot_price(z.name, t) == b.spot_price(z.name, t)

    def test_trace_market_from_config(self):
        m = SpotMarket.from_market_config(MarketConfig(providers=(
            ProviderConfig(name="aws",
                           price_trace=str(FIXTURES / "aws.csv")),
            ProviderConfig(name="gcp",
                           price_trace=str(FIXTURES / "gcp.csv")),
        )))
        assert list(m.providers) == ["aws", "gcp"]
        assert len(m.zones) == 4
        # provider registration order is the arbitration order
        assert [z.provider for z in m.zones] == \
            ["aws", "aws", "gcp", "gcp"]

    def test_duplicate_provider_rejected(self):
        m = SpotMarket([Provider("aws", 1.0)])
        with pytest.raises(ValueError, match="already"):
            m.add_provider(Provider("aws", 1.0))

    def test_zone_requires_registered_provider(self):
        m = SpotMarket([Provider("aws", 1.0)])
        with pytest.raises(ValueError, match="unknown provider"):
            m.add_zone(Zone("z", "r", "gcp"), _Flat(0.5))


class TestPinnedZoneProviderResolution:
    """A bare zone name (ClientProfile.zone with no provider) must bind
    to the zone's owning provider, not blindly to the default one."""

    def test_resolve_provider_prefers_owner(self):
        m = two_provider_market()
        assert m.resolve_provider("gcp-1a") == "gcp"
        assert m.resolve_provider("aws-1a") == "aws"
        assert m.resolve_provider("unknown") == "aws"      # default
        assert m.resolve_provider("gcp-1a", "aws") == "aws"  # explicit

    def test_request_in_pinned_foreign_zone(self):
        cfg = CloudConfig(spot_rate_sigma=0.0, market=MarketConfig(
            providers=(
                ProviderConfig(name="aws",
                               price_trace=str(FIXTURES / "aws.csv")),
                ProviderConfig(name="gcp", min_billing_s=30.0,
                               price_trace=str(FIXTURES / "gcp.csv")),
            )))
        sim = CloudSimulator(cfg, seed=0)
        inst = sim.request_instance("c", zone="us-central1-a")
        sim.run_until_idle()
        assert inst.provider == "gcp"
        sim.now = inst.t_ready + 3600.0
        assert sim.accrued_cost(inst) > 0      # prices resolve, no KeyError

    def test_pinned_foreign_zone_run_completes(self):
        from repro.common.config import ClientProfile, FLRunConfig
        from repro.fl.runner import FLCloudRunner
        cfg = CloudConfig(spot_rate_sigma=0.0, market=MarketConfig(
            providers=(
                ProviderConfig(name="aws",
                               price_trace=str(FIXTURES / "aws.csv")),
                ProviderConfig(name="gcp",
                               price_trace=str(FIXTURES / "gcp.csv")),
            )))
        clients = (ClientProfile("pinned", mean_epoch_s=300, jitter=0.0,
                                 zone="us-central1-a"),
                   ClientProfile("free", mean_epoch_s=150, jitter=0.0))
        run_cfg = FLRunConfig(dataset="t", clients=clients, n_epochs=2,
                              policy="fedcostaware", seed=0)
        r = FLCloudRunner(run_cfg, cloud_cfg=cfg)
        res = r.run()
        assert res.rounds_completed == 2
        pinned = [e for e in r.sim.event_log
                  if e["client"] == "pinned" and e["kind"] == "request"]
        assert all(e["provider"] == "gcp" for e in pinned)
